"""SliceRuntime demo — two tenants served concurrently on one pod.

The paper's system, live on the real engine: a (reduced) Llama-3 tenant on
a 2s.32c slice whose HBM budget is pinned *below* its footprint — so the
offload planner spills the embedding table whole and a cold tail of the KV
pool to the host tier (paper §VI-A) — next to a GPT-2 tenant on a 1s.16c
slice that fits outright. The runtime packs both rectangles with
``StaticPartitioner``, drives both engines round-robin, and reports
per-tenant tokens/sec, pod utilization, and the modeled power/throttling
account of §V-B.

    PYTHONPATH=src python examples/slice_runtime_demo.py
"""
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.serving import Request, SliceRuntime, TenantSpec


def main() -> None:
    mesh = make_host_mesh(1, 1)
    rt = SliceRuntime(mesh=mesh)

    # tenant A: llama3 on 2s.32c with a pinned HBM budget below footprint
    # (reduced-scale stand-in for "KV pool slightly exceeds the slice")
    llm_cfg = get_config("llama3-8b").reduced().with_(remat="none")
    rt.add_tenant(TenantSpec(
        "llm-serve", llm_cfg, profile="2s.32c", slots=4, max_seq=64,
        hbm_budget=380_000, spill_granule=4096))

    # tenant B: gpt2 on its own 1s.16c slice, fits without offloading
    gpt_cfg = get_config("gpt2-124m").reduced().with_(remat="none")
    rt.add_tenant(TenantSpec(
        "gpt2-serve", gpt_cfg, profile="1s.16c", slots=4, max_seq=32))

    print("=== placement & plans ===")
    for t in rt.tenants.values():
        print(f"  {t.name:10s} -> {t.alloc.profile.name} rect={t.alloc.rect} "
              f"offloaded={list(t.plan.offloaded)} "
              f"partial={[n for n, _ in t.plan.partial]} "
              f"host_bytes={t.plan.host_bytes}")
        split = t.engine.pool.split_leaves
        if split:
            print(f"  {'':10s}    cold-tail split: {split} "
                  f"(hot prefix length per leaf)")

    rng = np.random.default_rng(0)
    rt.submit("llm-serve", [
        Request(i, rng.integers(0, llm_cfg.vocab_size, size=8).astype(np.int32), 8)
        for i in range(8)])
    rt.submit("gpt2-serve", [
        Request(i, rng.integers(0, gpt_cfg.vocab_size, size=6).astype(np.int32), 6)
        for i in range(8)])

    report = rt.run()

    print("\n=== per-tenant serving report ===")
    for name, row in report["tenants"].items():
        print(f"  {name:10s} {row['profile']:8s} tokens={row['tokens_out']:4d} "
              f"tok/s={row['tok_per_s']:7.1f} completed={row['completed']} "
              f"truncated={row['truncated']} "
              f"kv_host/dev={row['kv_host_bytes']}/{row['kv_device_bytes']}")

    print(f"\npod utilization: {report['pod_utilization'] * 100:.0f}% "
          f"({report['free_chips']} chips free)")
    m = report["modeled"]
    print(f"modeled co-run (synthetic power calib.): "
          f"throttle={m['throttle']:.2f} "
          f"energy={m['energy_J'] / 1e3:.1f}kJ")


if __name__ == "__main__":
    main()
