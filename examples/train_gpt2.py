"""End-to-end driver (deliverable b): train a ~100M-param GPT-2 for a few
hundred steps — the paper's own llm.c training workload (Table III).

By default this runs the FULL gpt2-124m config for 200 steps on CPU, with
checkpointing and fault-tolerant restart enabled. That takes a while on one
CPU core; pass --tiny for a 2-layer sanity run.

    PYTHONPATH=src python examples/train_gpt2.py [--tiny] [--steps N]
"""
import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "gpt2-124m",
           "--steps", str(args.steps),
           "--batch", str(args.batch),
           "--seq", str(args.seq),
           "--ckpt-dir", "/tmp/repro_gpt2_ckpt"]
    if not args.tiny:
        cmd.append("--full-size")
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
