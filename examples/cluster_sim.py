"""Cluster scheduling demo — the fragmentation story, end to end.

Replays the crafted stranding trace from ``repro.cluster.trace`` under all
three placement policies on one 16×16 pod: ten small/medium jobs interleave
arrivals and completions until 128 chips are free but scattered; then an
8×16 job arrives that fits the pod's free chips and *no* aligned rectangle
(the arXiv 2512.16099 stranding case cited by ``StaticPartitioner.repack``).
First-fit leaves it queued past the horizon; the repack-enabled policy
compacts the five live slices — paying a modeled migration cost over the
pod's host links — and places it seconds later.

Next, the elastic-shrink story: a deadline job that would miss its SLO
behind two long slice holders is rescued by shrinking the low-priority
batch holder to a smaller profile (priced as a repack-style migration) —
the progress-based ``PodSimulator`` re-bases the victim's remaining work
onto the smaller slice.

Third, the preemption story: a deadline job arrives on a full pod where a
shrink cannot mint its rectangle — with priorities enabled the scheduler
checkpoint-evicts the low-priority batch holder (suspend priced as the
``train/checkpoint.py`` save volume over the pod's host links), the
deadline job hits its SLO, and the victim later resumes from its
checkpoint with ``work_done`` preserved.

Fourth, the grow story: when a short neighbour finishes, a running
training job absorbs the freed chips via the partitioner's transactional
``extend()`` and its projected finish improves.

Fifth, the cross-pod migration story (the Action API's
``MigrateAcrossPods``): on a load-imbalanced two-pod cluster every
in-pod rescue fails — the only free rectangle sits next to a full-power
holder and trips the shared power cap — so the scheduler relocates a
*cold* holder to the hot pod over the DCN (priced as checkpoint
save/restore over ``PodSpec.dcn_bw``) and places the hot deadline job in
the drained rectangle: global hot/cold balancing no single-pod move can
express.

Sixth, the look-ahead story: no *single* action mints the deadline job's
8×16 origin (each eviction frees one 8×8), so the greedy selector queues
it to a miss; ``LookAheadPolicy`` trial-applies the first eviction
(transactional ``apply``/``rollback``), sees the second now closes the
chain, and commits the pair.

Then a seeded mixed trace (serving + training + low-utilization batch jobs,
Poisson arrivals) is scheduled with serving jobs executing on **live**
``SliceRuntime`` tenants.

    PYTHONPATH=src python examples/cluster_sim.py
"""
from repro.cluster import (ClusterScheduler, PolicySpec, TraceConfig,
                           elastic_showcase, format_metrics,
                           fragmentation_showcase, generate_trace,
                           grow_showcase, lookahead_showcase,
                           migration_showcase, preemption_showcase)
from repro.cluster.placement import POLICY_NAMES

STRANDED = 10  # job_id of the 8×16 arrival in the showcase trace
DEADLINE = 2   # job_id of the SLO-critical arrival in the elastic trace
PREEMPT_DEADLINE = 2  # SLO-critical arrival in the preemption trace
VICTIM = 0     # low-priority batch holder / growing training job
MIGRATE_DEADLINE = 3  # SLO-critical arrival in the migration trace
LOOKAHEAD_DEADLINE = 3  # SLO-critical arrival in the look-ahead trace


def main() -> None:
    print("=== crafted stranding trace (one pod, horizon 3000 s) ===")
    jobs = fragmentation_showcase()
    results = []
    for policy in POLICY_NAMES:
        sched = ClusterScheduler(n_pods=1, policy=policy, horizon_s=3000.0)
        records, metrics = sched.run(jobs)
        results.append(metrics)
        big = next(r for r in records if r.job.job_id == STRANDED)
        print(f"  {policy:12s} 8x16 job: "
              + (f"placed at t={big.place_s:.0f}s on {big.profile_name} "
                 f"origin={big.origin}" if big.placed
                 else "QUEUED at horizon (stranded)"))
    print()
    print(format_metrics(results))

    print("\n=== elastic shrink: SLO miss -> hit (one pod) ===")
    for elastic in (False, True):
        sched = ClusterScheduler(
            n_pods=1, policy="frag_repack", horizon_s=3000.0,
            spec=PolicySpec(actions=("shrink",) if elastic else ()))
        records, metrics = sched.run(elastic_showcase())
        d = next(r for r in records if r.job.job_id == DEADLINE)
        verdict = ("SLO HIT" if d.finished and d.finish_s <= d.deadline_s
                   else "SLO MISS")
        print(f"  elastic={str(elastic):5s} deadline job: "
              + (f"placed t={d.place_s:.0f}s finish={d.finish_s:.0f}s "
                 f"deadline={d.deadline_s:.0f}s -> {verdict}"
                 if d.placed else f"never placed -> {verdict}")
              + f"  (shrinks={metrics.shrinks})")

    print("\n=== checkpoint preemption: SLO miss -> hit (one pod) ===")
    for priorities in (False, True):
        sched = ClusterScheduler(
            n_pods=1, policy="frag_repack",
            spec=PolicySpec(actions=("shrink", "preempt") if priorities
                            else ("shrink",)))
        records, metrics = sched.run(preemption_showcase())
        d = next(r for r in records if r.job.job_id == PREEMPT_DEADLINE)
        v = next(r for r in records if r.job.job_id == VICTIM)
        verdict = ("SLO HIT" if d.finished and d.finish_s <= d.deadline_s
                   else "SLO MISS")
        print(f"  priorities={str(priorities):5s} deadline job: "
              f"placed t={d.place_s:.0f}s finish={d.finish_s:.0f}s "
              f"deadline={d.deadline_s:.0f}s -> {verdict}")
        if priorities:
            print(f"    victim: evicted t={v.suspend_s:.0f}s, resumed "
                  f"t={v.resume_s:.0f}s, finished t={v.finish_s:.0f}s "
                  f"(checkpoint delay {v.checkpoint_delay_s:.2f}s, "
                  f"{v.checkpoint_bytes / 2**30:.0f} GiB saved+restored)")

    print("\n=== elastic grow: absorb freed neighbour chips (one pod) ===")
    for grow in (False, True):
        sched = ClusterScheduler(
            n_pods=1, policy="frag_repack",
            spec=PolicySpec(actions=("grow",) if grow else ()))
        records, metrics = sched.run(grow_showcase())
        g = next(r for r in records if r.job.job_id == VICTIM)
        print(f"  grow={str(grow):5s} training job: profile="
              f"{g.profile_name}{'+' if g.grown else ''} "
              f"finish={g.finish_s:.0f}s (grows={metrics.grows})")

    print("\n=== cross-pod migration: SLO miss -> hit (two pods, DCN) ===")
    for migrate in (False, True):
        sched = ClusterScheduler(
            n_pods=2, policy="frag_repack",
            spec=PolicySpec(actions=("shrink", "preempt", "migrate")
                            if migrate else ("shrink", "preempt")))
        records, metrics = sched.run(migration_showcase())
        d = next(r for r in records if r.job.job_id == MIGRATE_DEADLINE)
        v = next(r for r in records if r.job.job_id == VICTIM)
        verdict = ("SLO HIT" if d.finished and d.finish_s <= d.deadline_s
                   else "SLO MISS")
        print(f"  migrate={str(migrate):5s} deadline job: "
              f"placed t={d.place_s:.0f}s finish={d.finish_s:.0f}s "
              f"deadline={d.deadline_s:.0f}s -> {verdict}")
        if migrate:
            print(f"    victim: relocated pod0->pod{v.pod_idx} at "
                  f"t={v.migrate_s:.0f}s, kept running, finished "
                  f"t={v.finish_s:.0f}s ({v.dcn_bytes / 2**30:.0f} GiB "
                  f"over the DCN, {v.dcn_delay_s:.2f}s save+restore)")

    print("\n=== look-ahead: chained evictions rescue the SLO (one pod) ===")
    for selector in ("greedy", "lookahead"):
        sched = ClusterScheduler(
            n_pods=1, policy="frag_repack",
            spec=PolicySpec(selector=selector,
                            actions=("shrink", "preempt")))
        records, metrics = sched.run(lookahead_showcase())
        d = next(r for r in records if r.job.job_id == LOOKAHEAD_DEADLINE)
        verdict = ("SLO HIT" if d.finished and d.finish_s <= d.deadline_s
                   else "SLO MISS")
        print(f"  policy={selector:9s} deadline job: "
              + (f"placed t={d.place_s:.0f}s finish={d.finish_s:.0f}s "
                 f"deadline={d.deadline_s:.0f}s -> {verdict}"
                 if d.placed else f"never placed -> {verdict}")
              + f"  (preemptions={metrics.preemptions})")

    print("\n=== seeded mixed trace, live serving tenants (two pods) ===")
    trace = generate_trace(TraceConfig(seed=0, n_jobs=12,
                                       mean_interarrival_s=45.0))
    sched = ClusterScheduler(n_pods=2, policy="frag_repack",
                             execute_serving=True)
    records, metrics = sched.run(trace)
    for r in sorted(records, key=lambda r: r.job.job_id):
        live = f" tokens={r.tokens_out}" if r.executed else ""
        print(f"  job{r.job.job_id:<3d} {r.job.kind:8s} {r.job.arch:15s} "
              f"-> pod{r.pod_idx} {r.profile_name}{live}")
    print()
    print(format_metrics([metrics]))


if __name__ == "__main__":
    main()
