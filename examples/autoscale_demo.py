"""Autoscale demo — a compressed serving day, fixed vs autoscaled.

Two tenants ride one seeded diurnal tide (phase-staggered, so their
peaks don't coincide) through an eight-hour virtual day. The same load
curves are run twice:

* **fixed** — both tenants provisioned at peak size (``8s.128c``) all
  day; the ``AutoscaleController`` rides along in ``observe`` mode so
  the latency accounting is identical, but it never acts;
* **autoscale** — tenants start at ``1s.16c`` and the hysteresis
  controller resizes them through the priced Action API: ``Grow`` as
  the tide comes in (falling back to ``MigrateTenant`` when the local
  pod has no rectangle to extend into), ``ShrinkTenant`` as it goes
  out — each action transactional, priced, and cooldown-gated.

The punchline printed at the end is the paper's economic claim in
miniature: the autoscaled day burns a fraction of the fixed day's
chip-hours at an equal-or-better p99 SLO hit rate.

    PYTHONPATH=src python examples/autoscale_demo.py
"""
from repro.cluster import (AutoscaleController, AutoscaleSpec,
                           ClusterScheduler, format_metrics,
                           serving_workload)

DAY_S = 28800.0        # 8h virtual day (compressed for a quick demo)
INTERVAL_S = 300.0     # control period
COOLDOWN_S = 900.0     # min seconds between actions per tenant
TENANTS = 2
PODS = 2
SEED = 0


def run_day(mode: str):
    """One modeled serving day; ``mode`` is "fixed" or "autoscale"."""
    jobs, curves = serving_workload(
        n_tenants=TENANTS, curve="diurnal", horizon_s=DAY_S, seed=SEED,
        start_profile="1s.16c" if mode == "autoscale" else "8s.128c")
    spec = AutoscaleSpec(interval_s=INTERVAL_S, cooldown_s=COOLDOWN_S,
                         mode="hysteresis" if mode == "autoscale"
                         else "observe")
    ctrl = AutoscaleController(curves, spec, seed=SEED)
    sched = ClusterScheduler(n_pods=PODS, horizon_s=DAY_S, autoscaler=ctrl)
    _, metrics = sched.run(jobs)
    return metrics, ctrl


def main() -> None:
    print(f"=== fixed provisioning (8s.128c all day, {DAY_S / 3600:.0f}h "
          f"day, {TENANTS} tenants) ===")
    fixed_m, _ = run_day("fixed")
    print(format_metrics([fixed_m]))
    print()

    print("=== autoscaled (start 1s.16c, hysteresis controller) ===")
    auto_m, ctrl = run_day("autoscale")
    print(format_metrics([auto_m]))
    print()
    print("action log (t, tenant, kind):")
    for t, jid, kind in ctrl.action_log:
        print(f"  {t:>8,.0f}s  tenant {jid}  {kind}")
    print()

    saved = 100.0 * (1.0 - auto_m.serving_chip_hours
                     / fixed_m.serving_chip_hours)
    print(f"verdict: {auto_m.serving_chip_hours:,.1f} chip-hours vs "
          f"{fixed_m.serving_chip_hours:,.1f} fixed "
          f"({saved:.1f}% saved) at SLO hit rate "
          f"{auto_m.serving_slo_hit_rate:.1%} vs "
          f"{fixed_m.serving_slo_hit_rate:.1%}")
    assert auto_m.serving_chip_hours < fixed_m.serving_chip_hours
    assert auto_m.serving_slo_hit_rate >= fixed_m.serving_slo_hit_rate


if __name__ == "__main__":
    main()
