"""Fine-grained CPU offloading for serving — paper §VI-A, executed for real.

A (reduced) Llama-3 is served twice: KV pool resident in device memory, then
placed in ``pinned_host`` memory via JAX memory kinds — the same mechanism a
real TPU runtime uses. Outputs must match exactly; the wall-time difference
on this CPU container is NOT meaningful (both tiers are host RAM here) — the
roofline model in benchmarks/bench_offload.py prices the real TPU cost.

    PYTHONPATH=src python examples/offload_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.offload import inventory_from_tree, plan_offload
from repro.launch.mesh import make_host_mesh
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, host_axis_env())
    params, _ = model.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh(1, 1)

    # what would the planner offload if the KV pool overflowed the slice?
    cache = model.init_cache(4, 128)
    inv = inventory_from_tree({"kv": cache})
    total = sum(t.bytes for t in inv)
    plan = plan_offload(inv, hbm_budget=total // 2)
    print(f"KV pool {total / 1024:.0f} KiB, budget {total // 2 / 1024:.0f} KiB "
          f"-> offloaded {plan.host_bytes / 1024:.0f} KiB "
          f"(fits={plan.fits}, traffic/step={plan.host_traffic_per_step / 1024:.1f} KiB)")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(4)]

    results = {}
    for offload in (False, True):
        eng = ServingEngine(model, params, slots=2, max_seq=64,
                            mesh=mesh, offload_kv=offload)
        kinds = {x.sharding.memory_kind
                 for x in jax.tree_util.tree_leaves(eng.cache)}
        t0 = time.time()
        out = eng.run([Request(i, p, 6) for i, p in enumerate(prompts)])
        dt = time.time() - t0
        results[offload] = out
        print(f"offload_kv={offload!s:5s} memory_kinds={kinds} "
              f"wall={dt:.2f}s tokens={sum(len(v) for v in out.values())}")

    assert results[False] == results[True], "offloading changed results!"
    print("outputs identical with and without KV offloading ✓")


if __name__ == "__main__":
    main()
