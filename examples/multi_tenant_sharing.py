"""Multi-tenant GPU-sharing scenario — the paper's §V/§VI story end-to-end.

Three tenants (LLM serving, SSM serving, MoE training) are placed on ONE pod:
reward-metric selection (α sweep), static partitioning, fine-grained offload
planning for the tenant that doesn't fit its slice, co-run throughput/energy
vs the serial baseline, and the power-cap throttling check.

    PYTHONPATH=src python examples/multi_tenant_sharing.py
"""
from repro.configs import get_config, get_shape
from repro.core.cosched import corun_copies, mixed_tenancy
from repro.core.hw import GiB, V5E_POD
from repro.core.partitioner import StaticPartitioner
from repro.core.reward import sweep
from repro.core.slices import get_profile, profile_table
from repro.core.workload import WorkloadEstimate


def main() -> None:
    print("=== slice profile table (paper Table II analogue) ===")
    for r in profile_table():
        print(f"  {r['profile']:10s} chips={r['chips']:4d} "
              f"hbm={r['hbm_gib']:6.0f}GiB host_bw={r['host_link_gbps']:5.0f}GB/s")

    tenants = {
        "llm-serve": WorkloadEstimate(get_config("llama3-8b"),
                                      get_shape("decode_32k")),
        "ssm-serve": WorkloadEstimate(get_config("mamba2-130m"),
                                      get_shape("decode_32k")),
        "moe-train": WorkloadEstimate(get_config("granite-moe-1b-a400m"),
                                      get_shape("train_4k")),
    }

    print("\n=== reward-driven placement (α = 0.1, ≤half-pod quota) ===")
    placement = {}
    for tag, wl in tenants.items():
        pts = [p for p in sweep(wl, alpha=0.1) if p.profile.n_chips <= 128]
        best = pts[0]
        placement[tag] = best.profile.name
        off = (f" +offload {best.plan.host_bytes / GiB:.0f}GiB->host"
               if best.plan and best.plan.host_bytes else "")
        print(f"  {tag:10s} footprint={wl.footprint_bytes() / GiB:6.0f}GiB "
              f"-> {best.profile.name}{off}  R={best.reward:.2f} "
              f"perf_rel={best.perf_rel:.2f}")

    print("\n=== packing the pod ===")
    part = StaticPartitioner()
    for tag, prof in placement.items():
        a = part.allocate(get_profile(prof), tag=tag)
        print(f"  {tag:10s} -> rect {a.rect}")
    part.validate()
    print(f"  pod utilization: {part.utilization() * 100:.0f}% "
          f"({part.free_chips()} chips free)")

    print("\n=== co-run economics ===")
    res = mixed_tenancy(tenants, placement)
    print(f"  makespan {res['makespan_s']:.2f}s  energy {res['energy_J'] / 1e6:.2f}MJ  "
          f"throttle_factor {res['throttle_factor']:.2f}")

    print("\n=== N-copies sharing table for the SSM tenant (Fig. 5/6) ===")
    for copies, prof in ((16, "1s.16c"), (4, "4s.64c"), (2, "8s.128c")):
        r = corun_copies(tenants["ssm-serve"], get_profile(prof), copies)
        if r:
            print(f"  {r.config:12s} tput_norm={r.throughput_norm:5.2f} "
                  f"energy_norm={r.energy_norm:4.2f} throttled={r.throttled}")


if __name__ == "__main__":
    main()
