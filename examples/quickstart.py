"""Quickstart: train a tiny GPT-2 for 30 steps, then serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataPipeline, SyntheticSource
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model
from repro.optim import adamw
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    cfg = get_config("gpt2-124m").reduced()
    model = build_model(cfg, host_axis_env())
    params, _ = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=60)
    opt = adamw.init(params)
    pipe = DataPipeline(SyntheticSource(cfg.vocab_size, seed=0), 4, 64)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        p, o, _ = adamw.update(opt_cfg, grads, opt, params)
        return p, o, loss

    print("training…")
    for i in range(30):
        b = pipe.batch_at(i)
        params, opt, loss = step(params, opt,
                                 {k: jnp.asarray(v) for k, v in b.items()})
        if i % 10 == 0:
            print(f"  step {i:3d} loss {float(loss):.3f}")
    print(f"  final loss {float(loss):.3f}")

    print("serving…")
    engine = ServingEngine(model, params, slots=2, max_seq=96)
    prompts = [np.arange(1, 9, dtype=np.int32), np.arange(3, 17, dtype=np.int32)]
    out = engine.run([Request(i, p, 8) for i, p in enumerate(prompts)])
    for rid, toks in sorted(out.items()):
        print(f"  request {rid}: generated {toks}")


if __name__ == "__main__":
    main()
