"""SliceRuntime — multi-tenant serving on one statically partitioned pod.

This is the paper's system put together end-to-end on the *real* engine
(previously only the analytical simulator in ``core/cosched.py`` composed
these pieces):

1. **Place** — each tenant asks for a slice profile;
   ``StaticPartitioner`` packs the rectangles onto the pod grid and fails
   loudly when they don't fit (§IV/§V-A).
2. **Plan** — the tenant's *measured* inventory (its actual params and KV
   pool, via ``Model.serving_inventory``) goes through ``plan_offload``
   against the slice's HBM; an overhang spills to ``pinned_host`` with
   real memory kinds, partial KV spills as a physically split cold tail
   in the tenant's ``KVPool`` (§VI-A).
3. **Serve** — every tenant runs a ``TenantEngine`` (continuous batching,
   admission control); the runtime drives them round-robin and reports
   per-tenant tokens/sec plus pod utilization.
4. **Account** — the shared surfaces partitioning does NOT isolate (pod
   power delivery, §V-B) are priced by ``core.power``: the report includes
   the modeled throttle factor and energy for the co-run, so the paper's
   Figs. 5–7 quantities can be read off a live serving run.

On this CPU container the slices are logical (every tenant executes on
the host backend); the partitioner, plans, memory kinds, and power
accounting are exactly what a pod-scale deployment would use.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import jax

from repro.configs.base import ModelConfig
from repro.configs.shapes import get_shape
from repro.core.hw import PodSpec, V5E_POD
from repro.core.offload import OffloadPlan, place_tree, plan_offload
from repro.core.partitioner import SliceAllocation, StaticPartitioner
from repro.core.perfmodel import InstanceLoad, PerfModel, get_model
from repro.core.slices import SliceProfile, get_profile, smallest_fitting
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model
from repro.serving.tenant import Request, TenantEngine


@dataclass(frozen=True)
class TenantSpec:
    """Everything the runtime needs to admit one tenant."""
    name: str
    cfg: ModelConfig
    profile: Union[str, SliceProfile, None] = None  # None -> smallest fitting
    slots: int = 4
    max_seq: int = 128
    max_queue: Optional[int] = None
    # Override the slice's HBM budget for the offload plan. Reduced-scale
    # demo models fit any real slice trivially; pinning the budget below the
    # tenant's footprint exercises the same plan->spill path a full-size
    # model hits on a real 16-chip slice.
    hbm_budget: Optional[int] = None
    # Spill granule for divisible tensors; default (None) keeps the
    # production 64 MiB granule — shrink it alongside hbm_budget in demos.
    spill_granule: Optional[int] = None
    shape: str = "decode_32k"   # ShapeSuite for the modeled power accounting
    seed: int = 0
    # Pin the slice rectangle's origin (must be profile-aligned and free) —
    # set by fragmentation-aware placers (repro.cluster.placement); None
    # keeps the partitioner's first-fit origin.
    origin: Optional[tuple] = None


@dataclass
class Tenant:
    spec: TenantSpec
    alloc: SliceAllocation
    model: object
    params: object
    plan: OffloadPlan
    engine: TenantEngine
    inventory_bytes: int
    wall_s: float = 0.0
    submitted: int = 0

    @property
    def name(self) -> str:
        return self.spec.name


class SliceRuntime:
    def __init__(self, pod: PodSpec = V5E_POD, mesh=None,
                 partitioner: Optional[StaticPartitioner] = None,
                 perf: Optional[PerfModel] = None):
        self.pod = pod
        self.mesh = mesh   # execution mesh (host backend here); placement
        # an externally owned partitioner lets a cluster-level scheduler
        # (repro.cluster) share one pod grid between its own modeled jobs
        # and this runtime's live tenants
        self.partitioner = (partitioner if partitioner is not None
                            else StaticPartitioner(pod))
        # shared performance engine: throttle/energy accounting goes through
        # the same memoized PerfModel the cluster scheduler scores with
        self.perf = perf if perf is not None else get_model(pod.chip)
        self.tenants: Dict[str, Tenant] = {}

    # ------------------------------------------------------------------
    # tenant lifecycle
    # ------------------------------------------------------------------
    def _resolve_profile(self, spec: TenantSpec, footprint: int
                         ) -> SliceProfile:
        if isinstance(spec.profile, SliceProfile):
            return spec.profile
        if isinstance(spec.profile, str):
            return get_profile(spec.profile)
        prof = smallest_fitting(footprint, 0.0, self.pod)
        if prof is None:
            raise RuntimeError(
                f"tenant {spec.name!r}: footprint {footprint} bytes exceeds "
                f"every slice profile")
        return prof

    def add_tenant(self, spec: TenantSpec) -> Tenant:
        """Place, plan, and spin up one tenant. Raises (loudly) when the pod
        has no room for the requested profile or the tenant cannot fit its
        slice even with everything offloadable spilled."""
        if spec.name in self.tenants:
            raise ValueError(f"duplicate tenant {spec.name!r}")
        env = (host_axis_env() if self.mesh is None
               else None)
        model = (build_model(spec.cfg, env) if env is not None
                 else build_model(spec.cfg, self.mesh))
        params, param_specs = model.init(jax.random.PRNGKey(spec.seed))
        cache_bytes = model.cache_bytes(spec.slots, spec.max_seq)
        param_bytes = sum(int(x.size) * x.dtype.itemsize
                          for x in jax.tree_util.tree_leaves(params))
        footprint = param_bytes + cache_bytes

        profile = self._resolve_profile(spec, footprint)
        alloc = self.partitioner.allocate(profile, tag=spec.name,
                                          origin=spec.origin)
        try:
            tenant = self._plan_and_build(spec, profile, alloc, model,
                                          params, param_specs, footprint)
        except Exception:
            self.partitioner.release(alloc.slice_id)
            raise
        self.tenants[spec.name] = tenant
        return tenant

    def _plan_and_build(self, spec, profile, alloc, model, params,
                        param_specs, footprint) -> Tenant:
        chip = self.pod.chip
        # abstract cache: the inventory only needs sizes/dtypes, and the
        # engine's KVPool will allocate the real pool itself
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(spec.slots, spec.max_seq))
        inventory = model.serving_inventory(params, cache_shapes)
        hbm_budget = (spec.hbm_budget if spec.hbm_budget is not None
                      else profile.hbm_bytes(chip))
        plan = plan_offload(
            inventory, hbm_budget,
            host_budget=profile.host_dram_bytes(chip),
            **({"spill_granule": spec.spill_granule}
               if spec.spill_granule is not None else {}))
        if not plan.fits:
            raise RuntimeError(
                f"tenant {spec.name!r} does not fit {profile.name}: "
                f"{plan.resident_bytes} resident bytes > {hbm_budget} budget "
                f"even after spilling {plan.host_bytes} to host")
        if self.mesh is not None:
            params = place_tree({"params": params}, {"params": param_specs},
                                plan, self.mesh)["params"]
        engine = TenantEngine(
            model, params, slots=spec.slots, max_seq=spec.max_seq,
            mesh=self.mesh, plan=plan, max_queue=spec.max_queue,
            name=spec.name)
        return Tenant(spec=spec, alloc=alloc, model=model, params=params,
                      plan=plan, engine=engine, inventory_bytes=footprint)

    def remove_tenant(self, name: str, *, repack: bool = False) -> None:
        tenant = self.tenants.pop(name)
        self.partitioner.release(tenant.alloc.slice_id)
        if repack:
            self.partitioner.repack()

    def resize_tenant(self, name: str,
                      profile: Union[str, SliceProfile]) -> Tenant:
        """Move a live tenant to a different slice profile — the serving
        side of the cluster Action API's ``Shrink``/``Grow`` moves, with
        the same probe → price → commit discipline:

        1. **probe** — re-plan the tenant's measured inventory against the
           new profile's HBM/host budgets; a plan that does not fit raises
           before anything moves.
        2. **commit** — ``StaticPartitioner.resize`` swaps the rectangle
           transactionally (the slice keeps its id; growing requires the
           extension chips to be free, and a conflict raises with the grid
           untouched).

        A pinned ``spec.hbm_budget`` (demo tenants) is kept as-is, like
        ``add_tenant`` does. On this host backend the KV pool and engine
        keep running across the resize — what changes is the rectangle,
        the offload plan, and the modeled power/throttle accounting."""
        tenant = self.tenants[name]
        profile = (get_profile(profile) if isinstance(profile, str)
                   else profile)
        if profile.name == tenant.alloc.profile.name:
            return tenant
        spec = tenant.spec
        chip = self.pod.chip
        cache_shapes = jax.eval_shape(
            lambda: tenant.model.init_cache(spec.slots, spec.max_seq))
        inventory = tenant.model.serving_inventory(tenant.params,
                                                   cache_shapes)
        hbm_budget = (spec.hbm_budget if spec.hbm_budget is not None
                      else profile.hbm_bytes(chip))
        plan = plan_offload(
            inventory, hbm_budget,
            host_budget=profile.host_dram_bytes(chip),
            **({"spill_granule": spec.spill_granule}
               if spec.spill_granule is not None else {}))
        if not plan.fits:
            raise RuntimeError(
                f"tenant {name!r} does not fit {profile.name}: "
                f"{plan.resident_bytes} resident bytes > {hbm_budget} "
                f"budget even after spilling {plan.host_bytes} to host")
        self.partitioner.resize(tenant.alloc.slice_id, profile)
        tenant.plan = plan
        return tenant

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def submit(self, name: str, requests: Sequence[Request]) -> int:
        """Queue requests for one tenant; returns how many were admitted
        past the tenant's admission bound."""
        tenant = self.tenants[name]
        n = sum(tenant.engine.submit(r) for r in requests)
        tenant.submitted += n
        return n

    def step(self) -> Dict[str, int]:
        """One round-robin sweep: each tenant admits + decodes one tick."""
        out = {}
        for tenant in self.tenants.values():
            if tenant.engine.idle:
                continue
            t0 = time.perf_counter()
            out[tenant.name] = tenant.engine.tick()
            tenant.wall_s += time.perf_counter() - t0
        return out

    def run(self, max_ticks: Optional[int] = None) -> Dict[str, dict]:
        """Drive all tenants until every queue drains (or ``max_ticks``)."""
        ticks = 0
        while any(not t.engine.idle for t in self.tenants.values()):
            if max_ticks is not None and ticks >= max_ticks:
                break
            self.step()
            ticks += 1
        return self.report()

    # ------------------------------------------------------------------
    # accounting (paper Figs. 5-7 quantities, on the live engine)
    # ------------------------------------------------------------------
    def _instance_loads(self, steps: int = 100) -> List[InstanceLoad]:
        """Pod-scale modeled loads for the live tenant mix, scored by the
        shared ``PerfModel`` (full-size analytic numbers even when the
        tenants execute reduced configs on the host backend)."""
        loads = []
        for tenant in self.tenants.values():
            sc = self.perf.score(tenant.spec.cfg,
                                 get_shape(tenant.spec.shape),
                                 tenant.alloc.profile)
            if sc is None:   # cannot fit per the full-scale model: account
                # it as a fully-utilized slice rather than dropping it
                loads.append(InstanceLoad(tenant.alloc.profile.n_chips,
                                          1.0, 1.0, steps))
            else:
                loads.append(sc.load(steps))
        return loads

    def report(self) -> Dict[str, dict]:
        per_tenant = {}
        for tenant in self.tenants.values():
            eng = tenant.engine
            per_tenant[tenant.name] = {
                "profile": tenant.alloc.profile.name,
                "rect": tenant.alloc.rect,
                "tokens_out": eng.stats.tokens_out,
                "prefill_tokens": eng.stats.prefill_tokens,
                "completed": eng.stats.completed,
                "truncated": eng.stats.truncated,
                "rejected": eng.stats.rejected,
                "ticks": eng.stats.ticks,
                "tok_per_s": (eng.stats.tokens_out / tenant.wall_s
                              if tenant.wall_s else 0.0),
                "plan_host_bytes": tenant.plan.host_bytes,
                "plan_offloaded": list(tenant.plan.offloaded),
                "plan_partial": [n for n, _ in tenant.plan.partial],
                "kv_device_bytes": eng.pool.device_bytes,
                "kv_host_bytes": eng.pool.host_bytes,
                "latency": eng.stats.latency_percentiles(),
            }
            if self.perf.twin is not None:
                # twin-offload pricing for this tenant's rectangle: the
                # rung the cluster scheduler would co-execute host-side,
                # or None when the plain score already wins (nothing
                # compute-bearing spilled / speedup below threshold)
                tw = self.perf.score_twin(tenant.spec.cfg,
                                          get_shape(tenant.spec.shape),
                                          tenant.alloc.profile)
                sc = self.perf.score(tenant.spec.cfg,
                                     get_shape(tenant.spec.shape),
                                     tenant.alloc.profile)
                per_tenant[tenant.name]["twin"] = None if tw is None else {
                    "rung": tw.rung,
                    "cpu_fraction": tw.twin.cpu_fraction,
                    "step_time_s": tw.step_time,
                    "speedup": (sc.step_time / tw.step_time
                                if sc is not None else None),
                }
        result = {
            "tenants": per_tenant,
            "pod_utilization": self.partitioner.utilization(),
            "free_chips": self.partitioner.free_chips(),
        }
        if self.tenants:
            run = self.perf.corun(self._instance_loads(), self.pod)
            result["modeled"] = {   # synthetic power calibration (hw.py)
                "throttle": run.throttle,
                "throttled": run.throttled,
                "makespan_s": run.makespan_s,
                "energy_J": run.energy_J,
            }
        return result
