"""Back-compat shim: the old single-model ``ServingEngine`` name.

The engine was refactored into the SliceRuntime stack (docs/serving.md):

* ``repro.serving.kv_pool.KVPool``   — slot-paged cache + host placement
* ``repro.serving.tenant.TenantEngine`` — continuous batching per tenant
* ``repro.serving.runtime.SliceRuntime`` — multi-tenant pod runtime

``ServingEngine`` is now exactly a ``TenantEngine`` without a slice or an
offload plan of its own — kept so single-model callers and the original
tests keep working unchanged.
"""
from __future__ import annotations

from repro.serving.tenant import Request, TenantEngine

__all__ = ["Request", "ServingEngine"]


class ServingEngine(TenantEngine):
    def __init__(self, model, params, *, slots: int, max_seq: int,
                 mesh=None, offload_kv: bool = False):
        super().__init__(model, params, slots=slots, max_seq=max_seq,
                         mesh=mesh, offload_kv=offload_kv)
