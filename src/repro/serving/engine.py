"""Serving engine: slot-based continuous batching over a shared KV pool.

``ServingEngine`` owns a fixed (batch_slots, max_seq) cache, admits requests
into free slots (prefill writes the slot's KV prefix), and advances ALL live
slots with one fused decode step per tick — the standard continuous-batching
structure. The cache placement goes through the offload planner: with
``offload_kv=True`` the pool lives in ``pinned_host`` memory (paper §VI-A
applied to serving: a model whose KV pool slightly exceeds the slice's HBM
runs on the small slice instead of doubling it).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model_zoo import Model

PyTree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,)
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ServingEngine:
    def __init__(self, model: Model, params: PyTree, *, slots: int,
                 max_seq: int, mesh=None, offload_kv: bool = False):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.mesh = mesh
        self.cache = model.init_cache(slots, max_seq)
        if offload_kv and mesh is not None:
            specs = model.cache_specs(slots)
            self.cache = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(
                    x, NamedSharding(mesh, s, memory_kind="pinned_host")),
                self.cache, specs)
        self.positions = np.zeros(slots, np.int32)   # per-slot cache length
        self.live: Dict[int, Request] = {}           # slot -> request
        self._free = list(range(slots))
        self.ticks = 0

    # ------------------------------------------------------------------
    def admit(self, req: Request) -> bool:
        if not self._free:
            return False
        slot = self._free.pop()
        req.slot = slot
        # prefill: run forward with cache on the prompt, paste into the pool
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        _, _, pc = self.model.forward(self.params, batch, return_cache=True)
        plen = len(req.prompt)

        def paste(pool, pref):
            if pool.ndim >= 3 and pool.shape[2] == self.max_seq:
                return pool.at[:, slot:slot + 1, :plen].set(
                    pref.astype(pool.dtype))
            # state caches (ssm): (L, B, ...) — overwrite the slot
            return pool.at[:, slot:slot + 1].set(pref.astype(pool.dtype))

        self.cache = jax.tree_util.tree_map(paste, self.cache, pc)
        self.positions[slot] = plen
        self.live[slot] = req
        return True

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One decode step for every live slot. Returns tokens emitted."""
        if not self.live:
            return 0
        # batch the newest token of each live slot; idle slots get token 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, req in self.live.items():
            last = (req.generated[-1] if req.generated else int(req.prompt[-1]))
            tokens[slot, 0] = last
        # per-row cache positions: ragged continuous batching
        batch = {"tokens": jnp.asarray(tokens),
                 "pos": jnp.asarray(self.positions, jnp.int32)}
        logits, self.cache = self.model.decode(self.params, self.cache, batch)
        emitted = 0
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for slot, req in list(self.live.items()):
            req.generated.append(int(next_tokens[slot]))
            self.positions[slot] += 1
            emitted += 1
            if req.done or self.positions[slot] >= self.max_seq - 1:
                del self.live[slot]
                self._free.append(slot)
        self.ticks += 1
        return emitted

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        pending = list(requests)
        out: Dict[int, List[int]] = {}
        while pending or self.live:
            while pending and self._free:
                self.admit(pending.pop(0))
            self.tick()
            for r in requests:
                if r.done and r.rid not in out:
                    out[r.rid] = r.generated
        return out
