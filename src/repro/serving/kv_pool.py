"""KVPool — slot-paged KV/state pool with planner-driven host placement.

The pool owns a fixed ``(slots, max_seq)`` cache tree plus the slot free
list and per-slot lengths. Placement is where the paper's §VI-A mechanism
becomes real: an ``OffloadPlan`` maps onto the pool leaf-by-leaf with JAX
memory kinds —

* fully offloaded leaves live whole in ``pinned_host``;
* *partially* spilled leaves are physically split along the sequence axis
  into a device-resident hot prefix and a ``pinned_host`` cold tail (the
  fine-grained spill ``shardings_with_offload`` cannot express, because a
  single JAX buffer has exactly one memory kind);
* everything else stays in device memory.

Decode consumes ``materialize()`` (tail concatenated back on) and returns
the updated tree to ``update()``, which re-splits and re-pins the tail —
the double-buffered DMA round-trip of DESIGN.md §2, executed eagerly here.
On this CPU container both tiers are host RAM, so the split costs nothing
and changes nothing numerically; the roofline model prices the real link.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.offload import (OffloadPlan, _flatten_with_paths,
                                device_memory_kind, host_memory_kind)

PyTree = Any

SEQ_AXIS = 2  # layer-stacked caches: (L, slots, seq, heads, head_dim)


def _has_seq_axis(leaf, max_seq: int) -> bool:
    return leaf.ndim > SEQ_AXIS and leaf.shape[SEQ_AXIS] == max_seq


def _spec_allows_seq_split(spec, mesh) -> bool:
    """Splitting the seq axis needs that axis unsharded in the leaf spec
    (or sharded only over mesh axes of size 1, where the cut is still a
    whole-shard boundary)."""
    try:
        if len(spec) <= SEQ_AXIS or spec[SEQ_AXIS] is None:
            return True
    except TypeError:
        return True
    if mesh is None:
        return False
    axes = spec[SEQ_AXIS]
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return all(sizes.get(a, 1) == 1 for a in axes)


class KVPool:
    def __init__(self, model, slots: int, max_seq: int, *, mesh=None,
                 plan: Optional[OffloadPlan] = None, offload_all: bool = False,
                 dtype=jnp.bfloat16, prefix: str = "kv"):
        self.model = model
        self.slots = slots
        self.max_seq = max_seq
        self.mesh = mesh
        self.prefix = prefix
        self.positions = np.zeros(slots, np.int32)   # per-slot cache length
        self._free: List[int] = list(range(slots))

        cache = model.init_cache(slots, max_seq, dtype)
        flat = _flatten_with_paths(cache)
        self._paths = [p for p, _ in flat]
        self._treedef = jax.tree_util.tree_structure(cache)
        leaves = [leaf for _, leaf in flat]

        specs = _flatten_with_paths(model.cache_specs(slots))
        spec_by_path = dict(specs)

        # per-leaf placement decision
        self._hot_sharding: Dict[int, NamedSharding] = {}
        self._cold_sharding: Dict[int, NamedSharding] = {}
        self._host_sharding: Dict[int, NamedSharding] = {}   # fully-host
        self._hot_len: Dict[int, int] = {}            # split leaves only
        self._hot: List[Any] = []
        self._cold: Dict[int, Any] = {}
        self._host_leaves: Set[int] = set()           # fully host-placed

        host_kind = host_memory_kind(mesh) if mesh is not None else None
        dev_kind = device_memory_kind(mesh) if mesh is not None else None
        for i, (path, leaf) in enumerate(zip(self._paths, leaves)):
            full_path = f"{prefix}/{path}" if prefix else path
            kind, hot_len = self._decide(full_path, leaf, plan, offload_all,
                                         spec_by_path.get(path))
            if mesh is not None and kind != "device":
                spec = spec_by_path.get(path)
                if kind == "host":
                    sh = NamedSharding(mesh, spec, memory_kind=host_kind)
                    leaf = jax.device_put(leaf, sh)
                    self._host_leaves.add(i)
                    self._host_sharding[i] = sh
                elif kind == "split":
                    hot_sh = NamedSharding(mesh, spec, memory_kind=dev_kind)
                    cold_sh = NamedSharding(mesh, spec,
                                            memory_kind=host_kind)
                    idx = [slice(None)] * leaf.ndim
                    idx[SEQ_AXIS] = slice(0, hot_len)
                    hot = jax.device_put(leaf[tuple(idx)], hot_sh)
                    idx[SEQ_AXIS] = slice(hot_len, max_seq)
                    self._cold[i] = jax.device_put(leaf[tuple(idx)], cold_sh)
                    self._hot_len[i] = hot_len
                    self._hot_sharding[i] = hot_sh
                    self._cold_sharding[i] = cold_sh
                    leaf = hot
            self._hot.append(leaf)

    # ------------------------------------------------------------------
    def _decide(self, full_path: str, leaf, plan: Optional[OffloadPlan],
                offload_all: bool, spec) -> Tuple[str, int]:
        """('device'|'host'|'split', hot_len) for one leaf."""
        if offload_all or (plan is not None and plan.is_offloaded(full_path)):
            return "host", 0
        if plan is None:
            return "device", 0
        spilled = dict(plan.partial).get(full_path)
        if not spilled:
            return "device", 0
        nbytes = int(leaf.size) * leaf.dtype.itemsize
        frac = min(1.0, spilled / nbytes)
        if (_has_seq_axis(leaf, self.max_seq)
                and (spec is None or _spec_allows_seq_split(spec, self.mesh))):
            cold = min(self.max_seq - 1, max(1, math.ceil(frac * self.max_seq)))
            return "split", self.max_seq - cold
        # no seq axis to cut (ssm state, conv tail): round to majority side
        return ("host", 0) if frac >= 0.5 else ("device", 0)

    # ------------------------------------------------------------------
    # slot management (the "paged" part — one page per request slot)
    # ------------------------------------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free)

    def alloc_slot(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def free_slot(self, slot: int) -> None:
        self.positions[slot] = 0
        self._free.append(slot)

    # ------------------------------------------------------------------
    # cache access
    # ------------------------------------------------------------------
    def materialize(self) -> PyTree:
        """Full cache tree for decode: cold tails concatenated back on."""
        if not self._cold:
            return jax.tree_util.tree_unflatten(self._treedef, self._hot)
        leaves = []
        for i, hot in enumerate(self._hot):
            if i in self._cold:
                leaves.append(jnp.concatenate([hot, self._cold[i]],
                                              axis=SEQ_AXIS))
            else:
                leaves.append(hot)
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def update(self, new_cache: PyTree) -> None:
        """Absorb a decode-updated cache tree, re-splitting spilled tails
        back into pinned_host (the write-back half of the DMA round trip)."""
        leaves = jax.tree_util.tree_leaves(new_cache)
        assert len(leaves) == len(self._hot), "cache structure changed"
        for i, leaf in enumerate(leaves):
            if i in self._cold:
                hot_len = self._hot_len[i]
                idx = [slice(None)] * leaf.ndim
                idx[SEQ_AXIS] = slice(0, hot_len)
                self._hot[i] = jax.device_put(leaf[tuple(idx)],
                                              self._hot_sharding[i])
                idx[SEQ_AXIS] = slice(hot_len, self.max_seq)
                self._cold[i] = jax.device_put(leaf[tuple(idx)],
                                               self._cold_sharding[i])
            elif i in self._host_leaves:
                # eager decode outputs land in device memory; pin the leaf
                # back to the host tier or the whole "offloaded" pool would
                # migrate to HBM after one tick
                self._hot[i] = jax.device_put(leaf, self._host_sharding[i])
            else:
                self._hot[i] = leaf

    def paste(self, slot: int, prefix_cache: PyTree, plen: int) -> None:
        """Write a prefill prefix into one slot (the admit path)."""
        cache = self.materialize()

        def _paste(pool, pref):
            if _has_seq_axis(pool, self.max_seq):
                return pool.at[:, slot:slot + 1, :plen].set(
                    pref.astype(pool.dtype))
            # state caches (ssm): (L, B, ...) — overwrite the slot
            return pool.at[:, slot:slot + 1].set(pref.astype(pool.dtype))

        self.update(jax.tree_util.tree_map(_paste, cache, prefix_cache))
        self.positions[slot] = plen

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def memory_kinds(self) -> Set[str]:
        kinds = set()
        for i, leaf in enumerate(self._hot):
            sh = getattr(leaf, "sharding", None)
            kinds.add(getattr(sh, "memory_kind", None) or "device")
            if i in self._cold:
                kinds.add(self._cold[i].sharding.memory_kind)
        return kinds

    def _bytes(self, leaves) -> int:
        return sum(int(x.size) * x.dtype.itemsize for x in leaves)

    @property
    def device_bytes(self) -> int:
        """Planned HBM-resident bytes (hot prefixes + unspilled leaves)."""
        return self._bytes(leaf for i, leaf in enumerate(self._hot)
                           if i not in self._host_leaves)

    @property
    def host_bytes(self) -> int:
        """Planned host-tier bytes (cold tails + fully spilled leaves)."""
        return (self._bytes(self._cold.values())
                + self._bytes(self._hot[i] for i in self._host_leaves))

    @property
    def split_leaves(self) -> Dict[str, int]:
        """path -> hot prefix length for every physically split leaf."""
        return {self._paths[i]: n for i, n in self._hot_len.items()}
