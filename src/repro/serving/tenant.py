"""TenantEngine — one tenant's continuous-batching engine over a KVPool.

The refactored core of the old ``ServingEngine``: prefill and decode are
separate paths (``prefill`` writes one request's KV prefix into a pool
slot; ``tick`` advances ALL live slots with one fused ragged decode step),
requests queue behind an admission-control bound, and eviction at the pool
boundary records the partial generation instead of dropping the request —
a truncated answer is still an answer the tenant must bill for.

A tenant never sees another tenant's pool or params; the only shared
surfaces are the ones the paper identifies (host link, pod power), which
``SliceRuntime`` accounts for at the layer above.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import OffloadPlan
from repro.serving.kv_pool import KVPool

PyTree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,)
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    truncated: bool = False      # evicted at max_seq before max_new_tokens
    # latency stamps, in engine ticks (the engine's unit of time):
    submit_tick: Optional[int] = None   # queued (or first seen at prefill)
    admit_tick: Optional[int] = None    # slot claimed, prefix written
    finish_tick: Optional[int] = None   # completed/evicted, end of that tick

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


def _pct(xs: List[int], q: float) -> float:
    return float(np.percentile(np.asarray(xs, dtype=float), q)) if xs else 0.0


@dataclass
class TenantStats:
    ticks: int = 0
    tokens_out: int = 0
    prefill_tokens: int = 0
    admitted: int = 0
    rejected: int = 0
    completed: int = 0
    truncated: int = 0
    # per-request latency samples (ticks): admission-queue wait and
    # end-to-end submit → completion — the autoscaler's SLO signal
    queue_wait_ticks: List[int] = field(default_factory=list)
    e2e_ticks: List[int] = field(default_factory=list)

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 of queue wait and end-to-end latency, in ticks."""
        return {
            "queue_wait_p50": _pct(self.queue_wait_ticks, 50),
            "queue_wait_p99": _pct(self.queue_wait_ticks, 99),
            "e2e_p50": _pct(self.e2e_ticks, 50),
            "e2e_p99": _pct(self.e2e_ticks, 99),
        }


class TenantEngine:
    def __init__(self, model, params: PyTree, *, slots: int, max_seq: int,
                 mesh=None, offload_kv: bool = False,
                 plan: Optional[OffloadPlan] = None,
                 max_queue: Optional[int] = None, name: str = "tenant"):
        self.name = name
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.mesh = mesh
        self.plan = plan
        self.pool = KVPool(model, slots, max_seq, mesh=mesh, plan=plan,
                           offload_all=offload_kv and mesh is not None)
        self.queue: Deque[Request] = deque()
        self.max_queue = max_queue
        self.live: Dict[int, Request] = {}           # slot -> request
        self.outputs: Dict[int, List[int]] = {}      # rid -> generated
        self.stats = TenantStats()
        self.ticks = 0

    # -- compatibility properties (pre-refactor ServingEngine surface) -----
    @property
    def cache(self) -> PyTree:
        return self.pool.materialize()

    @property
    def positions(self) -> np.ndarray:
        return self.pool.positions

    # ------------------------------------------------------------------
    # admission control
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request; False = rejected (queue at its admission bound)."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.stats.rejected += 1
            return False
        if req.submit_tick is None:
            req.submit_tick = self.ticks
        self.queue.append(req)
        return True

    @property
    def idle(self) -> bool:
        return not self.queue and not self.live

    # ------------------------------------------------------------------
    # prefill path
    # ------------------------------------------------------------------
    def prefill(self, req: Request) -> bool:
        """Claim a slot and write the request's KV prefix into the pool."""
        if len(req.prompt) > self.max_seq - 1:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} "
                f"exceeds max_seq-1 ({self.max_seq - 1}) — queue path "
                f"rejects these; direct prefill callers must pre-check")
        slot = self.pool.alloc_slot()
        if slot is None:
            return False
        req.slot = slot
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        _, _, pc = self.model.forward(self.params, batch, return_cache=True)
        plen = len(req.prompt)
        self.pool.paste(slot, pc, plen)
        self.live[slot] = req
        if req.submit_tick is None:
            req.submit_tick = self.ticks   # direct-admit callers skip submit()
        req.admit_tick = self.ticks
        self.stats.queue_wait_ticks.append(req.admit_tick - req.submit_tick)
        self.stats.admitted += 1
        self.stats.prefill_tokens += plen
        return True

    def admit(self, req: Request) -> bool:
        """Pre-refactor surface: direct prefill, bypassing the queue."""
        return self.prefill(req)

    def _admit_from_queue(self) -> None:
        while self.queue and self.pool.free_slots:
            req = self.queue.popleft()
            if len(req.prompt) > self.max_seq - 1:
                # prompt can never fit the pool: reject it, visibly — an
                # empty result with the truncated flag, not a crash
                req.truncated = True
                self.outputs[req.rid] = req.generated
                self.stats.rejected += 1
                continue
            self.prefill(req)

    # ------------------------------------------------------------------
    # decode path
    # ------------------------------------------------------------------
    def tick(self) -> int:
        """Admit what fits, then one decode step for every live slot.
        Returns tokens emitted."""
        self._admit_from_queue()
        if not self.live:
            return 0
        # batch the newest token of each live slot; idle slots get token 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, req in self.live.items():
            last = (req.generated[-1] if req.generated else int(req.prompt[-1]))
            tokens[slot, 0] = last
        # per-row cache positions: ragged continuous batching
        batch = {"tokens": jnp.asarray(tokens),
                 "pos": jnp.asarray(self.pool.positions, jnp.int32)}
        logits, new_cache = self.model.decode(
            self.params, self.pool.materialize(), batch)
        self.pool.update(new_cache)
        emitted = 0
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for slot, req in list(self.live.items()):
            req.generated.append(int(next_tokens[slot]))
            self.pool.positions[slot] += 1
            emitted += 1
            if req.done or self.pool.positions[slot] >= self.max_seq - 1:
                if not req.done:
                    # evicted at the pool boundary: a *truncated* generation,
                    # recorded like any other (the pre-refactor engine
                    # silently dropped these)
                    req.truncated = True
                    self.stats.truncated += 1
                self.stats.completed += 1
                req.finish_tick = self.ticks + 1   # done by this tick's end
                self.stats.e2e_ticks.append(req.finish_tick - req.submit_tick)
                self.outputs[req.rid] = req.generated
                del self.live[slot]
                self.pool.free_slot(slot)
        self.ticks += 1
        self.stats.ticks += 1
        self.stats.tokens_out += emitted
        return emitted

    # ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Drain a closed batch of requests (single-tenant convenience).
        Every request appears in the result — including ones evicted at
        ``max_seq`` with a partial generation (``req.truncated`` set)."""
        for r in requests:
            self.queue.append(r)    # closed batch: bypass the admission bound
        while not self.idle:
            self.tick()
        return dict(self.outputs)
