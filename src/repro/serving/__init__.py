"""repro.serving"""
