"""repro.serving — the SliceRuntime multi-tenant serving stack."""
from repro.serving.kv_pool import KVPool
from repro.serving.tenant import Request, TenantEngine, TenantStats
from repro.serving.runtime import SliceRuntime, TenantSpec
from repro.serving.engine import ServingEngine

__all__ = ["KVPool", "Request", "TenantEngine", "TenantStats",
           "SliceRuntime", "TenantSpec", "ServingEngine"]
