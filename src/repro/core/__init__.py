"""Core library: the paper's contribution as composable modules.

- hw: TPU chip/pod hardware model
- slices: static slice profiles (MIG-table analogue)
- partitioner: StaticPartitioner over the pod device grid
- offload: fine-grained host-offload planner (+ memory-kind application)
- roofline: three-term roofline from compiled HLO
- workload: analytic per-step estimates feeding reward/cosched
- reward: the paper's R-metric and config selector
- utilization: derived utilization metrics (paper IV)
- cosched: co-running throughput/energy simulator (paper V)
- power: shared-power-cap throttling model (paper V-B)
- perfmodel: the one performance engine (memoized scoring + progress-based
  PodSimulator) every consumer outside core/ goes through
"""
from repro.core.hw import V5E, V5E_POD, ChipSpec, PodSpec
from repro.core.offload import OffloadPlan, TensorInfo, plan_offload
from repro.core.partitioner import SliceAllocation, StaticPartitioner
from repro.core.perfmodel import (Anchor, PerfModel, PerfScore, PodSimulator,
                                  get_model, load_anchors)
from repro.core.reward import RewardPoint, select, sweep
from repro.core.roofline import RooflineTerms, analyze, parse_collectives
from repro.core.slices import PROFILES, SliceProfile, get_profile, profile_table
from repro.core.workload import WorkloadEstimate

__all__ = [
    "V5E", "V5E_POD", "ChipSpec", "PodSpec",
    "OffloadPlan", "TensorInfo", "plan_offload",
    "SliceAllocation", "StaticPartitioner",
    "RewardPoint", "select", "sweep",
    "RooflineTerms", "analyze", "parse_collectives",
    "PROFILES", "SliceProfile", "get_profile", "profile_table",
    "WorkloadEstimate",
    "Anchor", "PerfModel", "PerfScore", "PodSimulator", "get_model",
    "load_anchors",
]
