"""Three-term roofline analysis from compiled XLA artifacts.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)
    (+ host term when an offload plan adds host-link traffic)

Sources: ``compiled.cost_analysis()`` supplies HLO_FLOPs and HLO_bytes
(per-device, since the module is SPMD-partitioned). Collective bytes are NOT
in cost_analysis — we parse the partitioned HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.

Loop multiplicity: jax.lax.scan lowers to a while loop whose body appears
ONCE in the HLO text but executes trip-count times. Collectives found inside
a while-body computation are therefore multiplied by ``loop_trip_count``
(supplied by the caller — the model's layer count). Nested scans (attention
KV chunks inside a layer) contain no collectives by construction of our
sharding, so a single multiplier is exact for this codebase; the parser still
reports which computations it scaled so this assumption is auditable.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.hw import ChipSpec, V5E

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[8,128]' / 'f32[]' ; tuples handled by caller."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    size = 1
    if dims:
        for d in dims.split(","):
            size *= int(d)
    return size * _DTYPE_BYTES.get(dt, 4)


def _result_bytes(line: str) -> int:
    """Sum bytes of the op's result shape(s) on an HLO text line."""
    lhs = line.split("=", 1)
    if len(lhs) != 2:
        return 0
    # result type is after '=' : e.g.  %x = bf16[2,4]{1,0} all-gather(...)
    rhs = lhs[1].strip()
    total = 0
    for m in re.finditer(r"([a-z0-9]+\[[0-9,]*\])", rhs.split("(")[0]):
        total += _shape_bytes(m.group(1))
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)
    scaled_computations: List[str] = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str, loop_trip_count: int = 1
                      ) -> CollectiveStats:
    """Sum collective result bytes in partitioned HLO; collectives inside
    while-loop bodies are scaled by ``loop_trip_count``."""
    stats = CollectiveStats()
    # split into computations:  name { ... }
    comp_re = re.compile(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
    # find while bodies: body=%name
    while_bodies = set(re.findall(r"body=(%?[\w\.\-]+)", hlo_text))
    cur_comp = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = comp_re.match(stripped)
        if m and stripped.endswith("{"):
            cur_comp = m.group(1)
            continue
        for op in COLLECTIVE_OPS:
            # "all-reduce(" or "all-reduce-start("
            if re.search(rf"=\s*(?:[a-z0-9\[\],{{}}\s/*]+)?{op}(?:-start)?\(",
                         stripped):
                nbytes = _result_bytes(stripped)
                mult = 1
                if cur_comp is not None and any(
                        cur_comp.lstrip("%").startswith(b.lstrip("%").split(".")[0])
                        or b in (cur_comp,) for b in while_bodies):
                    mult = loop_trip_count
                    if cur_comp not in stats.scaled_computations:
                        stats.scaled_computations.append(cur_comp)
                stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + nbytes * mult
                stats.count_by_op[op] = stats.count_by_op.get(op, 0) + mult
                break
    return stats


@dataclass
class RooflineTerms:
    """All times in seconds; per-step, per-chip view of one compiled program."""
    t_compute: float
    t_memory: float
    t_collective: float
    t_host: float
    hlo_flops: float            # per chip
    hlo_bytes: float            # per chip
    collective_bytes: float     # per chip
    host_bytes: float           # per chip
    model_flops: float          # 6·N·D (or analogous) — global useful FLOPs
    n_chips: int
    collectives: Optional[CollectiveStats] = None
    hlo_cost: Optional[object] = None            # core.hlo_analysis.HloCost
    xla_cost_analysis: Optional[dict] = None     # raw (loop-unaware) numbers
    # CPU-side service time of a twin-offload split (core.offload.plan_twin);
    # 0.0 everywhere except twin rungs, so plain scores are unchanged.
    t_cpu: float = 0.0

    @property
    def step_time(self) -> float:
        """Perfect-overlap lower bound: the slowest wall dominates."""
        return max(self.t_compute, self.t_memory, self.t_collective,
                   self.t_host, self.t_cpu)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective, "host": self.t_host,
                 "cpu": self.t_cpu}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/padding/redundancy waste.

        HLO flops are per-chip; model flops global."""
        total_hlo = self.hlo_flops * self.n_chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def model_flops_utilization(self) -> float:
        """Roofline-model MFU: useful FLOPs / (chips × peak × step_time)."""
        denom = self.n_chips * V5E.peak_flops_bf16 * self.step_time
        return self.model_flops / denom if denom else 0.0

    def as_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "t_host_s": self.t_host,
            "t_cpu_s": self.t_cpu,
            "step_time_s": self.step_time, "dominant": self.dominant,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_mfu": self.model_flops_utilization,
            "n_chips": self.n_chips,
        }


def analyze(cost_analysis: Dict[str, float], hlo_text: str, n_chips: int,
            model_flops: float, *, loop_trip_count: int = 1,
            host_bytes_per_step: float = 0.0, chip: ChipSpec = V5E
            ) -> RooflineTerms:
    """Roofline terms from a compiled module.

    Primary source is the loop-aware HLO analyzer (``core.hlo_analysis``):
    XLA's own ``cost_analysis()`` counts while-loop bodies once (verified
    empirically), which under-counts scan-over-layers models by ~the layer
    count. The raw cost_analysis numbers are kept as cross-check fields.
    """
    from repro.core.hlo_analysis import analyze_hlo
    hc = analyze_hlo(hlo_text)
    flops = float(hc.flops)                      # per chip, trip-corrected
    nbytes = float(hc.bytes_accessed)
    coll_bytes = float(hc.total_collective_bytes)
    # legacy stats view for reporting
    coll = CollectiveStats(
        bytes_by_op={k: int(v) for k, v in hc.collective_bytes.items()},
        count_by_op=dict(hc.collective_counts),
        scaled_computations=[f"{k}×{v}" for k, v in
                             sorted(hc.trip_counts.items())[:12]])
    host_per_chip = host_bytes_per_step / n_chips if n_chips else 0.0
    terms = RooflineTerms(
        t_compute=flops / chip.peak_flops_bf16,
        t_memory=nbytes / chip.hbm_bw,
        t_collective=coll_bytes / chip.ici_bw,
        t_host=host_per_chip / chip.host_link_bw_per_chip,
        hlo_flops=flops, hlo_bytes=nbytes, collective_bytes=coll_bytes,
        host_bytes=host_per_chip, model_flops=model_flops, n_chips=n_chips,
        collectives=coll,
    )
    terms.hlo_cost = hc  # top cost sites for the perf loop
    terms.xla_cost_analysis = {
        "flops_uncorrected": float(cost_analysis.get("flops", 0.0)),
        "bytes_uncorrected": float(cost_analysis.get("bytes accessed", 0.0)),
    }
    return terms


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D for training (fwd+bwd), 2·N·D for inference;
    MoE uses active params (assignment §Roofline)."""
    n = cfg.active_param_count()
    tokens = shape.tokens_per_step
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens
