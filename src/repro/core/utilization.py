"""Utilization metrics per workload — paper §IV (Figs. 2-3), derived.

The paper samples GPM hardware counters (SM occupancy, bandwidth, capacity);
this container has no hardware, so the same quantities are derived from
roofline terms (labeled "derived" in every report):

  U_compute  ~ SM occupancy analogue  = t_compute / step_time
  U_bw       ~ memory bandwidth util  = t_memory / step_time
  U_capacity ~ memory capacity util   = resident_bytes / slice HBM
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.hw import ChipSpec, V5E
from repro.core.perfmodel import get_model
from repro.core.slices import PROFILES, SliceProfile
from repro.core.workload import WorkloadEstimate


@dataclass(frozen=True)
class UtilizationReport:
    profile: str
    u_compute: float
    u_bandwidth: float
    u_capacity: float
    fits: bool
    offloaded_bytes: int
    dominant: str

    def waste_compute(self, profile: SliceProfile, pod_chips: int) -> float:
        return (profile.n_chips / pod_chips) * (1 - self.u_compute)


def utilization_on(wl: WorkloadEstimate, profile: SliceProfile,
                   chip: ChipSpec = V5E) -> Optional[UtilizationReport]:
    sc = get_model(chip).score(wl.cfg, wl.shape, profile)
    if sc is None:
        return None
    return UtilizationReport(
        profile=profile.name,
        u_compute=sc.u_compute,
        u_bandwidth=sc.terms.t_memory / sc.step_time if sc.step_time else 0.0,
        u_capacity=min(1.0, sc.plan.resident_bytes / profile.hbm_bytes(chip)),
        fits=True,
        offloaded_bytes=sc.plan.host_bytes,
        dominant=sc.terms.dominant,
    )


def scaling_curve(wl: WorkloadEstimate, chip: ChipSpec = V5E) -> List[dict]:
    """Paper Fig. 4: relative performance vs slice size, normalized to the
    smallest profile the workload fits on WITHOUT offloading (the paper's
    setup — offloaded points are reported separately, marked ``offloaded``)."""
    perf = get_model(chip)
    rows = []
    base_rate = None
    for prof in PROFILES:
        fits_plain = wl.footprint_bytes() <= prof.hbm_bytes(chip)
        sc = perf.score(wl.cfg, wl.shape, prof)
        if not fits_plain:
            if sc is not None:
                rows.append({"profile": prof.name, "fits": False,
                             "offloaded": True,
                             "offload_rate": 1.0 / sc.step_time})
            else:
                rows.append({"profile": prof.name, "fits": False,
                             "offloaded": False})
            continue
        terms = sc.terms
        rate = 1.0 / terms.step_time
        if base_rate is None:
            base_rate = rate
            base_chips = prof.n_chips
        rows.append({
            "profile": prof.name, "fits": True,
            "rel_perf": rate / base_rate,
            "ideal": prof.n_chips / base_chips,
            "dominant": terms.dominant,
            "offloaded": False,
        })
    return rows
