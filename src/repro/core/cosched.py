"""Co-scheduling simulator — the paper's §V system-level experiments.

Given a workload and a sharing configuration (N copies on N slices of one
pod), compute aggregate throughput and energy, normalized to the serial
full-pod baseline — the structure of paper Figs. 5 and 6 — including the
shared-power-cap throttling interference of Fig. 7. All scoring and power
accounting goes through ``core.perfmodel.PerfModel`` (one shared memo table
with the cluster scheduler and the serving runtime).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.hw import PodSpec, V5E_POD
from repro.core.perfmodel import PerfModel, get_model
from repro.core.slices import PROFILES, SliceProfile, get_profile
from repro.core.workload import WorkloadEstimate


@dataclass(frozen=True)
class CoRunResult:
    config: str
    copies: int
    throughput_norm: float    # aggregate task throughput vs serial baseline
    energy_norm: float        # total energy vs serial baseline
    throttled: bool
    throttle_factor: float
    per_instance_step: float  # effective (throttled) step time per instance


def corun_copies(wl: WorkloadEstimate, profile: SliceProfile, copies: int,
                 pod: PodSpec = V5E_POD, steps: int = 100,
                 perf: Optional[PerfModel] = None) -> Optional[CoRunResult]:
    """N identical copies, one per slice (paper §V-A setup)."""
    if copies > profile.max_instances(pod):
        return None
    perf = perf if perf is not None else get_model(pod.chip)
    sc = perf.score(wl.cfg, wl.shape, profile)
    if sc is None:
        return None
    run = perf.corun([sc.load(steps)] * copies, pod)

    base_sc = perf.score(wl.cfg, wl.shape, PROFILES[-1])
    s_makespan, s_energy = perf.serial_baseline(base_sc.load(steps),
                                               copies, pod)
    return CoRunResult(
        config=f"{copies}x{profile.name}",
        copies=copies,
        throughput_norm=(s_makespan / run.makespan_s
                         if run.makespan_s else 0.0),
        energy_norm=run.energy_J / s_energy if s_energy else 0.0,
        throttled=run.throttled,
        throttle_factor=run.throttle,
        per_instance_step=(max(run.effective_times) / steps
                           if run.effective_times else 0.0),
    )


def sharing_table(wl: WorkloadEstimate, pod: PodSpec = V5E_POD
                  ) -> List[CoRunResult]:
    """Sweep the standard sharing configs (paper Fig. 5's x-axis analogue)."""
    out = []
    for prof_name, copies in (("1s.16c", 16), ("1s.16c", 8), ("2s.32c", 8),
                              ("4s.64c", 4), ("8s.128c", 2)):
        r = corun_copies(wl, get_profile(prof_name), copies, pod)
        if r is not None:
            out.append(r)
    return out


def mixed_tenancy(workloads: Dict[str, WorkloadEstimate],
                  placement: Dict[str, str], pod: PodSpec = V5E_POD,
                  steps: int = 100):
    """Co-run *different* workloads on one pod (beyond-paper: the paper only
    co-runs identical copies). placement: tag -> profile name."""
    from repro.core.partitioner import StaticPartitioner
    perf = get_model(pod.chip)
    part = StaticPartitioner(pod)
    loads = []
    rows = []
    for tag, prof_name in placement.items():
        wl = workloads[tag]
        prof = get_profile(prof_name)
        part.allocate(prof, tag=tag)         # raises if it doesn't pack
        sc = perf.score(wl.cfg, wl.shape, prof)
        if sc is None:
            raise RuntimeError(f"{tag!r} does not fit {prof_name} even "
                               f"with offload")
        loads.append(sc.load(steps))
        rows.append((tag, prof_name, sc.step_time, sc.u_compute,
                     sc.plan.offloaded))
    part.validate()
    run = perf.corun(loads, pod)
    return {
        "placements": rows,
        "makespan_s": run.makespan_s,
        "energy_J": run.energy_J,
        "throttle_factor": run.throttle,
        "pod_utilization": part.utilization(),
        "effective_times": list(run.effective_times),
    }
