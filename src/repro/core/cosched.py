"""Co-scheduling simulator — the paper's §V system-level experiments.

Given a workload and a sharing configuration (N copies on N slices of one
pod), compute aggregate throughput and energy, normalized to the serial
full-pod baseline — the structure of paper Figs. 5 and 6 — including the
shared-power-cap throttling interference of Fig. 7.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.hw import PodSpec, V5E_POD
from repro.core.power import InstanceLoad, co_run, serial_run, throttle_factor
from repro.core.slices import PROFILES, SliceProfile, get_profile
from repro.core.workload import WorkloadEstimate


@dataclass(frozen=True)
class CoRunResult:
    config: str
    copies: int
    throughput_norm: float    # aggregate task throughput vs serial baseline
    energy_norm: float        # total energy vs serial baseline
    throttled: bool
    throttle_factor: float
    per_instance_step: float  # effective (throttled) step time per instance


def corun_copies(wl: WorkloadEstimate, profile: SliceProfile, copies: int,
                 pod: PodSpec = V5E_POD, steps: int = 100
                 ) -> Optional[CoRunResult]:
    """N identical copies, one per slice (paper §V-A setup)."""
    if copies > profile.max_instances(pod):
        return None
    plan = wl.plan_for(profile, pod.chip)
    if not plan.fits:
        return None
    terms = wl.roofline_on(profile, pod.chip,
                           plan if plan.offloaded else None)
    u_c = terms.t_compute / terms.step_time
    inst = InstanceLoad(profile.n_chips, u_c, terms.step_time, steps)
    instances = [inst] * copies
    makespan, energy, eff = co_run(instances, pod)
    f = throttle_factor(instances, pod)

    full = PROFILES[-1]
    terms_full = wl.roofline_on(full, pod.chip)
    u_full = terms_full.t_compute / terms_full.step_time
    base = InstanceLoad(full.n_chips, u_full, terms_full.step_time, steps)
    s_makespan, s_energy = serial_run(base, copies, pod)

    return CoRunResult(
        config=f"{copies}x{profile.name}",
        copies=copies,
        throughput_norm=s_makespan / makespan if makespan else 0.0,
        energy_norm=energy / s_energy if s_energy else 0.0,
        throttled=f < 1.0,
        throttle_factor=f,
        per_instance_step=max(eff) / steps if eff else 0.0,
    )


def sharing_table(wl: WorkloadEstimate, pod: PodSpec = V5E_POD
                  ) -> List[CoRunResult]:
    """Sweep the standard sharing configs (paper Fig. 5's x-axis analogue)."""
    out = []
    for prof_name, copies in (("1s.16c", 16), ("1s.16c", 8), ("2s.32c", 8),
                              ("4s.64c", 4), ("8s.128c", 2)):
        r = corun_copies(wl, get_profile(prof_name), copies, pod)
        if r is not None:
            out.append(r)
    return out


def mixed_tenancy(workloads: Dict[str, WorkloadEstimate],
                  placement: Dict[str, str], pod: PodSpec = V5E_POD,
                  steps: int = 100):
    """Co-run *different* workloads on one pod (beyond-paper: the paper only
    co-runs identical copies). placement: tag -> profile name."""
    from repro.core.partitioner import StaticPartitioner
    part = StaticPartitioner(pod)
    loads = []
    rows = []
    for tag, prof_name in placement.items():
        wl = workloads[tag]
        prof = get_profile(prof_name)
        part.allocate(prof, tag=tag)         # raises if it doesn't pack
        plan = wl.plan_for(prof, pod.chip)
        terms = wl.roofline_on(prof, pod.chip, plan if plan.offloaded else None)
        u = terms.t_compute / terms.step_time
        loads.append(InstanceLoad(prof.n_chips, u, terms.step_time, steps))
        rows.append((tag, prof_name, terms.step_time, u, plan.offloaded))
    part.validate()
    makespan, energy, eff = co_run(loads, pod)
    f = throttle_factor(loads, pod)
    return {
        "placements": rows,
        "makespan_s": makespan,
        "energy_J": energy,
        "throttle_factor": f,
        "pod_utilization": part.utilization(),
        "effective_times": eff,
    }
