"""Analytic workload estimates: footprint, FLOPs, traffic per step.

The reward sweep (paper §VI-B) must evaluate every (profile × offload plan)
combination cheaply, so it uses these closed-form estimates rather than a
compile per point. The pod-scale dry-run (launch/dryrun.py) provides the
measured-from-HLO anchors; ``benchmarks/roofline.py`` cross-checks the two
(EXPERIMENTS.md §Roofline reports both where available).

All byte counts are *global per step*; roofline terms divide by chip count.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.configs.base import MOE, ModelConfig
from repro.configs.shapes import DECODE, TRAIN, ShapeSuite
from repro.core.hw import ChipSpec, HostSpec, V5E, V5E_HOST
from repro.core.offload import (GROUP_TRAFFIC, OffloadPlan, TensorInfo,
                                TwinOffloadPlan, TwinShard, plan_offload,
                                plan_twin)
from repro.core.roofline import RooflineTerms, model_flops_for
from repro.core.slices import SliceProfile

# Twin-offload shard constants (documented modeling assumptions):
# Adam update arithmetic per parameter (m/v decay, bias correction, step) and
# the fp32 host-DRAM accesses it makes (read m,v,g,p; write m,v,p).
ADAM_FLOPS_PER_PARAM = 12.0
ADAM_DRAM_BYTES_PER_PARAM = 7 * 4
# Decode attention over a cached element: one MAC against K and one against V.
KV_FLOPS_PER_ELEMENT = 4.0
# Fraction of decode tokens routed through *cold* (spilled) MoE experts —
# cold by definition, so well under the uniform 1/num_experts share.
MOE_COLD_TOKEN_FRACTION = 0.1


@dataclass(frozen=True)
class WorkloadEstimate:
    cfg: ModelConfig
    shape: ShapeSuite

    # ------------------------------------------------------------------
    # memory footprint inventory (drives capacity + offload decisions)
    # ------------------------------------------------------------------
    def inventory(self) -> List[TensorInfo]:
        cfg, shape = self.cfg, self.shape
        N = cfg.param_count()
        embed_params = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        body_params = N - embed_params
        inv: List[TensorInfo] = []
        if shape.kind == TRAIN:
            # fp32 master + grads + adam moments; bf16 working copy is transient
            inv += [
                TensorInfo("params/body", body_params * 4, "param", divisible=True),
                TensorInfo("params/embed", embed_params * 4, "embed", divisible=True),
                TensorInfo("grads", N * 4, "param", offloadable=False),
                TensorInfo("opt/mu", N * 4, "opt_state", divisible=True),
                TensorInfo("opt/nu", N * 4, "opt_state", divisible=True),
                TensorInfo("activations", self._act_checkpoint_bytes(),
                           "activation", divisible=True),
            ]
        else:
            inv += [
                TensorInfo("params/body", body_params * 2, "param", divisible=True),
                TensorInfo("params/embed", embed_params * 2, "embed", divisible=True),
            ]
            kv = self._kv_bytes()
            if kv:
                inv.append(TensorInfo("kv_cache", kv, "kv_cache", divisible=True,
                                      traffic_multiplier=(
                                          2.0 if shape.kind != DECODE else 0.05)))
        return inv

    def _act_checkpoint_bytes(self) -> int:
        """Layer-boundary activations saved by the default remat policy."""
        cfg, shape = self.cfg, self.shape
        return (cfg.num_layers * shape.global_batch * shape.seq_len
                * cfg.d_model * 2)

    def _kv_bytes(self) -> int:
        cfg, shape = self.cfg, self.shape
        if cfg.family == "ssm":
            state = (cfg.num_layers * shape.global_batch * cfg.ssm_heads
                     * cfg.ssm_head_dim * cfg.ssm_state * 4)
            conv = (cfg.num_layers * shape.global_batch * (cfg.conv_width - 1)
                    * (cfg.d_inner + 2 * cfg.ssm_state) * 2)
            return state + conv
        if cfg.family == "hybrid":
            napps = max(1, cfg.num_layers // max(cfg.attn_every, 1))
            attn_kv = (napps * shape.global_batch * shape.seq_len
                       * 2 * cfg.num_kv_heads * cfg.head_dim * 2)
            state = (cfg.num_layers * shape.global_batch * cfg.ssm_heads
                     * cfg.ssm_head_dim * cfg.ssm_state * 4)
            return attn_kv + state
        layers = cfg.num_layers + (cfg.encoder_layers if cfg.family == "encdec" else 0)
        return (cfg.num_layers * shape.global_batch * shape.seq_len
                * 2 * cfg.num_kv_heads * cfg.head_dim * 2)

    def footprint_bytes(self) -> int:
        return sum(t.bytes for t in self.inventory())

    # ------------------------------------------------------------------
    # per-step global FLOPs / traffic
    # ------------------------------------------------------------------
    def flops(self) -> float:
        base = model_flops_for(self.cfg, self.shape)
        if self.shape.kind != DECODE and self.cfg.num_heads:
            # attention scores/values matmuls: 12·B·S²·H·hd per layer (fwd+bwd
            # for train ×3 of fwd), causal halves it
            cfg, shape = self.cfg, self.shape
            attn = (cfg.num_layers * shape.global_batch * shape.seq_len ** 2
                    * cfg.num_heads * cfg.head_dim * 2 * 2) / 2
            base += attn * (3.0 if self.shape.kind == TRAIN else 1.0)
        return base

    def hbm_bytes(self) -> float:
        """Global HBM traffic per step (rough, documented factors)."""
        cfg, shape = self.cfg, self.shape
        N = cfg.active_param_count()
        tokens = shape.tokens_per_step
        if shape.kind == TRAIN:
            # params bf16 read fwd+bwd, grads written+reduced, adam r/w fp32,
            # activations written+read once around each remat boundary
            return (cfg.param_count() * (2 * 2 + 4 + 16)
                    + self._act_checkpoint_bytes() * 3.0
                    + tokens * cfg.d_model * 2 * 8)
        if shape.kind == DECODE:
            return N * 2 + self._kv_bytes() * 1.0 + tokens * cfg.d_model * 2 * 4
        return (N * 2 + self._kv_bytes() * 2.0
                + tokens * cfg.d_model * 2 * 8)

    def collective_bytes_per_chip(self, n_chips: int) -> float:
        """Per-chip collective traffic/step under our sharding (DESIGN.md §5).

        Key scaling fact (the source of the paper's sub-linear classes): the
        FSDP all-gather *received bytes per chip* are the full bf16 layer
        weights regardless of chip count, so this term does NOT shrink as the
        slice grows — more chips → relatively more collective-bound."""
        cfg, shape = self.cfg, self.shape
        if n_chips <= 1:
            return 0.0
        N = cfg.param_count()
        frac = (n_chips - 1) / n_chips
        tokens_local = shape.tokens_per_step / n_chips
        if shape.kind == TRAIN:
            fsdp_ag = 2 * N * 2 * frac          # recv full bf16 params, fwd+bwd
            grad_rs = N * 4 * frac              # send fp32 grads
            tp_acts = tokens_local * cfg.d_model * 2 * 2 * cfg.num_layers
            return fsdp_ag + grad_rs + tp_acts
        # inference: weights resident; TP activation reductions only
        layers = cfg.num_layers + (cfg.encoder_layers if cfg.family == "encdec" else 0)
        return tokens_local * cfg.d_model * 2 * 2 * max(layers, 1)

    def collective_count(self) -> int:
        """Collectives on the critical path per step (latency floor)."""
        cfg, shape = self.cfg, self.shape
        per_layer = 4 if shape.kind == TRAIN else 2
        return max(1, per_layer * cfg.num_layers)

    # ------------------------------------------------------------------
    COLLECTIVE_LATENCY_S = 2.5e-6  # per-collective launch+sync latency

    def roofline_on(self, profile: SliceProfile, chip: ChipSpec = V5E,
                    plan: Optional[OffloadPlan] = None) -> RooflineTerms:
        n = profile.n_chips
        host_traffic = plan.host_traffic_per_step if plan else 0.0
        coll_pc = self.collective_bytes_per_chip(n)
        t_coll = coll_pc / chip.ici_bw
        if n > 1:  # latency floor: small workloads on big slices stall here
            t_coll += self.collective_count() * self.COLLECTIVE_LATENCY_S
        return RooflineTerms(
            t_compute=self.flops() / n / chip.peak_flops_bf16,
            t_memory=self.hbm_bytes() / n / chip.hbm_bw,
            t_collective=t_coll,
            t_host=host_traffic / profile.host_link_bw(chip),
            hlo_flops=self.flops() / n,
            hlo_bytes=self.hbm_bytes() / n,
            collective_bytes=coll_pc,
            host_bytes=host_traffic / n,
            model_flops=model_flops_for(self.cfg, self.shape),
            n_chips=n,
        )

    def plan_for(self, profile: SliceProfile, chip: ChipSpec = V5E) -> OffloadPlan:
        return plan_offload(self.inventory(), profile.hbm_bytes(chip),
                            host_budget=profile.host_dram_bytes(chip))

    # ------------------------------------------------------------------
    # twin-offload co-execution (compute shards eligible for the CPU side)
    # ------------------------------------------------------------------
    def twin_candidates(self, plan: OffloadPlan) -> List[TwinShard]:
        """Divisible compute-bearing shards whose *state already spilled* —
        running their consumer on the CPU replaces the state's link round
        trip with the (much smaller) operand/result exchange.

        Three shard kinds, per the twin-offload scheme:

        - ``opt_step`` (train): the Adam update over the spilled fraction of
          the moments. Removes the moments' round trip (``opt_state``
          traffic); adds fp32 grads down + updated master params up.
        - ``kv_tail`` (decode): attention over the spilled cold KV tail.
          Removes the tail gather; adds per-layer query/partial-output
          exchange.
        - ``moe_cold`` (MoE decode): cold-expert MLP where the spilled
          expert weights live. Removes the weight streaming; adds the
          routed tokens' activations both ways.

        ``cpu_fraction`` is a placeholder (1.0) here — ``plan_twin`` solves
        the actual split.
        """
        cfg, shape = self.cfg, self.shape
        inv = {t.name: t for t in self.inventory()}
        out: List[TwinShard] = []
        if shape.kind == TRAIN:
            spilled = sum(
                plan.spilled_fraction(n, inv[n].bytes) * inv[n].bytes
                for n in ("opt/mu", "opt/nu") if n in inv)
            if spilled > 0:
                # spilled moment bytes map to phi*N params (m+v = 8 bytes/param)
                n_params = spilled / 8.0
                out.append(TwinShard(
                    "opt_step", "opt_state", 1.0,
                    flops=ADAM_FLOPS_PER_PARAM * n_params,
                    cpu_bytes=ADAM_DRAM_BYTES_PER_PARAM * n_params,
                    link_bytes=8.0 * n_params,  # grads down + params up, fp32
                    link_bytes_saved=GROUP_TRAFFIC["opt_state"] * spilled))
        if shape.kind == DECODE and "kv_cache" in inv:
            t = inv["kv_cache"]
            frac = plan.spilled_fraction("kv_cache", t.bytes)
            if frac > 0:
                # bytes/step the decode step actually touches in the spilled
                # tail (the same sparse-access model behind the 0.05 link
                # multiplier) — host-side attention touches them from DRAM
                # instead of over the link
                gather = t.traffic_per_step * frac
                exchange = (shape.tokens_per_step * cfg.d_model * 2 * 2
                            * cfg.num_layers)
                out.append(TwinShard(
                    "kv_tail", "kv_cache", 1.0,
                    flops=KV_FLOPS_PER_ELEMENT * gather / 2.0,
                    cpu_bytes=gather,
                    link_bytes=float(exchange),
                    link_bytes_saved=gather))
        if shape.kind == DECODE and cfg.family == MOE:
            spilled = sum(
                plan.spilled_fraction(n, inv[n].bytes) * inv[n].bytes
                for n in ("params/body",) if n in inv)
            if spilled > 0:
                tokens = shape.tokens_per_step * MOE_COLD_TOKEN_FRACTION
                out.append(TwinShard(
                    "moe_cold", "param", 1.0,
                    flops=2.0 * (spilled / 2.0) * tokens,
                    cpu_bytes=spilled,
                    link_bytes=tokens * cfg.d_model * 2 * 2,
                    link_bytes_saved=GROUP_TRAFFIC["param"] * spilled))
        return out

    def twin_plan_for(self, profile: SliceProfile, chip: ChipSpec = V5E,
                      host: HostSpec = V5E_HOST, *,
                      max_cpu_fraction: float = 1.0
                      ) -> Optional[TwinOffloadPlan]:
        """Solved twin split for this workload on ``profile`` — ``None`` when
        the memory plan doesn't fit, nothing compute-bearing spilled, or
        the solver keeps every candidate at fraction zero (the plain path
        is already optimal, e.g. behind a coherence-scaled link)."""
        plan = self.plan_for(profile, chip)
        if not plan.fits:
            return None
        cands = self.twin_candidates(plan)
        if not cands:
            return None
        base = self.roofline_on(profile, chip, plan)
        gpu_floor = max(base.t_compute, base.t_memory, base.t_collective)
        twin = plan_twin(
            plan, cands, gpu_floor_s=gpu_floor,
            link_bw=profile.host_link_bw(chip), host=host,
            n_hosts=profile.n_hosts(chip),
            max_cpu_fraction=max_cpu_fraction)
        return twin if twin.shards else None

    def roofline_twin(self, profile: SliceProfile, twin: TwinOffloadPlan,
                      chip: ChipSpec = V5E) -> RooflineTerms:
        """Roofline terms for a twin rung: the GPU-side terms of the base
        plan with the host term re-priced at the split's residual link
        traffic (coherence-scaled) and the CPU service time added."""
        from dataclasses import replace as _replace
        base = self.roofline_on(profile, chip, twin.base)
        return _replace(base, t_host=twin.t_link, t_cpu=twin.t_cpu,
                        host_bytes=twin.link_traffic_per_step / profile.n_chips)
