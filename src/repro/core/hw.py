"""Hardware model: TPU v5e chip/host/pod constants.

These are the constants the roofline analysis, the offload planner, and the
power model all read from. Sources: assignment-provided roofline constants
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI); host-side figures follow
typical v5e host provisioning and are the TPU analogue of the paper's
Grace-Hopper CPU side (NVLink-C2C 450 GB/s there, PCIe-class ~32 GB/s/host
here — the ~30× weaker host link is the main quantitative assumption change,
see DESIGN.md §2/§7).

Power figures are synthetic calibrations to public v5e TDP-class numbers; the
paper's §V-B finding (partitions isolate compute/memory but NOT power
delivery) is reproduced structurally by the shared pod-level cap.
"""
from __future__ import annotations

from dataclasses import dataclass

GiB = 1024 ** 3


@dataclass(frozen=True)
class HostSpec:
    """CPU side of one host — the *compute* half of the offload tier.

    ``OffloadPlan`` only needs the host link and DRAM capacity (both on
    ``ChipSpec``); twin-offload co-execution (``core.offload.plan_twin``)
    additionally needs how fast the host can run the work it receives:
    aggregate CPU throughput, host memory bandwidth (optimizer math is
    memory-bound on CPU), and whether the chip-to-host link is
    cache-coherent. A coherent C2C link (the paper's Grace-Hopper story)
    moves cache lines instead of DMA granules, modeled as a flat
    multiplier on the effective link bandwidth.
    """
    name: str = "v5e-host"
    cpu_flops: float = 3.0e12               # FLOP/s per host (fp32 SIMD)
    dram_bw: float = 300e9                  # bytes/s per host (DDR channels)
    c2c_coherent: bool = False              # cache-coherent chip<->host link?
    c2c_scale: float = 8.0                  # link multiplier when coherent

    def effective_link_scale(self) -> float:
        return self.c2c_scale if self.c2c_coherent else 1.0


V5E_HOST = HostSpec()
# The paper's C2C configuration: same CPU, coherent link (NVLink-C2C-class).
V5E_HOST_C2C = HostSpec(name="v5e-host-c2c", c2c_coherent=True)


@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12         # FLOP/s per chip
    hbm_bytes: int = 16 * GiB               # HBM capacity per chip
    hbm_bw: float = 819e9                   # bytes/s per chip
    ici_bw_per_link: float = 50e9           # bytes/s per direction per link
    ici_links: int = 4                      # 2D torus: ±x, ±y
    # host side (the "CPU offload" tier)
    chips_per_host: int = 8
    host_dram_bytes: int = 512 * GiB        # per host
    host_link_bw: float = 32e9              # bytes/s per host (PCIe-class)
    # data-center network: each host carries one 100 GbE-class NIC onto the
    # cluster fabric. Cross-pod tenant migration (cluster/actions.py
    # MigrateAcrossPods) prices its save/restore volumes over this link —
    # the DCN NIC, not the PCIe host link, is the bottleneck of a
    # pod-to-pod move. Units: bytes/s per host.
    dcn_link_bw: float = 12.5e9             # bytes/s per host (100 GbE DCN)
    # power model (synthetic; labeled as such in all outputs)
    idle_watts: float = 60.0
    active_watts: float = 200.0             # chip at full utilization

    @property
    def host_link_bw_per_chip(self) -> float:
        return self.host_link_bw / self.chips_per_host

    @property
    def host_dram_per_chip(self) -> int:
        return self.host_dram_bytes // self.chips_per_host

    @property
    def ici_bw(self) -> float:
        """Aggregate injection bandwidth per chip."""
        return self.ici_bw_per_link * self.ici_links


@dataclass(frozen=True)
class PodSpec:
    chip: ChipSpec
    rows: int = 16
    cols: int = 16
    # shared power delivery: provisioned below sum-of-chip-max (the paper's
    # §V-B interference channel). 0.85 over-subscription factor.
    power_cap_fraction: float = 0.85

    @property
    def n_chips(self) -> int:
        return self.rows * self.cols

    @property
    def hbm_total(self) -> int:
        return self.n_chips * self.chip.hbm_bytes

    @property
    def peak_flops(self) -> float:
        return self.n_chips * self.chip.peak_flops_bf16

    @property
    def power_cap_watts(self) -> float:
        return self.power_cap_fraction * self.n_chips * self.chip.active_watts

    @property
    def n_hosts(self) -> int:
        return max(1, self.n_chips // self.chip.chips_per_host)

    @property
    def host_bw(self) -> float:
        """Aggregate host-link (PCIe-class) bandwidth of the pod, bytes/s —
        the price basis for in-pod migrations and checkpoint save/restore."""
        return self.n_hosts * self.chip.host_link_bw

    @property
    def dcn_bw(self) -> float:
        """Aggregate DCN bandwidth of the pod, bytes/s (``n_hosts`` NICs at
        ``chip.dcn_link_bw`` each; 32 hosts × 12.5 GB/s = 400 GB/s for the
        default 256-chip pod) — the price basis for cross-pod migration."""
        return self.n_hosts * self.chip.dcn_link_bw


V5E = ChipSpec()
V5E_POD = PodSpec(chip=V5E)
