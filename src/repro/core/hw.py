"""Hardware model: chip/host/pod constants for the modeled families.

These are the constants the roofline analysis, the offload planner, and the
power model all read from. Sources: assignment-provided roofline constants
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI); host-side figures follow
typical v5e host provisioning and are the TPU analogue of the paper's
Grace-Hopper CPU side (NVLink-C2C 450 GB/s there, PCIe-class ~32 GB/s/host
here — the ~30× weaker host link is the main quantitative assumption change,
see DESIGN.md §2/§7).

Power figures are synthetic calibrations to public TDP-class numbers; the
paper's §V-B finding (partitions isolate compute/memory but NOT power
delivery) is reproduced structurally by the shared pod-level cap.

Two chip families live here:

* ``V5E`` — the original TPU v5e family. One partition mode (``fixed``):
  the grid geometry and roofline constants never change at runtime.
* ``MI300X`` — an MI300-class reconfigurable part, modeled at XCD
  granularity (one grid cell = one XCD; eight XCDs = one package = one
  "host" aggregation unit). Its :class:`PartitionMode` table exposes the
  runtime-switchable compute modes (monolithic **SPX** vs per-XCD **CPX**,
  which gate slice granularity) and memory modes (**NPS1** vs **NPS4**
  quadrant interleave, which trade effective local HBM bandwidth against
  visible capacity). The per-mode deltas are *synthetic calibrations* to
  publicly reported MI300 partitioning effects — labeled as such, exactly
  like the power figures above — and flow into the roofline via
  :func:`effective_chip`.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Tuple

GiB = 1024 ** 3


@dataclass(frozen=True)
class HostSpec:
    """CPU side of one host — the *compute* half of the offload tier.

    ``OffloadPlan`` only needs the host link and DRAM capacity (both on
    ``ChipSpec``); twin-offload co-execution (``core.offload.plan_twin``)
    additionally needs how fast the host can run the work it receives:
    aggregate CPU throughput, host memory bandwidth (optimizer math is
    memory-bound on CPU), and whether the chip-to-host link is
    cache-coherent. A coherent C2C link (the paper's Grace-Hopper story)
    moves cache lines instead of DMA granules, modeled as a flat
    multiplier on the effective link bandwidth.
    """
    name: str = "v5e-host"
    cpu_flops: float = 3.0e12               # FLOP/s per host (fp32 SIMD)
    dram_bw: float = 300e9                  # bytes/s per host (DDR channels)
    c2c_coherent: bool = False              # cache-coherent chip<->host link?
    c2c_scale: float = 8.0                  # link multiplier when coherent

    def effective_link_scale(self) -> float:
        return self.c2c_scale if self.c2c_coherent else 1.0


V5E_HOST = HostSpec()
# The paper's C2C configuration: same CPU, coherent link (NVLink-C2C-class).
V5E_HOST_C2C = HostSpec(name="v5e-host-c2c", c2c_coherent=True)


@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12         # FLOP/s per chip
    hbm_bytes: int = 16 * GiB               # HBM capacity per chip
    hbm_bw: float = 819e9                   # bytes/s per chip
    ici_bw_per_link: float = 50e9           # bytes/s per direction per link
    ici_links: int = 4                      # 2D torus: ±x, ±y
    # host side (the "CPU offload" tier)
    chips_per_host: int = 8
    host_dram_bytes: int = 512 * GiB        # per host
    host_link_bw: float = 32e9              # bytes/s per host (PCIe-class)
    # data-center network: each host carries one 100 GbE-class NIC onto the
    # cluster fabric. Cross-pod tenant migration (cluster/actions.py
    # MigrateAcrossPods) prices its save/restore volumes over this link —
    # the DCN NIC, not the PCIe host link, is the bottleneck of a
    # pod-to-pod move. Units: bytes/s per host.
    dcn_link_bw: float = 12.5e9             # bytes/s per host (100 GbE DCN)
    # power model (synthetic; labeled as such in all outputs)
    idle_watts: float = 60.0
    active_watts: float = 200.0             # chip at full utilization

    @property
    def host_link_bw_per_chip(self) -> float:
        return self.host_link_bw / self.chips_per_host

    @property
    def host_dram_per_chip(self) -> int:
        return self.host_dram_bytes // self.chips_per_host

    @property
    def ici_bw(self) -> float:
        """Aggregate injection bandwidth per chip."""
        return self.ici_bw_per_link * self.ici_links


@dataclass(frozen=True)
class PodSpec:
    chip: ChipSpec
    rows: int = 16
    cols: int = 16
    # shared power delivery: provisioned below sum-of-chip-max (the paper's
    # §V-B interference channel). 0.85 over-subscription factor.
    power_cap_fraction: float = 0.85

    @property
    def n_chips(self) -> int:
        return self.rows * self.cols

    @property
    def hbm_total(self) -> int:
        return self.n_chips * self.chip.hbm_bytes

    @property
    def peak_flops(self) -> float:
        return self.n_chips * self.chip.peak_flops_bf16

    @property
    def power_cap_watts(self) -> float:
        return self.power_cap_fraction * self.n_chips * self.chip.active_watts

    @property
    def n_hosts(self) -> int:
        return max(1, self.n_chips // self.chip.chips_per_host)

    @property
    def host_bw(self) -> float:
        """Aggregate host-link (PCIe-class) bandwidth of the pod, bytes/s —
        the price basis for in-pod migrations and checkpoint save/restore."""
        return self.n_hosts * self.chip.host_link_bw

    @property
    def dcn_bw(self) -> float:
        """Aggregate DCN bandwidth of the pod, bytes/s (``n_hosts`` NICs at
        ``chip.dcn_link_bw`` each; 32 hosts × 12.5 GB/s = 400 GB/s for the
        default 256-chip pod) — the price basis for cross-pod migration."""
        return self.n_hosts * self.chip.dcn_link_bw


V5E = ChipSpec()
V5E_POD = PodSpec(chip=V5E)


# ---------------------------------------------------------------------------
# Partition modes (MI300-class SPX/CPX × NPS1/NPS4) + the chip registry
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PartitionMode:
    """One runtime-selectable partitioning of a reconfigurable chip.

    ``compute`` is the compute-partition axis (``"spx"`` — monolithic, the
    whole package is one scheduling unit; ``"cpx"`` — per-XCD). It gates
    *slice granularity*: ``min_slice_chips`` is the smallest rectangle (in
    grid cells) the partitioner may hand out in this mode, so an SPX pod
    only offers the coarse end of the profile ladder. ``memory`` is the
    NUMA-per-socket axis (``"nps1"`` — fully interleaved; ``"nps4"`` —
    quadrant): NPS4 raises effective *local* HBM bandwidth but shrinks the
    capacity visible to one partition. The three ``*_scale`` factors carry
    those deltas into the roofline terms via :func:`effective_chip`; all
    are synthetic calibrations (documented in docs/hardware.md).

    ``switch_downtime_s`` is the fixed wall-clock outage a mode switch
    costs on top of draining the pod — the price basis of the
    ``ReconfigurePartition`` cluster action.
    """
    name: str
    compute: str = "spx"            # "spx" | "cpx"
    memory: str = "nps1"            # "nps1" | "nps4"
    flops_scale: float = 1.0        # × peak FLOP/s per cell
    hbm_bw_scale: float = 1.0       # × effective HBM bytes/s per cell
    hbm_capacity_scale: float = 1.0  # × visible HBM bytes per cell
    min_slice_chips: int = 1        # granularity floor (grid cells)
    switch_downtime_s: float = 30.0

    @property
    def is_identity(self) -> bool:
        """True when the mode leaves the roofline constants untouched."""
        return (self.flops_scale == 1.0 and self.hbm_bw_scale == 1.0
                and self.hbm_capacity_scale == 1.0)


# The v5e is not reconfigurable: one identity mode, zero-cost by construction
# (there is never another mode to switch to).
FIXED_MODE = PartitionMode(name="fixed")

# MI300-class part at XCD granularity: one grid cell = one XCD, eight XCDs =
# one package. Package-level public figures (~1.3 PFLOP/s bf16, 192 GB HBM3,
# 5.3 TB/s) divided by eight; host side is the package's PCIe Gen5-class
# attach. Power is synthetic (750 W-class package / 8).
MI300X = ChipSpec(
    name="mi300x",
    peak_flops_bf16=163e12,         # per XCD (~1.3 PF / 8)
    hbm_bytes=24 * GiB,             # per XCD (192 GB / 8)
    hbm_bw=663e9,                   # per XCD (5.3 TB/s / 8)
    ici_bw_per_link=64e9,           # Infinity-Fabric-class
    ici_links=4,
    chips_per_host=8,               # one package per host unit
    host_dram_bytes=768 * GiB,
    host_link_bw=64e9,              # PCIe Gen5 x16-class per package
    dcn_link_bw=12.5e9,
    idle_watts=12.0,
    active_watts=95.0,              # 750 W-class package / 8
)

# Synthetic per-mode deltas (see docs/hardware.md for the calibration
# story and units). SPX schedules whole packages → the granularity floor
# is 64 cells (an 8×8 rectangle, eight packages); CPX exposes every XCD.
# NPS4 quadrant interleave: +30% effective local bandwidth, 75% visible
# capacity. CPX adds a small locality bonus to per-cell peak FLOP/s.
MI300_MODES: Dict[str, PartitionMode] = {
    "spx-nps1": PartitionMode(
        name="spx-nps1", compute="spx", memory="nps1", min_slice_chips=64),
    "spx-nps4": PartitionMode(
        name="spx-nps4", compute="spx", memory="nps4", hbm_bw_scale=1.30,
        hbm_capacity_scale=0.75, min_slice_chips=64),
    "cpx-nps1": PartitionMode(
        name="cpx-nps1", compute="cpx", memory="nps1", flops_scale=1.05),
    "cpx-nps4": PartitionMode(
        name="cpx-nps4", compute="cpx", memory="nps4", flops_scale=1.05,
        hbm_bw_scale=1.30, hbm_capacity_scale=0.75),
}

MI300_POD = PodSpec(chip=MI300X)

# CLI-facing registry: alias → ChipSpec. ``get_chip`` is the one lookup the
# trace loader and launchers go through, so unknown names fail readably.
CHIPS: Dict[str, ChipSpec] = {"v5e": V5E, "mi300": MI300X}

_MODES_BY_CHIP: Dict[str, Dict[str, PartitionMode]] = {
    V5E.name: {"fixed": FIXED_MODE},
    MI300X.name: MI300_MODES,
}
_DEFAULT_MODE: Dict[str, str] = {V5E.name: "fixed", MI300X.name: "spx-nps1"}


def get_chip(name: str) -> ChipSpec:
    """Resolve a chip alias (``"v5e"``, ``"mi300"``) to its ChipSpec."""
    try:
        return CHIPS[name]
    except KeyError:
        raise ValueError(f"unknown chip {name!r}; valid: "
                         f"{sorted(CHIPS)}") from None


def partition_modes(chip: ChipSpec) -> Dict[str, PartitionMode]:
    """The mode table of ``chip`` (fixed-only for non-reconfigurable
    parts, including derived/effective chips)."""
    return dict(_MODES_BY_CHIP.get(chip.name, {"fixed": FIXED_MODE}))


def default_mode(chip: ChipSpec) -> str:
    """The mode a freshly built pod of ``chip`` boots in."""
    return _DEFAULT_MODE.get(chip.name, "fixed")


def get_mode(chip: ChipSpec, name: str) -> PartitionMode:
    """Resolve one mode of ``chip`` by name; unknown names fail readably."""
    modes = partition_modes(chip)
    try:
        return modes[name]
    except KeyError:
        raise ValueError(f"unknown partition mode {name!r} for chip "
                         f"{chip.name!r}; valid: {sorted(modes)}") from None


_EFFECTIVE: Dict[Tuple[ChipSpec, PartitionMode], ChipSpec] = {}


def effective_chip(base: ChipSpec, mode: PartitionMode) -> ChipSpec:
    """The ChipSpec the roofline actually sees under ``mode``.

    Identity modes return ``base`` itself (same object — every memo keyed
    on the chip stays bit-identical with the fixed-mode default). Scaling
    modes derive a frozen copy with the mode's deltas applied and the mode
    name folded into ``name`` — so every PerfModel memo, ``profile_key``,
    and ProbeCache signature downstream is automatically mode-keyed."""
    if mode.is_identity:
        return base
    key = (base, mode)
    eff = _EFFECTIVE.get(key)
    if eff is None:
        eff = _EFFECTIVE[key] = replace(
            base,
            name=f"{base.name}:{mode.name}",
            peak_flops_bf16=base.peak_flops_bf16 * mode.flops_scale,
            hbm_bw=base.hbm_bw * mode.hbm_bw_scale,
            hbm_bytes=int(base.hbm_bytes * mode.hbm_capacity_scale),
        )
    return eff


def ladder_for(mode: PartitionMode):
    """The slice-profile ladder available under ``mode`` — the full table
    filtered by the mode's granularity floor (smallest first, like
    ``PROFILES``)."""
    from repro.core.slices import PROFILES   # slices imports hw; keep lazy
    return tuple(p for p in PROFILES if p.n_chips >= mode.min_slice_chips)
