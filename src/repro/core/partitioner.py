"""StaticPartitioner — carve a pod's device grid into isolated sub-slices.

The TPU analogue of creating MIG GPU instances (paper §II-B3): each allocated
slice owns a disjoint rectangle of chips (disjoint ICI links → physical
isolation of compute, HBM and interconnect; only host links and pod power
delivery stay shared — exactly the residual interference surface the paper
identifies). Each slice exposes a ``jax.sharding.Mesh`` with ("data","model")
axes over its rectangle.

Also implements the *elastic repartitioning* used by the fault-tolerant
runner: on chip/host failure, the workload is re-admitted onto the largest
still-free profile and the offload planner re-plans for the smaller HBM pool.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hw import PodSpec, V5E_POD
from repro.core.slices import PROFILES, SliceProfile


@dataclass
class SliceAllocation:
    slice_id: int
    profile: SliceProfile
    origin: Tuple[int, int]          # (row, col) of the rectangle
    devices: Optional[np.ndarray]    # 2D array of device objects (or None)
    tag: str = ""

    @property
    def rect(self) -> Tuple[int, int, int, int]:
        r, c = self.origin
        return (r, c, r + self.profile.rows, c + self.profile.cols)

    def mesh(self, axis_names: Tuple[str, str] = ("data", "model")):
        """Build a jax Mesh over this slice's devices."""
        import jax
        from jax.sharding import Mesh
        assert self.devices is not None, "logical allocation has no devices"
        return Mesh(self.devices, axis_names)


_PROFILES_DESC = tuple(sorted(PROFILES, key=lambda p: -p.n_chips))


class StaticPartitioner:
    """Packs rectangular slices into the pod grid (first-fit, row-major).

    Free-rectangle index: every aligned-origin query (``origins_for``,
    ``largest_free_profile``, ``free_chips``, the placer's
    ``best_origin_for``) is answered from per-profile free-block bitmaps
    plus 2D prefix sums, rebuilt lazily when the grid generation counter
    moves — O(profiles) tiny numpy ops per mutation instead of an O(grid)
    rescan per probe. Anything that writes ``_grid`` from outside the
    class must call :meth:`mark_dirty`.
    """

    def __init__(self, pod: PodSpec = V5E_POD,
                 devices: Optional[Sequence] = None):
        self.pod = pod
        # the slice ladder this partitioner carves from — the full table by
        # default; a partition mode with a granularity floor installs a
        # filtered ladder via set_profiles() (MI300 SPX offers only the
        # coarse end). Index structures are derived from it, so a ladder
        # change is a grid mutation for caching purposes.
        self.profiles: Tuple[SliceProfile, ...] = PROFILES
        self._profiles_desc: Tuple[SliceProfile, ...] = _PROFILES_DESC
        self._grid = np.full((pod.rows, pod.cols), -1, dtype=np.int64)  # slice_id or -1
        self._next_id = 0
        self._gen = 0          # bumped on every grid mutation
        self._idx_gen = -1     # generation the cached index was built at
        self._idx: Optional[dict] = None
        self.allocations: Dict[int, SliceAllocation] = {}
        if devices is not None:
            devs = np.asarray(devices, dtype=object)
            if devs.size != pod.n_chips:
                raise ValueError(
                    f"need {pod.n_chips} devices for a {pod.rows}x{pod.cols} pod, "
                    f"got {devs.size}")
            self._devices = devs.reshape(pod.rows, pod.cols)
        else:
            self._devices = None

    # ------------------------------------------------------------------
    @property
    def generation(self) -> int:
        """Monotone grid-mutation counter. Every allocate/release/repack/
        extend/resize/fail/rollback bump moves it, so equal generations
        mean a bit-identical *free mask* — the structural validity token
        the scheduler's ``ProbeCache`` keys on. (Self-restoring probe
        trials re-stamp their starting value via ``restore_generation``,
        so generations identify the free structure, not slice ids.)"""
        return self._gen

    def restore_generation(self, gen: int) -> None:
        """Re-stamp ``generation`` after a self-restoring trial (release +
        re-allocate at the same origin) whose net effect on the free mask
        is nil. Only slice ids advanced, and nothing keyed on the
        generation reads ids: the free-rectangle index is derived from the
        free mask alone. A copy the trial rebuilt mid-flight must be
        dropped *eagerly* — re-stamping makes mid-trial generation values
        reusable, so a later trial could otherwise match a stale
        ``_idx_gen`` against a different grid. An index built at ``gen``
        itself (before the trial) stays valid: the free mask is back.
        Never call this after a mutation that changes which chips are
        free — that would serve stale index/cache entries."""
        if self._idx_gen > gen:
            self._idx_gen = -1
            self._idx = None
        self._gen = gen

    def mark_dirty(self) -> None:
        """Invalidate the free-rectangle index after external grid surgery
        (transaction rollback writes ``_grid`` wholesale, ``fail_chips``
        kills cells, a mode switch swaps the ladder). The cached ``_idx``
        is dropped *eagerly*, not just generation-bumped: a later
        ``restore_generation`` may re-stamp an older generation value, and
        a lazily retained index built after this mutation could then match
        that re-stamped generation against a different grid."""
        self._gen += 1
        self._idx = None
        self._idx_gen = -1

    def set_profiles(self, profiles: Sequence[SliceProfile]) -> None:
        """Install the slice ladder of a new partition mode and re-derive
        every ladder-ordered structure (descending scan order, the lazy
        free-rectangle index). A no-op ladder still counts as a mutation —
        callers switch modes, and mode identity lives above us."""
        self.profiles = tuple(profiles)
        self._profiles_desc = tuple(
            sorted(self.profiles, key=lambda p: -p.n_chips))
        self.mark_dirty()

    def _index(self) -> dict:
        """The free-rectangle index for the current grid generation,
        filled lazily per component: the free-cell count, and per profile
        a free-block bitmap (a block = one aligned candidate rectangle),
        its count, a 2D prefix sum (so "free blocks inside a block span"
        is O(1)), and the materialized origin list. Every entry is built
        on first use after a mutation — a drain-gate free-chip query never
        pays for placement-grade structures."""
        if self._idx_gen != self._gen or self._idx is None:
            self._idx = {"free": None, "free_mask": None, "blocks": {},
                         "counts": {}, "prefix": {}, "origins": {},
                         "best": {}, "largest": -1, "frag": None}
            self._idx_gen = self._gen
        return self._idx

    def _free_mask(self, idx: dict) -> np.ndarray:
        mask = idx["free_mask"]
        if mask is None:
            mask = idx["free_mask"] = self._grid == -1
        return mask

    def _blocks(self, idx: dict, profile: SliceProfile) -> list:
        """Free-block bitmap for ``profile`` as nested Python lists (the
        per-origin lookups below are scalar; list indexing beats numpy)."""
        B = idx["blocks"].get(profile.name)
        if B is None:
            a, b = profile.rows, profile.cols
            n_br = self.pod.rows // a
            n_bc = self.pod.cols // b
            if n_br and n_bc:
                arr = self._free_mask(idx)[:n_br * a, :n_bc * b].reshape(
                    n_br, a, n_bc, b).all(axis=(1, 3))
                idx["counts"][profile.name] = int(arr.sum())
                B = arr.tolist()
            else:
                idx["counts"][profile.name] = 0
                B = [[False] * n_bc for _ in range(n_br)]
            idx["blocks"][profile.name] = B
        return B

    def _prefix(self, idx: dict, profile: SliceProfile) -> list:
        """2D prefix sums of the free-block bitmap, as nested lists:
        ``P[i][j]`` = free blocks in ``B[:i, :j]``."""
        P = idx["prefix"].get(profile.name)
        if P is None:
            B = self._blocks(idx, profile)
            n_br = len(B)
            n_bc = len(B[0]) if n_br else 0
            P = [[0] * (n_bc + 1)]
            for i in range(n_br):
                row = [0]
                above = P[i]
                acc = 0
                Bi = B[i]
                for j in range(n_bc):
                    acc += Bi[j]
                    row.append(above[j + 1] + acc)
                P.append(row)
            idx["prefix"][profile.name] = P
        return P

    def origins_for(self, profile: SliceProfile) -> List[Tuple[int, int]]:
        """Every free origin for ``profile`` on the alignment grid (origins
        at multiples of the slice side — keeps packing fragmentation-free
        for power-of-two profiles), in row-major order. The candidate set a
        fragmentation-aware placer scores instead of taking first-fit's
        first hit."""
        idx = self._index()
        cached = idx["origins"].get(profile.name)
        if cached is None:
            B = self._blocks(idx, profile)
            a, b = profile.rows, profile.cols
            cached = [(i * a, j * b)
                      for i, row in enumerate(B)
                      for j, freeb in enumerate(row) if freeb]
            idx["origins"][profile.name] = cached
        return list(cached)

    def _find_origin(self, profile: SliceProfile) -> Optional[Tuple[int, int]]:
        """First-fit: the first free aligned origin, if any."""
        origins = self.origins_for(profile)
        return origins[0] if origins else None

    def allocate(self, profile: SliceProfile, tag: str = "",
                 origin: Optional[Tuple[int, int]] = None) -> SliceAllocation:
        if origin is not None:
            r, c = origin
            if r % profile.rows or c % profile.cols:
                raise ValueError(
                    f"origin {origin} not aligned for {profile.name} "
                    f"(must be multiples of {profile.rows}x{profile.cols})")
            if (r + profile.rows > self.pod.rows
                    or c + profile.cols > self.pod.cols
                    or not (self._grid[r:r + profile.rows,
                                       c:c + profile.cols] == -1).all()):
                raise RuntimeError(
                    f"origin {origin} not free for profile {profile.name}")
        else:
            origin = self._find_origin(profile)
        if origin is None:
            raise RuntimeError(f"no room for profile {profile.name} "
                               f"(free chips: {self.free_chips()})")
        sid = self._next_id
        self._next_id += 1
        r, c = origin
        self._grid[r:r + profile.rows, c:c + profile.cols] = sid
        self._gen += 1
        devs = (self._devices[r:r + profile.rows, c:c + profile.cols]
                if self._devices is not None else None)
        alloc = SliceAllocation(sid, profile, origin, devs, tag)
        self.allocations[sid] = alloc
        return alloc

    def release(self, slice_id: int) -> None:
        alloc = self.allocations.pop(slice_id)
        r, c, r2, c2 = alloc.rect
        self._grid[r:r2, c:c2] = -1
        self._gen += 1

    # ------------------------------------------------------------------
    def free_chips(self) -> int:
        idx = self._index()
        if idx["free"] is None:
            idx["free"] = int(self._free_mask(idx).sum())
        return idx["free"]

    def used_chips(self) -> int:
        return self.pod.n_chips - self.free_chips()

    def utilization(self) -> float:
        return self.used_chips() / self.pod.n_chips

    def validate(self) -> None:
        """Invariants: disjoint rectangles exactly covering their grid marks."""
        seen = np.full_like(self._grid, -1)
        for sid, a in self.allocations.items():
            r, c, r2, c2 = a.rect
            region = self._grid[r:r2, c:c2]
            if not (region == sid).all():
                raise AssertionError(f"slice {sid} region corrupted")
            if not (seen[r:r2, c:c2] == -1).all():
                raise AssertionError(f"slice {sid} overlaps another")
            seen[r:r2, c:c2] = sid
        marked = {int(s) for s in np.unique(self._grid) if s >= 0}
        if marked != set(self.allocations):
            raise AssertionError("grid marks do not match allocation table")

    # ------------------------------------------------------------------
    def fail_chips(self, chips: List[Tuple[int, int]]) -> List[int]:
        """Mark chips dead; returns slice_ids of affected allocations (which
        are released — the fault runner re-admits them elsewhere)."""
        affected = set()
        for (r, c) in chips:
            sid = int(self._grid[r, c])
            if sid >= 0:
                affected.add(sid)
        for sid in affected:
            self.release(sid)
        for (r, c) in chips:
            self._grid[r, c] = -2  # dead
        # Route through mark_dirty(), not a bare generation bump: killing
        # cells permanently changes the free mask, so the lazy index must
        # be dropped eagerly (see mark_dirty) and the generation move must
        # invalidate every ProbeCache entry keyed on the old value.
        self.mark_dirty()
        return sorted(affected)

    def largest_free_profile(self) -> Optional[SliceProfile]:
        idx = self._index()
        cached = idx["largest"]
        if cached == -1:
            cached = None
            for p in self._profiles_desc:
                self._blocks(idx, p)
                if idx["counts"][p.name]:
                    cached = p
                    break
            idx["largest"] = cached
        return cached

    def largest_free_profile_if(self, profile: SliceProfile,
                                origin: Tuple[int, int]
                                ) -> Optional[SliceProfile]:
        """Largest profile still placeable *after* hypothetically placing
        ``profile`` at ``origin`` — the look-ahead a fragmentation-aware
        placer ranks candidate origins by (arXiv 2512.16099's stranding
        metric). Answered from the free-rectangle index without touching
        the grid: a candidate block survives the hypothetical placement
        iff it is free now and disjoint from the probed rectangle, so the
        survivor count is (free blocks) − (free blocks inside the probed
        rectangle's block span), one prefix-sum lookup per profile."""
        idx = self._index()
        r0, c0 = origin
        pa, pb = profile.rows, profile.cols
        if (r0 % pa == 0 and c0 % pb == 0
                and r0 + pa <= self.pod.rows and c0 + pb <= self.pod.cols):
            B = self._blocks(idx, profile)
            free_here = B[r0 // pa][c0 // pb]
        else:   # unaligned probe — not index-addressable, read the grid
            free_here = bool(
                (self._grid[r0:r0 + pa, c0:c0 + pb] == -1).all())
        if not free_here:
            raise RuntimeError(f"origin {origin} not free for {profile.name}")
        return self._largest_after(idx, profile, r0, c0)

    def _largest_after(self, idx: dict, profile: SliceProfile,
                       r0: int, c0: int) -> Optional[SliceProfile]:
        """Largest profile with a free block disjoint from the rectangle
        ``profile`` @ ``(r0, c0)`` — prefix-sum arithmetic, no grid
        writes: survivors = (free blocks) − (free blocks whose block span
        intersects the probed rectangle)."""
        r1 = r0 + profile.rows
        c1 = c0 + profile.cols
        for q in self._profiles_desc:
            self._blocks(idx, q)
            cnt = idx["counts"][q.name]
            if not cnt:
                continue
            qa, qb = q.rows, q.cols
            P = self._prefix(idx, q)
            n_br, n_bc = len(P) - 1, len(P[0]) - 1
            i0 = min(n_br, r0 // qa)
            i1 = min(n_br, -(-r1 // qa))
            j0 = min(n_bc, c0 // qb)
            j1 = min(n_bc, -(-c1 // qb))
            overlap = 0
            if i1 > i0 and j1 > j0:
                overlap = P[i1][j1] - P[i0][j1] - P[i1][j0] + P[i0][j0]
            if cnt - overlap > 0:
                return q
        return None

    def best_origin_for(self, profile: SliceProfile
                        ) -> Optional[Tuple[Tuple[int, int], int]]:
        """The fragmentation-aware placer's scored scan, answered from the
        index and memoized per grid generation: the first free origin (in
        row-major order) maximizing the chips of the largest profile still
        placeable afterwards. Returns ``((row, col), chips_after)`` or
        ``None`` when no aligned origin is free."""
        idx = self._index()
        key = profile.name
        if key in idx["best"]:
            return idx["best"][key]
        origins = self.origins_for(profile)
        if not origins:
            idx["best"][key] = None
            return None
        # Hoist the per-q structures out of the origin loop (each origin's
        # survivor test is then pure arithmetic on them), and stop at the
        # first origin preserving the largest currently-free profile —
        # survivors are a subset of the free blocks, so nothing later can
        # beat it, and the strictly-greater scan keeps the first max.
        pa, pb = profile.rows, profile.cols
        qinfo = []
        for q in self._profiles_desc:
            self._blocks(idx, q)
            cnt = idx["counts"][q.name]
            if cnt:
                qinfo.append((q.n_chips, q.rows, q.cols, cnt,
                              self._prefix(idx, q)))
        ceiling = qinfo[0][0] if qinfo else 0
        best = None
        for origin in origins:
            r0, c0 = origin
            r1 = r0 + pa
            c1 = c0 + pb
            chips = 0
            for n_chips, qa, qb, cnt, P in qinfo:
                n_br = len(P) - 1
                n_bc = len(P[0]) - 1
                i0 = min(n_br, r0 // qa)
                i1 = min(n_br, -(-r1 // qa))
                j0 = min(n_bc, c0 // qb)
                j1 = min(n_bc, -(-c1 // qb))
                overlap = 0
                if i1 > i0 and j1 > j0:
                    overlap = P[i1][j1] - P[i0][j1] - P[i1][j0] + P[i0][j0]
                if cnt - overlap > 0:
                    chips = n_chips
                    break
            if best is None or chips > best[1]:
                best = (origin, chips)
                if chips == ceiling:
                    break
        idx["best"][key] = best
        return best

    def fragmentation_ratio(self) -> float:
        """How far the largest placeable profile falls short of what the
        free chip *count* promises: ``1 - placeable / promised`` where
        ``promised`` is the biggest profile with ``n_chips <= free``. 0 on
        an empty or compactly packed grid (where the count keeps its
        promise), 0.5 in the showcase stranding state (128 chips free, but
        only an 8×8 placeable)."""
        idx = self._index()
        cached = idx["frag"]
        if cached is not None:
            return cached
        free = self.free_chips()
        promised = max((p.n_chips for p in self.profiles
                        if p.n_chips <= free), default=0)
        if promised == 0:
            ratio = 0.0
        else:
            largest = self.largest_free_profile()
            placeable = largest.n_chips if largest else 0
            ratio = max(0.0, 1.0 - placeable / promised)
        idx["frag"] = ratio
        return ratio

    def repack(self) -> Dict[int, Tuple[int, int]]:
        """Defragment: re-place every live allocation largest-first from a
        clean grid (dead chips stay dead). Long-lived multi-tenant runtimes
        interleave allocate/release, and first-fit on the alignment grid can
        strand free rectangles that no longer admit a large profile even
        though enough chips are free — the fragmentation problem of
        arXiv 2512.16099. Returns {slice_id: new_origin} for moved slices.

        Note: this moves *logical* rectangles; a real runtime would migrate
        the tenant's state between the old and new device sets.
        """
        old_grid = self._grid.copy()
        dead = self._grid == -2
        self._grid = np.full_like(self._grid, -1)
        self._grid[dead] = -2
        self._gen += 1
        placed: Dict[int, Tuple[int, int]] = {}
        for sid, alloc in sorted(self.allocations.items(),
                                 key=lambda kv: -kv[1].profile.n_chips):
            origin = self._find_origin(alloc.profile)
            if origin is None:
                self._grid = old_grid          # roll back, nothing was moved
                self._gen += 1
                raise RuntimeError(
                    f"repack failed: no room for live slice {sid} "
                    f"({alloc.profile.name}) — dead chips block every "
                    f"aligned origin")
            r, c = origin
            self._grid[r:r + alloc.profile.rows, c:c + alloc.profile.cols] = sid
            self._gen += 1
            placed[sid] = origin
        moved: Dict[int, Tuple[int, int]] = {}
        for sid, origin in placed.items():
            alloc = self.allocations[sid]
            if origin != alloc.origin:
                moved[sid] = origin
            alloc.origin = origin
            r, c = origin
            alloc.devices = (
                self._devices[r:r + alloc.profile.rows,
                              c:c + alloc.profile.cols]
                if self._devices is not None else None)
        self.validate()
        return moved

    def extend(self, slice_id: int, profile: SliceProfile) -> SliceAllocation:
        """Grow a live slice in place to a strictly larger ``profile`` —
        the rectangle-extension primitive behind the cluster scheduler's
        elastic-grow path (the symmetric move to its shrink).

        The slice keeps its ``slice_id``; its rectangle is extended to the
        aligned origin of ``profile`` that contains the current rectangle
        (power-of-two sides guarantee such an origin exists for any aligned
        slice). Every newly covered chip must currently be free — live
        neighbours are never displaced and dead chips are never absorbed.

        Transactional like ``repack()``: on any failure a ``RuntimeError``
        (or ``ValueError`` for a non-growing profile) is raised and the
        grid, the allocation table, and the allocation itself are exactly
        as before the call. Returns the updated allocation.
        """
        alloc = self.allocations[slice_id]
        old = alloc.profile
        if profile.rows < old.rows or profile.cols < old.cols \
                or profile.n_chips <= old.n_chips:
            raise ValueError(
                f"extend() only grows: {old.name} -> {profile.name} is not "
                f"a strict rectangle extension")
        r0, c0 = alloc.origin
        nr = (r0 // profile.rows) * profile.rows
        nc = (c0 // profile.cols) * profile.cols
        if nr + profile.rows > self.pod.rows or nc + profile.cols > self.pod.cols:
            raise RuntimeError(
                f"extend failed: {profile.name} at {(nr, nc)} exceeds the pod")
        region = self._grid[nr:nr + profile.rows, nc:nc + profile.cols]
        # every cell must be ours or free — no live neighbour, no dead chip
        if not ((region == slice_id) | (region == -1)).all():
            raise RuntimeError(
                f"extend failed: chips under {profile.name} at {(nr, nc)} "
                f"are not free (slice {slice_id} stays {old.name})")
        self._grid[nr:nr + profile.rows, nc:nc + profile.cols] = slice_id
        self._gen += 1
        alloc.profile = profile
        alloc.origin = (nr, nc)
        alloc.devices = (
            self._devices[nr:nr + profile.rows, nc:nc + profile.cols]
            if self._devices is not None else None)
        self.validate()
        return alloc

    def resize(self, slice_id: int, profile: SliceProfile) -> SliceAllocation:
        """Move a live slice to ``profile`` in place, keeping its
        ``slice_id`` — the one transaction primitive behind every elastic
        rectangle change (cluster ``Shrink``/``Grow`` actions, the serving
        runtime's ``resize_tenant``).

        Growing delegates to ``extend()`` (every newly covered chip must be
        free). Shrinking keeps the current origin: power-of-two profile
        sides make an origin aligned for a larger profile aligned for every
        smaller one, so the smaller rectangle always fits inside the old
        footprint and the trimmed chips free. Transactional: any failure
        raises and leaves the grid, the allocation table, and the
        allocation exactly as before the call.
        """
        alloc = self.allocations[slice_id]
        old = alloc.profile
        if profile is old or profile.name == old.name:
            return alloc
        if profile.rows >= old.rows and profile.cols >= old.cols:
            return self.extend(slice_id, profile)
        if profile.rows > old.rows or profile.cols > old.cols:
            raise ValueError(
                f"resize() needs comparable rectangles: {old.name} -> "
                f"{profile.name} neither grows nor shrinks both sides")
        r, c, r2, c2 = alloc.rect
        self._grid[r:r2, c:c2] = -1
        self._grid[r:r + profile.rows, c:c + profile.cols] = slice_id
        self._gen += 1
        alloc.profile = profile
        alloc.devices = (
            self._devices[r:r + profile.rows, c:c + profile.cols]
            if self._devices is not None else None)
        self.validate()
        return alloc

    def pack(self, demands: List[SliceProfile]) -> List[SliceAllocation]:
        """Allocate a list of profiles (largest first) — multi-tenant setup."""
        out = []
        for p in sorted(demands, key=lambda p: -p.n_chips):
            out.append(self.allocate(p))
        return out
