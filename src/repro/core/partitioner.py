"""StaticPartitioner — carve a pod's device grid into isolated sub-slices.

The TPU analogue of creating MIG GPU instances (paper §II-B3): each allocated
slice owns a disjoint rectangle of chips (disjoint ICI links → physical
isolation of compute, HBM and interconnect; only host links and pod power
delivery stay shared — exactly the residual interference surface the paper
identifies). Each slice exposes a ``jax.sharding.Mesh`` with ("data","model")
axes over its rectangle.

Also implements the *elastic repartitioning* used by the fault-tolerant
runner: on chip/host failure, the workload is re-admitted onto the largest
still-free profile and the offload planner re-plans for the smaller HBM pool.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hw import PodSpec, V5E_POD
from repro.core.slices import PROFILES, SliceProfile


@dataclass
class SliceAllocation:
    slice_id: int
    profile: SliceProfile
    origin: Tuple[int, int]          # (row, col) of the rectangle
    devices: Optional[np.ndarray]    # 2D array of device objects (or None)
    tag: str = ""

    @property
    def rect(self) -> Tuple[int, int, int, int]:
        r, c = self.origin
        return (r, c, r + self.profile.rows, c + self.profile.cols)

    def mesh(self, axis_names: Tuple[str, str] = ("data", "model")):
        """Build a jax Mesh over this slice's devices."""
        import jax
        from jax.sharding import Mesh
        assert self.devices is not None, "logical allocation has no devices"
        return Mesh(self.devices, axis_names)


class StaticPartitioner:
    """Packs rectangular slices into the pod grid (first-fit, row-major)."""

    def __init__(self, pod: PodSpec = V5E_POD,
                 devices: Optional[Sequence] = None):
        self.pod = pod
        self._grid = np.full((pod.rows, pod.cols), -1, dtype=np.int64)  # slice_id or -1
        self._next_id = 0
        self.allocations: Dict[int, SliceAllocation] = {}
        if devices is not None:
            devs = np.asarray(devices, dtype=object)
            if devs.size != pod.n_chips:
                raise ValueError(
                    f"need {pod.n_chips} devices for a {pod.rows}x{pod.cols} pod, "
                    f"got {devs.size}")
            self._devices = devs.reshape(pod.rows, pod.cols)
        else:
            self._devices = None

    # ------------------------------------------------------------------
    def origins_for(self, profile: SliceProfile) -> List[Tuple[int, int]]:
        """Every free origin for ``profile`` on the alignment grid (origins
        at multiples of the slice side — keeps packing fragmentation-free
        for power-of-two profiles), in row-major order. The candidate set a
        fragmentation-aware placer scores instead of taking first-fit's
        first hit."""
        out = []
        for r in range(0, self.pod.rows - profile.rows + 1, profile.rows):
            for c in range(0, self.pod.cols - profile.cols + 1, profile.cols):
                if (self._grid[r:r + profile.rows, c:c + profile.cols] == -1).all():
                    out.append((r, c))
        return out

    def _find_origin(self, profile: SliceProfile) -> Optional[Tuple[int, int]]:
        """First-fit: the first free aligned origin, if any."""
        origins = self.origins_for(profile)
        return origins[0] if origins else None

    def allocate(self, profile: SliceProfile, tag: str = "",
                 origin: Optional[Tuple[int, int]] = None) -> SliceAllocation:
        if origin is not None:
            r, c = origin
            if r % profile.rows or c % profile.cols:
                raise ValueError(
                    f"origin {origin} not aligned for {profile.name} "
                    f"(must be multiples of {profile.rows}x{profile.cols})")
            if (r + profile.rows > self.pod.rows
                    or c + profile.cols > self.pod.cols
                    or not (self._grid[r:r + profile.rows,
                                       c:c + profile.cols] == -1).all()):
                raise RuntimeError(
                    f"origin {origin} not free for profile {profile.name}")
        else:
            origin = self._find_origin(profile)
        if origin is None:
            raise RuntimeError(f"no room for profile {profile.name} "
                               f"(free chips: {self.free_chips()})")
        sid = self._next_id
        self._next_id += 1
        r, c = origin
        self._grid[r:r + profile.rows, c:c + profile.cols] = sid
        devs = (self._devices[r:r + profile.rows, c:c + profile.cols]
                if self._devices is not None else None)
        alloc = SliceAllocation(sid, profile, origin, devs, tag)
        self.allocations[sid] = alloc
        return alloc

    def release(self, slice_id: int) -> None:
        alloc = self.allocations.pop(slice_id)
        r, c, r2, c2 = alloc.rect
        self._grid[r:r2, c:c2] = -1

    # ------------------------------------------------------------------
    def free_chips(self) -> int:
        return int((self._grid == -1).sum())

    def used_chips(self) -> int:
        return self.pod.n_chips - self.free_chips()

    def utilization(self) -> float:
        return self.used_chips() / self.pod.n_chips

    def validate(self) -> None:
        """Invariants: disjoint rectangles exactly covering their grid marks."""
        seen = np.full_like(self._grid, -1)
        for sid, a in self.allocations.items():
            r, c, r2, c2 = a.rect
            region = self._grid[r:r2, c:c2]
            if not (region == sid).all():
                raise AssertionError(f"slice {sid} region corrupted")
            if not (seen[r:r2, c:c2] == -1).all():
                raise AssertionError(f"slice {sid} overlaps another")
            seen[r:r2, c:c2] = sid
        marked = {int(s) for s in np.unique(self._grid) if s >= 0}
        if marked != set(self.allocations):
            raise AssertionError("grid marks do not match allocation table")

    # ------------------------------------------------------------------
    def fail_chips(self, chips: List[Tuple[int, int]]) -> List[int]:
        """Mark chips dead; returns slice_ids of affected allocations (which
        are released — the fault runner re-admits them elsewhere)."""
        affected = set()
        for (r, c) in chips:
            sid = int(self._grid[r, c])
            if sid >= 0:
                affected.add(sid)
        for sid in affected:
            self.release(sid)
        for (r, c) in chips:
            self._grid[r, c] = -2  # dead
        return sorted(affected)

    def largest_free_profile(self) -> Optional[SliceProfile]:
        for p in sorted(PROFILES, key=lambda p: -p.n_chips):
            if self._find_origin(p) is not None:
                return p
        return None

    def largest_free_profile_if(self, profile: SliceProfile,
                                origin: Tuple[int, int]
                                ) -> Optional[SliceProfile]:
        """Largest profile still placeable *after* hypothetically placing
        ``profile`` at ``origin`` — the look-ahead a fragmentation-aware
        placer ranks candidate origins by (arXiv 2512.16099's stranding
        metric). The grid is restored before returning."""
        r, c = origin
        region = self._grid[r:r + profile.rows, c:c + profile.cols]
        if not (region == -1).all():
            raise RuntimeError(f"origin {origin} not free for {profile.name}")
        self._grid[r:r + profile.rows, c:c + profile.cols] = -3  # probe mark
        try:
            return self.largest_free_profile()
        finally:
            self._grid[r:r + profile.rows, c:c + profile.cols] = -1

    def fragmentation_ratio(self) -> float:
        """How far the largest placeable profile falls short of what the
        free chip *count* promises: ``1 - placeable / promised`` where
        ``promised`` is the biggest profile with ``n_chips <= free``. 0 on
        an empty or compactly packed grid (where the count keeps its
        promise), 0.5 in the showcase stranding state (128 chips free, but
        only an 8×8 placeable)."""
        free = self.free_chips()
        promised = max((p.n_chips for p in PROFILES if p.n_chips <= free),
                       default=0)
        if promised == 0:
            return 0.0
        largest = self.largest_free_profile()
        placeable = largest.n_chips if largest else 0
        return max(0.0, 1.0 - placeable / promised)

    def repack(self) -> Dict[int, Tuple[int, int]]:
        """Defragment: re-place every live allocation largest-first from a
        clean grid (dead chips stay dead). Long-lived multi-tenant runtimes
        interleave allocate/release, and first-fit on the alignment grid can
        strand free rectangles that no longer admit a large profile even
        though enough chips are free — the fragmentation problem of
        arXiv 2512.16099. Returns {slice_id: new_origin} for moved slices.

        Note: this moves *logical* rectangles; a real runtime would migrate
        the tenant's state between the old and new device sets.
        """
        old_grid = self._grid.copy()
        dead = self._grid == -2
        self._grid = np.full_like(self._grid, -1)
        self._grid[dead] = -2
        placed: Dict[int, Tuple[int, int]] = {}
        for sid, alloc in sorted(self.allocations.items(),
                                 key=lambda kv: -kv[1].profile.n_chips):
            origin = self._find_origin(alloc.profile)
            if origin is None:
                self._grid = old_grid          # roll back, nothing was moved
                raise RuntimeError(
                    f"repack failed: no room for live slice {sid} "
                    f"({alloc.profile.name}) — dead chips block every "
                    f"aligned origin")
            r, c = origin
            self._grid[r:r + alloc.profile.rows, c:c + alloc.profile.cols] = sid
            placed[sid] = origin
        moved: Dict[int, Tuple[int, int]] = {}
        for sid, origin in placed.items():
            alloc = self.allocations[sid]
            if origin != alloc.origin:
                moved[sid] = origin
            alloc.origin = origin
            r, c = origin
            alloc.devices = (
                self._devices[r:r + alloc.profile.rows,
                              c:c + alloc.profile.cols]
                if self._devices is not None else None)
        self.validate()
        return moved

    def extend(self, slice_id: int, profile: SliceProfile) -> SliceAllocation:
        """Grow a live slice in place to a strictly larger ``profile`` —
        the rectangle-extension primitive behind the cluster scheduler's
        elastic-grow path (the symmetric move to its shrink).

        The slice keeps its ``slice_id``; its rectangle is extended to the
        aligned origin of ``profile`` that contains the current rectangle
        (power-of-two sides guarantee such an origin exists for any aligned
        slice). Every newly covered chip must currently be free — live
        neighbours are never displaced and dead chips are never absorbed.

        Transactional like ``repack()``: on any failure a ``RuntimeError``
        (or ``ValueError`` for a non-growing profile) is raised and the
        grid, the allocation table, and the allocation itself are exactly
        as before the call. Returns the updated allocation.
        """
        alloc = self.allocations[slice_id]
        old = alloc.profile
        if profile.rows < old.rows or profile.cols < old.cols \
                or profile.n_chips <= old.n_chips:
            raise ValueError(
                f"extend() only grows: {old.name} -> {profile.name} is not "
                f"a strict rectangle extension")
        r0, c0 = alloc.origin
        nr = (r0 // profile.rows) * profile.rows
        nc = (c0 // profile.cols) * profile.cols
        if nr + profile.rows > self.pod.rows or nc + profile.cols > self.pod.cols:
            raise RuntimeError(
                f"extend failed: {profile.name} at {(nr, nc)} exceeds the pod")
        region = self._grid[nr:nr + profile.rows, nc:nc + profile.cols]
        # every cell must be ours or free — no live neighbour, no dead chip
        if not ((region == slice_id) | (region == -1)).all():
            raise RuntimeError(
                f"extend failed: chips under {profile.name} at {(nr, nc)} "
                f"are not free (slice {slice_id} stays {old.name})")
        self._grid[nr:nr + profile.rows, nc:nc + profile.cols] = slice_id
        alloc.profile = profile
        alloc.origin = (nr, nc)
        alloc.devices = (
            self._devices[nr:nr + profile.rows, nc:nc + profile.cols]
            if self._devices is not None else None)
        self.validate()
        return alloc

    def resize(self, slice_id: int, profile: SliceProfile) -> SliceAllocation:
        """Move a live slice to ``profile`` in place, keeping its
        ``slice_id`` — the one transaction primitive behind every elastic
        rectangle change (cluster ``Shrink``/``Grow`` actions, the serving
        runtime's ``resize_tenant``).

        Growing delegates to ``extend()`` (every newly covered chip must be
        free). Shrinking keeps the current origin: power-of-two profile
        sides make an origin aligned for a larger profile aligned for every
        smaller one, so the smaller rectangle always fits inside the old
        footprint and the trimmed chips free. Transactional: any failure
        raises and leaves the grid, the allocation table, and the
        allocation exactly as before the call.
        """
        alloc = self.allocations[slice_id]
        old = alloc.profile
        if profile is old or profile.name == old.name:
            return alloc
        if profile.rows >= old.rows and profile.cols >= old.cols:
            return self.extend(slice_id, profile)
        if profile.rows > old.rows or profile.cols > old.cols:
            raise ValueError(
                f"resize() needs comparable rectangles: {old.name} -> "
                f"{profile.name} neither grows nor shrinks both sides")
        r, c, r2, c2 = alloc.rect
        self._grid[r:r2, c:c2] = -1
        self._grid[r:r + profile.rows, c:c + profile.cols] = slice_id
        alloc.profile = profile
        alloc.devices = (
            self._devices[r:r + profile.rows, c:c + profile.cols]
            if self._devices is not None else None)
        self.validate()
        return alloc

    def pack(self, demands: List[SliceProfile]) -> List[SliceAllocation]:
        """Allocate a list of profiles (largest first) — multi-tenant setup."""
        out = []
        for p in sorted(demands, key=lambda p: -p.n_chips):
            out.append(self.allocate(p))
        return out
