"""Slice profiles — the TPU analogue of the paper's MIG profile table (Tab. II).

A *slice* is a contiguous rectangular sub-grid of the pod's 2D ICI mesh with
power-of-two sides. This is the real constraint TPU interconnects impose, and
it reproduces MIG's coarse doubling granularity from first principles: valid
slices on a 16×16 pod are 4×4, 4×8, 8×8, 8×16, 16×16 — each step doubles BOTH
compute and memory, exactly the coupled coarse-grained provisioning the paper
critiques (§IV-C). Compute and HBM cannot be scaled independently; the escape
hatch is the paper's contribution: host-memory offloading (core/offload.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.hw import ChipSpec, PodSpec, V5E_POD, GiB


@dataclass(frozen=True)
class SliceProfile:
    """One entry of the profile table."""
    name: str
    rows: int
    cols: int

    @property
    def n_chips(self) -> int:
        return self.rows * self.cols

    def max_instances(self, pod: PodSpec) -> int:
        return (pod.rows // self.rows) * (pod.cols // self.cols)

    def hbm_bytes(self, chip: ChipSpec) -> int:
        return self.n_chips * chip.hbm_bytes

    def peak_flops(self, chip: ChipSpec) -> float:
        return self.n_chips * chip.peak_flops_bf16

    def host_dram_bytes(self, chip: ChipSpec) -> int:
        return self.n_hosts(chip) * chip.host_dram_bytes

    def host_link_bw(self, chip: ChipSpec) -> float:
        return self.n_hosts(chip) * chip.host_link_bw

    def n_hosts(self, chip: ChipSpec) -> int:
        return max(1, self.n_chips // chip.chips_per_host)

    def mesh_shape(self) -> Tuple[int, int]:
        """(data, model) axis sizes for this slice's sub-mesh."""
        return (self.rows, self.cols)


# The profile table for a 16×16 v5e pod — names follow the MIG convention
# <compute-slices>s.<chips>c (1 compute slice = 16 chips = smallest rectangle).
PROFILES: Tuple[SliceProfile, ...] = (
    SliceProfile("1s.16c", 4, 4),
    SliceProfile("2s.32c", 4, 8),
    SliceProfile("4s.64c", 8, 8),
    SliceProfile("8s.128c", 8, 16),
    SliceProfile("16s.256c", 16, 16),
)
PROFILES_BY_NAME = {p.name: p for p in PROFILES}


def get_profile(name: str) -> SliceProfile:
    try:
        return PROFILES_BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown slice profile {name!r}; valid: "
                       f"{sorted(PROFILES_BY_NAME)}") from None


def profile_table(pod: PodSpec = V5E_POD) -> List[dict]:
    """The paper's Table II analogue: usable/wasted resources per profile."""
    rows = []
    for p in PROFILES:
        n = p.max_instances(pod)
        used = n * p.n_chips
        rows.append({
            "profile": p.name,
            "max_instances": n,
            "chips": p.n_chips,
            "hbm_gib": p.hbm_bytes(pod.chip) / GiB,
            "peak_tflops": p.peak_flops(pod.chip) / 1e12,
            "hosts": p.n_hosts(pod.chip),
            "host_dram_gib": p.host_dram_bytes(pod.chip) / GiB,
            "host_link_gbps": p.host_link_bw(pod.chip) / 1e9,
            "wasted_chips_pct": 100.0 * (pod.n_chips - used) / pod.n_chips,
        })
    return rows


def smallest_fitting(bytes_needed: int, flops_needed: float,
                     pod: PodSpec = V5E_POD) -> Optional[SliceProfile]:
    """Smallest profile whose HBM holds ``bytes_needed`` (paper §VI-A's
    'next larger profile' step — what offloading lets you avoid)."""
    for p in PROFILES:
        if p.hbm_bytes(pod.chip) >= bytes_needed:
            return p
    return None


def capacity_waste(bytes_needed: int, profile: SliceProfile,
                   pod: PodSpec = V5E_POD) -> float:
    """Fraction of the slice's HBM left unused by the workload."""
    cap = profile.hbm_bytes(pod.chip)
    return max(0.0, (cap - bytes_needed) / cap)
