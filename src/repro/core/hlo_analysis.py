"""Loop-aware analysis of partitioned, optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically on XLA:CPU), which under-counts scan-over-layers models by the
layer count. This analyzer parses the HLO text instead and:

  1. splits the module into computations and ops,
  2. recovers while-loop trip counts from the integer ``constant(N)`` in each
     loop's condition computation (lax.scan always emits a static bound),
  3. propagates execution multipliers through the call graph
     (entry ×1 → while body ×N → nested while ×N×M …),
  4. sums dot FLOPs (2 · |result| · |contraction|), per-op HBM traffic
     (operands + results of top-level fusions/dots/copies — post-fusion,
     operand/result sets ARE the HBM traffic), and collective bytes by op,
  5. keeps the top cost sites with their ``op_name`` metadata — pointing
     straight at the model source line for the perf loop.

Everything is per-device (the module is SPMD-partitioned).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*\))?\s*->[^{]*\{")


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'bf16[8,128]{1,0}' or '(s32[], f32[2,4])' -> [(dtype, dims), ...]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _shape_bytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _numel(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


@dataclass
class Op:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str
    raw_operands: str = ""

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.result_shapes)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    is_entry: bool = False


@dataclass
class CostSite:
    op_name: str          # model-level source (from metadata)
    kind: str             # "flops" | "bytes" | collective opcode
    value: float          # flops or bytes, multiplier applied
    computation: str
    multiplier: int


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)
    trip_counts: Dict[str, int] = field(default_factory=dict)
    top_flops_sites: List[CostSite] = field(default_factory=list)
    top_collective_sites: List[CostSite] = field(default_factory=list)
    top_bytes_sites: List[CostSite] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


# traffic-relevant opcodes (post-fusion, these touch HBM)
_TRAFFIC_OPS = {"fusion", "dot", "copy", "custom-call", "reduce", "transpose",
                "convolution", "dynamic-slice", "dynamic-update-slice",
                "gather", "scatter", "concatenate", "slice", "pad", "reverse",
                "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "iota", "broadcast", "select-and-scatter",
                "reduce-window", "sort", "convert", "cholesky",
                "triangular-solve"}
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "rng-bit-generator",
             "while", "conditional", "call"}


def parse_module(text: str) -> Tuple[Dict[str, Computation], Dict[str, Op]]:
    comps: Dict[str, Computation] = {}
    ops_by_name: Dict[str, Op] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw.rstrip())
        if not line:
            continue
        if not line.startswith(" "):
            m = _HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(1), is_entry=line.startswith("ENTRY"))
                comps[cur.name] = cur
                continue
            if line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, operand_str, attrs = m.groups()
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        op = Op(name, opcode, _parse_shapes(type_str), operands, attrs,
                raw_operands=operand_str)
        cur.ops.append(op)
        ops_by_name[name] = op
    return comps, ops_by_name


def _const_value(op: Op) -> Optional[int]:
    m = re.match(r"\s*(\d+)\s*$", op.raw_operands)
    return int(m.group(1)) if m else None


def analyze_hlo(text: str, top_k: int = 12) -> HloCost:
    comps, ops_by_name = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HloCost()

    # --- multipliers via call graph ---
    mult: Dict[str, int] = {c: 0 for c in comps}
    mult[entry.name] = 1
    fused_targets: set = set()  # register-resident computations (no HBM traffic)
    # topological-ish: iterate until stable (call graphs here are shallow)
    for _ in range(12):
        changed = False
        for comp in comps.values():
            m0 = mult.get(comp.name, 0)
            if m0 == 0:
                continue
            for op in comp.ops:
                if op.opcode == "while":
                    bm = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                    cm = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                    if not bm:
                        continue
                    body = bm.group(1)
                    trips = 1
                    if cm and cm.group(1) in comps:
                        trips = _trip_count_from_cond(comps[cm.group(1)])
                    new = m0 * trips
                    if mult.get(body, 0) < new:
                        mult[body] = new
                        changed = True
                    if cm and mult.get(cm.group(1), 0) < new:
                        mult[cm.group(1)] = new
                else:
                    for cal in re.findall(r"(?:calls|to_apply|branch_computations)="
                                          r"\{?%?([\w\.\-,%\s]+)\}?", op.attrs):
                        for target in re.findall(r"[\w\.\-]+", cal):
                            if target in comps:
                                fused_targets.add(target)
                                if mult.get(target, 0) < m0:
                                    mult[target] = m0
                                    changed = True
        if not changed:
            break

    cost = HloCost()
    cost.trip_counts = {c: m for c, m in mult.items() if m > 1}
    flops_sites: List[CostSite] = []
    coll_sites: List[CostSite] = []
    bytes_sites: List[CostSite] = []

    for comp in comps.values():
        m = mult.get(comp.name, 0)
        if m == 0:
            continue
        in_registers = comp.name in fused_targets  # fusion-internal ops
        for op in comp.ops:
            # ---- FLOPs from dots ----
            if op.opcode == "dot":
                lhs = ops_by_name.get(op.operands[0]) if op.operands else None
                contraction = 1
                cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
                if lhs is not None and cd and lhs.result_shapes:
                    dims = lhs.result_shapes[0][1]
                    for idx in (int(i) for i in cd.group(1).split(",") if i):
                        if idx < len(dims):
                            contraction *= dims[idx]
                f = 2.0 * _numel(op.result_shapes[0][1]) * contraction * m
                cost.flops += f
                meta = re.search(r'op_name="([^"]+)"', op.attrs)
                flops_sites.append(CostSite(
                    meta.group(1) if meta else op.name, "flops", f,
                    comp.name, m))
            # ---- collectives ----
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES:
                b = float(op.result_bytes) * m
                cost.collective_bytes[base] = cost.collective_bytes.get(base, 0.0) + b
                cost.collective_counts[base] = cost.collective_counts.get(base, 0) + m
                meta = re.search(r'op_name="([^"]+)"', op.attrs)
                coll_sites.append(CostSite(
                    meta.group(1) if meta else op.name, base, b, comp.name, m))
            # ---- HBM traffic (fusion-internal ops stay in registers) ----
            if op.opcode in _TRAFFIC_OPS and not in_registers:
                traffic = _op_traffic(op, ops_by_name, comps) * m
                cost.bytes_accessed += traffic
                if traffic > 0:
                    meta = re.search(r'op_name="([^"]+)"', op.attrs)
                    bytes_sites.append(CostSite(
                        meta.group(1) if meta else op.name, "bytes",
                        traffic, comp.name, m))

    flops_sites.sort(key=lambda s: -s.value)
    coll_sites.sort(key=lambda s: -s.value)
    bytes_sites.sort(key=lambda s: -s.value)
    cost.top_flops_sites = flops_sites[:top_k]
    cost.top_collective_sites = coll_sites[:top_k]
    cost.top_bytes_sites = bytes_sites[:top_k]
    return cost


_SLICING = ("dynamic-slice", "gather", "slice")


def _op_traffic(op: Op, ops_by_name: Dict[str, Op],
                comps: Dict[str, Computation]) -> float:
    """HBM bytes touched by one execution of a top-level op.

    Slicing ops read only their window; dynamic-update-slice writes only the
    update (XLA aliases the buffer in place). Fusions are analyzed through
    their called computation: a fusion PARAMETER consumed solely by slicing
    ops inside the fusion contributes the slice bytes, not the full buffer
    (this is exactly the scan-over-layers pattern: stacked (L, …) weights
    enter the loop body via dynamic-slice-in-fusion), and a fusion whose root
    is a dynamic-update-slice on a parameter (KV-cache append) contributes
    the update window, not the whole cache.
    """
    if op.opcode in _SLICING:
        return 2.0 * op.result_bytes
    if op.opcode in ("dynamic-update-slice", "scatter"):
        upd = sum(ops_by_name[o].result_bytes
                  for o in op.operands[1:2] if o in ops_by_name)
        return 2.0 * max(upd, 1)

    if op.opcode == "fusion":
        cm = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
        comp = comps.get(cm.group(1)) if cm else None
        if comp is not None:
            inner_by_name = {o.name: o for o in comp.ops}
            # parameter index -> inner op
            params: Dict[int, Op] = {}
            for o in comp.ops:
                if o.opcode == "parameter":
                    idx = _const_value(o)
                    if idx is not None:
                        params[idx] = o
            total = 0.0
            for i, operand_name in enumerate(op.operands):
                outer = ops_by_name.get(operand_name)
                if outer is None or outer.opcode == "constant":
                    continue
                pin = params.get(i)
                charged = None
                if pin is not None:
                    consumers = [o for o in comp.ops if pin.name in o.operands]
                    if consumers and all(o.opcode in _SLICING
                                         for o in consumers):
                        charged = sum(o.result_bytes for o in consumers)
                    elif consumers and all(
                            o.opcode == "dynamic-update-slice" and
                            o.operands and o.operands[0] == pin.name
                            for o in consumers):
                        charged = 0  # pure in-place destination
                total += charged if charged is not None else outer.result_bytes
            # result: in-place dus root writes only the update window
            root = comp.ops[-1] if comp.ops else None
            if root is not None and root.opcode == "dynamic-update-slice":
                upd = sum(inner_by_name[o].result_bytes
                          for o in root.operands[1:2] if o in inner_by_name)
                total += max(upd, 1)
            else:
                total += op.result_bytes
            return total

    operand_bytes = sum(
        ops_by_name[o].result_bytes for o in op.operands
        if o in ops_by_name and ops_by_name[o].opcode != "constant")
    return float(op.result_bytes + operand_bytes)


def _trip_count_from_cond(cond: Computation) -> int:
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            v = _const_value(op)
            if v is None:
                # constant value may sit in the operand parens position
                m = re.search(r"constant\((\d+)\)", op.attrs)
                v = int(m.group(1)) if m else None
            if v is not None and op.result_shapes and \
                    op.result_shapes[0][0].startswith(("s", "u")):
                best = max(best, v)
    return best
