"""The paper's reward metric (§VI-B) and configuration selector.

    R = (P / P_full) / (α + W_MEM + W_SM)

with compute waste W_SM → W_compute = (chips_slice/chips_pod)·(1 − U_c) and
memory waste W_MEM = (HBM_slice − resident)/HBM_pod. α ∈ [0,1] is the policy
knob: α = 0 prioritizes reducing underutilization, α → 1 prioritizes
performance (paper Fig. 8).

Performance P is the roofline-model step rate (1/step_time) — this container
has no TPU, so P is *estimated*, exactly as DESIGN.md §7(5) documents. The
selector sweeps every slice profile, with and without the offload plan, and
returns the argmax — reproducing the paper's "offload on the small slice vs
take the next slice up" decision procedure.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.hw import ChipSpec, PodSpec, V5E_POD
from repro.core.offload import OffloadPlan, estimated_step_slowdown
from repro.core.slices import PROFILES, SliceProfile
from repro.core.workload import WorkloadEstimate


@dataclass(frozen=True)
class RewardPoint:
    profile: SliceProfile
    plan: Optional[OffloadPlan]      # None -> no offloading used/needed
    fits: bool
    step_time: float                 # seconds (roofline estimate)
    perf_rel: float                  # P / P_full
    u_compute: float                 # roofline compute utilization on slice
    w_sm: float
    w_mem: float
    reward: float
    alpha: float

    @property
    def label(self) -> str:
        off = "+offload" if self.plan and self.plan.offloaded else ""
        return f"{self.profile.name}{off}"


def evaluate(wl: WorkloadEstimate, profile: SliceProfile, *, alpha: float,
             use_offload: bool, pod: PodSpec = V5E_POD,
             p_full: Optional[float] = None) -> Optional[RewardPoint]:
    chip = pod.chip
    inv_bytes = wl.footprint_bytes()
    hbm = profile.hbm_bytes(chip)
    plan: Optional[OffloadPlan] = None
    if inv_bytes > hbm:
        if not use_offload:
            return None  # does not fit without offloading
        plan = wl.plan_for(profile, chip)
        if not plan.fits:
            return None
    terms = wl.roofline_on(profile, chip, plan)
    step = terms.step_time
    resident = plan.resident_bytes if plan else inv_bytes
    u_c = terms.t_compute / step if step else 0.0
    w_sm = (profile.n_chips / pod.n_chips) * (1.0 - u_c)
    w_mem = max(0.0, (hbm - resident)) / pod.hbm_total
    if p_full is None:
        p_full = 1.0 / wl.roofline_on(PROFILES[-1], chip).step_time
    perf_rel = (1.0 / step) / p_full
    # ε-floor keeps R finite when a config achieves (near-)zero waste at α=0
    reward = perf_rel / max(alpha + w_mem + w_sm, 1e-3)
    return RewardPoint(profile, plan, True, step, perf_rel, u_c, w_sm, w_mem,
                       reward, alpha)


def sweep(wl: WorkloadEstimate, *, alpha: float, pod: PodSpec = V5E_POD
          ) -> List[RewardPoint]:
    """All feasible (profile × {plain, +offload}) points, best reward first."""
    p_full = 1.0 / wl.roofline_on(PROFILES[-1], pod.chip).step_time
    pts: List[RewardPoint] = []
    for prof in PROFILES:
        plain = evaluate(wl, prof, alpha=alpha, use_offload=False, pod=pod,
                         p_full=p_full)
        if plain is not None:
            pts.append(plain)
        else:
            off = evaluate(wl, prof, alpha=alpha, use_offload=True, pod=pod,
                           p_full=p_full)
            if off is not None:
                pts.append(off)
    return sorted(pts, key=lambda p: -p.reward)


def select(wl: WorkloadEstimate, *, alpha: float, pod: PodSpec = V5E_POD
           ) -> Optional[RewardPoint]:
    pts = sweep(wl, alpha=alpha, pod=pod)
    return pts[0] if pts else None
