"""PerfModel + PodSimulator — the one performance engine under the planner,
the cluster scheduler, and the serving runtime.

Before this module existed, four consumers (cluster placement, the cluster
scheduler, ``core/cosched.py``, ``serving/runtime.py``) each glued
``WorkloadEstimate.roofline_on`` to ``core.power.throttle_factor`` by hand,
and the scheduler froze every job's duration at admission time. The paper's
§V-B point is exactly that this is wrong: static slices isolate compute and
memory but share the pod power cap, so a job's *effective* speed changes
every time the tenant mix changes. MISO (arXiv 2207.11428) re-probes
placements as load shifts, and online MIG scheduling (arXiv 2512.16099)
prices reconfiguration against current progress — both need a performance
model that can be re-solved mid-flight.

Two layers:

* ``PerfModel`` — memoized (config × shape × profile) scoring: offload plan
  for fit, roofline terms for speed, power-throttle/co-run wrappers for the
  shared-cap surface. Optionally calibrated by *measured* anchors from the
  dry-run HLO artifacts (``benchmarks/roofline.py`` reads the same files):
  an anchor's compiled per-chip FLOPs/bytes rescale the analytic compute and
  memory terms for that (arch, shape) at every profile.
* ``PodSimulator`` — a progress-based execution engine. Jobs carry
  ``work_done / work_total``; every admission, completion, resize, or delay
  re-solves the pod throttle for the new mix and re-projects every remaining
  finish time. ``frozen=True`` reproduces the legacy fixed-at-admission
  durations bit-for-bit (same float expressions, same summation order), so
  the PR 2 scheduler numbers stay exactly reproducible.
"""
from __future__ import annotations

import json
import os
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.configs import get_config, get_shape
from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSuite
from repro.core.hw import ChipSpec, PodSpec, V5E, V5E_POD
from repro.core.offload import OffloadPlan, TwinOffloadPlan, TwinSpec
from repro.core.power import (InstanceLoad, co_run, pod_draw, serial_run,
                              throttle_factor)
from repro.core.roofline import RooflineTerms
from repro.core.slices import PROFILES, SliceProfile, get_profile
from repro.core.workload import WorkloadEstimate


# ---------------------------------------------------------------------------
# measured anchors (dry-run HLO artifacts)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Anchor:
    """Measured-from-HLO per-chip counts for one compiled (arch, shape)."""
    arch: str
    shape: str
    n_chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    step_time_s: float

    @property
    def flops_global(self) -> float:
        return self.hlo_flops_per_chip * self.n_chips

    @property
    def bytes_global(self) -> float:
        return self.hlo_bytes_per_chip * self.n_chips


def load_anchors(artifact_dir: str, mesh: str = "single"
                 ) -> Dict[Tuple[str, str], Anchor]:
    """Read ``<artifact_dir>/<mesh>/arch__shape.json`` dry-run records (the
    files ``benchmarks/roofline.py`` tabulates) into calibration anchors.
    Missing directory → no anchors; skipped/failed cells are ignored."""
    d = os.path.join(artifact_dir, mesh)
    anchors: Dict[Tuple[str, str], Anchor] = {}
    if not os.path.isdir(d):
        return anchors
    for f in sorted(os.listdir(d)):
        if not f.endswith(".json") or f.count("__") != 1:
            continue
        with open(os.path.join(d, f)) as fh:
            rec = json.load(fh)
        if rec.get("skipped") or rec.get("error") or "roofline" not in rec:
            continue
        r = rec["roofline"]
        anchors[(rec["arch"], rec["shape"])] = Anchor(
            arch=rec["arch"], shape=rec["shape"],
            n_chips=int(r["n_chips"]),
            hlo_flops_per_chip=float(r["hlo_flops_per_chip"]),
            hlo_bytes_per_chip=float(r["hlo_bytes_per_chip"]),
            step_time_s=float(r["step_time_s"]))
    return anchors


# ---------------------------------------------------------------------------
# PerfModel
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PerfScore:
    """One scored (workload × profile) point — everything a consumer needs
    to place, admit, or account a job without re-touching the roofline."""
    profile: SliceProfile
    plan: OffloadPlan
    terms: RooflineTerms
    step_time: float
    u_compute: float           # compute share of the step (power-model util)
    perf_per_chip: float       # (1/step)/n_chips — the MISO ranking score
    calibrated: bool = False   # True when a measured anchor rescaled terms
    # twin-offload rung: the solved CPU co-execution split behind this
    # score's terms; None for every plain (GPU-only) score
    twin: Optional[TwinOffloadPlan] = None

    @property
    def rung(self) -> str:
        """Display/cache identity of this elastic rung: the profile name,
        suffixed with the CPU fraction for twin rungs (``4s.64c+cpu0.60``).
        Probe caches key on this instead of ``profile.name`` so a twin and a
        plain score on the same rectangle never collide."""
        if self.twin is None:
            return self.profile.name
        return f"{self.profile.name}+cpu{self.twin.cpu_fraction:.2f}"

    def load(self, steps: int = 1) -> InstanceLoad:
        return InstanceLoad(self.profile.n_chips, self.u_compute,
                            self.step_time, steps)


@dataclass(frozen=True)
class CheckpointCost:
    """Priced suspend/resume of one tenant's resident state.

    Models what ``train/checkpoint.py`` actually moves: ``save`` host-
    gathers every resident leaf over the slice's host links (device →
    host DRAM, then disk — the link is the bottleneck at PCIe-class
    bandwidth), ``restore`` streams the same bytes back and
    ``device_put``s them onto the resuming slice (possibly a different
    one — elastic restart). Units: ``bytes`` in bytes, ``save_s`` /
    ``restore_s`` in wall-clock seconds over the given link bandwidth."""
    bytes: int
    save_s: float
    restore_s: float

    @property
    def total_s(self) -> float:
        return self.save_s + self.restore_s


@dataclass(frozen=True)
class CoRunSummary:
    """Shared-power-cap account of one concurrent mix (paper Figs. 5-7)."""
    throttle: float
    throttled: bool
    makespan_s: float
    energy_J: float
    effective_times: Tuple[float, ...]


class PerfModel:
    """Memoized workload → profile → plan scoring over the analytic model,
    optionally calibrated by measured dry-run anchors."""

    _MAX_JOB_MEMO = 4096   # matches the old feasible_options lru_cache bound

    def __init__(self, chip: ChipSpec = V5E,
                 anchors: Optional[Dict[Tuple[str, str], Anchor]] = None,
                 twin: Optional[TwinSpec] = None,
                 profiles: Optional[Sequence[SliceProfile]] = None):
        self.chip = chip
        self.anchors = dict(anchors) if anchors else {}
        # default-off twin-offload rungs: a TwinSpec turns on CPU
        # co-execution scoring (score_twin / extra options rows)
        self.twin = twin
        # the slice ladder this model scores over — partition modes with a
        # granularity floor (MI300 SPX) pass a filtered ladder; the default
        # is the full table, and a full ladder is normalized back to the
        # module constant so the default identity (and every pin keyed on
        # it) is untouched
        self.profiles: Tuple[SliceProfile, ...] = (
            PROFILES if profiles is None or tuple(profiles) == PROFILES
            else tuple(profiles))
        # scoring-identity token: two models with the same chip and the
        # same anchor set price every (workload, profile) identically, so
        # probe caches keyed on this never leak scores across an
        # anchored/analytic (or cross-chip) model swap; twin enablement is
        # part of the identity for the same reason (same token as before
        # when twin is off, so existing pins are untouched). A gated
        # ladder is part of the identity too: two modes sharing a chip
        # name but differing in granularity floor must not share probes.
        self.profile_key: Tuple = (chip.name, tuple(sorted(self.anchors)))
        if self.profiles is not PROFILES:
            self.profile_key += (
                ("ladder",) + tuple(p.name for p in self.profiles),)
        if twin is not None:
            self.profile_key += (("twin", twin.host.name,
                                  twin.host.c2c_coherent, twin.min_speedup,
                                  twin.max_cpu_fraction),)
        self._workloads: Dict[tuple, WorkloadEstimate] = {}
        self._scores: Dict[tuple, Optional[PerfScore]] = {}
        self._options: Dict[tuple, Tuple[PerfScore, ...]] = {}
        self._slo: "OrderedDict[object, tuple]" = OrderedDict()

    @classmethod
    def from_artifacts(cls, artifact_dir: str, mesh: str = "single",
                       chip: ChipSpec = V5E) -> "PerfModel":
        return cls(chip=chip, anchors=load_anchors(artifact_dir, mesh))

    # -- workload layer -------------------------------------------------
    def workload(self, cfg: ModelConfig, shape: ShapeSuite) -> WorkloadEstimate:
        key = (cfg, shape)
        wl = self._workloads.get(key)
        if wl is None:
            wl = self._workloads[key] = WorkloadEstimate(cfg, shape)
        return wl

    # -- calibration ----------------------------------------------------
    def _calibration(self, wl: WorkloadEstimate) -> Tuple[float, float]:
        """(flops_scale, bytes_scale) from a measured anchor, or (1, 1).

        The anchor's compiled global FLOPs/bytes over the analytic ones —
        compile-time realities (remat recompute, padding, fused transposes)
        the closed forms can't see. The ratio is profile-independent, so one
        anchored mesh calibrates every slice size of that (arch, shape)."""
        a = self.anchors.get((wl.cfg.name, wl.shape.name))
        if a is None:
            return 1.0, 1.0
        flops = wl.flops()
        nbytes = wl.hbm_bytes()
        return (a.flops_global / flops if flops else 1.0,
                a.bytes_global / nbytes if nbytes else 1.0)

    # -- scoring layer --------------------------------------------------
    def score(self, cfg: ModelConfig, shape: ShapeSuite,
              profile: SliceProfile) -> Optional[PerfScore]:
        """Plan + (possibly anchor-calibrated) roofline terms for one
        workload on one profile; ``None`` when it cannot fit even with
        everything offloadable spilled. Memoized."""
        key = (cfg, shape, profile)
        if key in self._scores:
            return self._scores[key]
        wl = self.workload(cfg, shape)
        plan = wl.plan_for(profile, self.chip)
        if not plan.fits:
            self._scores[key] = None
            return None
        spilled = plan.offloaded or plan.partial
        terms = wl.roofline_on(profile, self.chip, plan if spilled else None)
        fs, bs = self._calibration(wl)
        calibrated = (fs, bs) != (1.0, 1.0)
        if calibrated:
            terms = replace(terms, t_compute=terms.t_compute * fs,
                            t_memory=terms.t_memory * bs,
                            hlo_flops=terms.hlo_flops * fs,
                            hlo_bytes=terms.hlo_bytes * bs)
        step = terms.step_time
        sc = PerfScore(
            profile=profile, plan=plan, terms=terms, step_time=step,
            u_compute=terms.t_compute / step if step else 0.0,
            perf_per_chip=(1.0 / step) / profile.n_chips if step else 0.0,
            calibrated=calibrated)
        self._scores[key] = sc
        return sc

    def score_twin(self, cfg: ModelConfig, shape: ShapeSuite,
                   profile: SliceProfile) -> Optional[PerfScore]:
        """Twin-offload rung for one workload on one profile: the same
        rectangle with part of the compute co-executed host-side.

        ``None`` unless this model was built with a ``TwinSpec``, the plain
        score exists, something compute-bearing actually spilled, and the
        solved split beats the plain step time by ``twin.min_speedup`` —
        rungs that don't pay for themselves are never emitted, so every
        downstream consumer (placement, shrink probes, the autoscaler) can
        treat a twin rung as strictly better perf-per-chip at equal chips.
        Memoized alongside ``score``."""
        if self.twin is None:
            return None
        key = (cfg, shape, profile, "twin")
        if key in self._scores:
            return self._scores[key]
        out: Optional[PerfScore] = None
        plain = self.score(cfg, shape, profile)
        if plain is not None:
            wl = self.workload(cfg, shape)
            tp = wl.twin_plan_for(profile, self.chip, self.twin.host,
                                  max_cpu_fraction=self.twin.max_cpu_fraction)
            if tp is not None and tp.shards:
                terms = wl.roofline_twin(profile, tp, self.chip)
                fs, bs = self._calibration(wl)
                calibrated = (fs, bs) != (1.0, 1.0)
                if calibrated:
                    terms = replace(terms, t_compute=terms.t_compute * fs,
                                    t_memory=terms.t_memory * bs,
                                    hlo_flops=terms.hlo_flops * fs,
                                    hlo_bytes=terms.hlo_bytes * bs)
                step = terms.step_time
                if step and plain.step_time / step >= self.twin.min_speedup:
                    out = PerfScore(
                        profile=profile, plan=tp.base, terms=terms,
                        step_time=step,
                        u_compute=terms.t_compute / step,
                        perf_per_chip=(1.0 / step) / profile.n_chips,
                        calibrated=calibrated, twin=tp)
        self._scores[key] = out
        return out

    def options(self, job, ignore_pin: bool = False) -> Tuple[PerfScore, ...]:
        """Every profile a trace job fits on (possibly only via offloading),
        smallest first. A pinned ``job.profile`` restricts the set unless
        ``ignore_pin`` (the elastic shrink/grow path scans the full table).
        With twin rungs enabled each profile may contribute a second row —
        plain first, then its (faster) twin rung, preserving the
        smallest-chips-first order. Memoized per job — the scheduler's
        placement retries are free."""
        key = (job, ignore_pin)
        if key in self._options:
            return self._options[key]
        if len(self._options) >= self._MAX_JOB_MEMO:
            # jobs are unique per trace; bound the only unbounded memo (the
            # cfg/shape/profile tables are naturally small)
            self._options.clear()
        cfg, shape = get_config(job.arch), get_shape(job.shape)
        if ignore_pin or not job.profile:
            profs: Tuple[SliceProfile, ...] = self.profiles
        else:
            pinned = get_profile(job.profile)
            # a pin below the mode's granularity floor is unschedulable on
            # this model — the ladder is the hardware's word, not a hint
            profs = (pinned,) if pinned in self.profiles else ()
        rows: List[PerfScore] = []
        for p in profs:
            sc = self.score(cfg, shape, p)
            if sc is None:
                continue
            rows.append(sc)
            tw = self.score_twin(cfg, shape, p)
            if tw is not None:
                rows.append(tw)
        out = tuple(rows)
        self._options[key] = out
        return out

    def score_many(self, cfgs: Iterable[ModelConfig],
                   shapes: Iterable[ShapeSuite],
                   profiles: Optional[Sequence[SliceProfile]] = None,
                   ) -> Dict[Tuple[str, str, str], Optional[PerfScore]]:
        """Batched scoring over the full cfg × shape × profile cross
        product in one call — each workload is materialized once and its
        whole profile row is filled before moving on, so a trace loader or
        benchmark can pre-warm the memo for every (arch, shape) it is
        about to replay instead of paying cold ``score`` misses scattered
        through the scheduler's hot path. Returns
        ``{(cfg.name, shape.name, profile.name): PerfScore | None}``;
        every entry also lands in the shared ``score`` memo."""
        if profiles is None:
            profiles = self.profiles   # this model's (possibly gated) ladder
        out: Dict[Tuple[str, str, str], Optional[PerfScore]] = {}
        for cfg in cfgs:
            for shape in shapes:
                self.workload(cfg, shape)   # one estimate per pair
                for p in profiles:
                    out[(cfg.name, shape.name, p.name)] = \
                        self.score(cfg, shape, p)
                    tw = self.score_twin(cfg, shape, p)
                    if tw is not None:
                        out[(cfg.name, shape.name, tw.rung)] = tw
        return out

    _MAX_SLO_MEMO = 4096

    def slo_table(self, job) -> Tuple[Tuple[PerfScore, float], ...]:
        """LRU of ``(score, unthrottled modeled duration)`` rows for one
        trace job, smallest profile first — the deadline filter in
        ``cluster.actions.slo_profiles`` becomes one comparison per row
        instead of a fresh options scan + duration multiply per probe.
        Keyed on the job itself (its tag/pin/steps are all hash inputs);
        throttle state is deliberately *not* in the key because the rows
        are unthrottled nominal durations — each probe re-checks its own
        start delay against the live pod via ``meets_after``."""
        hit = self._slo.get(job)
        if hit is not None:
            self._slo.move_to_end(job)
            return hit
        rows = tuple(
            (sc, job.duration_s if job.duration_s is not None
             else job.steps * sc.step_time)
            for sc in self.options(job))
        self._slo[job] = rows
        if len(self._slo) > self._MAX_SLO_MEMO:
            self._slo.popitem(last=False)
        return rows

    # -- power surface (paper §V-B) -------------------------------------
    def throttle(self, loads: Sequence[InstanceLoad],
                 pod: PodSpec = V5E_POD) -> float:
        """Shared-cap frequency-scale factor f ≤ 1 for a concurrent mix."""
        return throttle_factor(loads, pod)

    def draw(self, loads: Sequence[InstanceLoad], pod: PodSpec = V5E_POD,
             capped: bool = True) -> float:
        d = pod_draw(loads, pod)
        return min(d, pod.power_cap_watts) if capped else d

    def corun(self, loads: Sequence[InstanceLoad],
              pod: PodSpec = V5E_POD) -> CoRunSummary:
        """Concurrent-mix account: throttle, makespan, piecewise energy."""
        f = throttle_factor(loads, pod)
        makespan, energy, eff = co_run(loads, pod)
        return CoRunSummary(throttle=f, throttled=f < 1.0,
                            makespan_s=makespan, energy_J=energy,
                            effective_times=tuple(eff))

    # -- checkpoint pricing (preemption / resume) ------------------------
    def checkpoint_cost(self, resident_bytes: int,
                        host_link_bw: float) -> CheckpointCost:
        """Price a checkpoint-based suspend/resume of ``resident_bytes``
        (the tenant's device-resident state, bytes) over ``host_link_bw``
        (aggregate host-link bytes/s of the slice or pod involved).

        This is the cost model the cluster scheduler's preemption path
        uses: evicting a job pays ``save_s`` before the freed rectangle is
        usable (the ``train/checkpoint.py`` save volume: one host-gather
        of every resident leaf), and resuming pays ``restore_s`` before
        progress continues (the restore volume: the same leaves streamed
        back and re-placed — ``checkpoint.restore`` accepts a different
        slice's shardings, so the resuming slice need not be the one that
        saved). The cross-pod ``MigrateAcrossPods`` action prices the
        identical save/restore pair over the pod's DCN instead — pass
        ``PodSpec.dcn_bw`` (bytes/s) as the link bandwidth."""
        bw = max(host_link_bw, 1.0)
        seconds = resident_bytes / bw
        return CheckpointCost(bytes=int(resident_bytes),
                              save_s=seconds, restore_s=seconds)

    def serial_baseline(self, load: InstanceLoad, copies: int,
                        pod: PodSpec = V5E_POD) -> Tuple[float, float]:
        """Paper Fig. 5/6 serial full-pod baseline (makespan, energy)."""
        return serial_run(load, copies, pod)


_MODELS: Dict[tuple, PerfModel] = {}


def get_model(chip: ChipSpec = V5E,
              twin: Optional[TwinSpec] = None,
              profiles: Optional[Sequence[SliceProfile]] = None) -> PerfModel:
    """Process-wide shared PerfModel per (chip spec, twin spec, ladder), so
    the placement policies, the scheduler, cosched, and the serving runtime
    all hit one memo table. Twin-enabled models are separate instances — the
    default twin-off model (and every pin that depends on it) is untouched.
    A full (or omitted) ladder normalizes to the legacy two-tuple key, so
    pre-existing entries and identities are bit-identical. Anchored models
    are built explicitly and passed around."""
    if profiles is not None and tuple(profiles) == PROFILES:
        profiles = None
    key = ((chip, twin) if profiles is None
           else (chip, twin, tuple(profiles)))
    m = _MODELS.get(key)
    if m is None:
        m = _MODELS[key] = PerfModel(chip, twin=twin, profiles=profiles)
    return m


def model_for_mode(chip: ChipSpec, mode, twin: Optional[TwinSpec] = None
                   ) -> PerfModel:
    """The shared PerfModel of ``chip`` under partition mode ``mode`` — the
    mode's roofline deltas folded in via ``effective_chip`` and its
    granularity floor via the profile ladder. For an identity mode with the
    full ladder (v5e ``fixed``, mi300 ``spx-nps1`` compute side) this
    returns the *same object* as ``get_model(chip, twin)`` would for the
    effective chip, so fixed-mode pins are untouched."""
    from repro.core.hw import effective_chip, ladder_for
    return get_model(effective_chip(chip, mode), twin=twin,
                     profiles=ladder_for(mode))


# ---------------------------------------------------------------------------
# PodSimulator
# ---------------------------------------------------------------------------
@dataclass
class SimJob:
    """Progress state of one instance on the simulated pod.

    ``fixed_s`` set → the duration is pinned (crafted job) or frozen at
    admission (compatibility mode): wall time only, never re-solved.
    Otherwise ``work_done/work_total`` are in *nominal unthrottled seconds*;
    the wall-time cost of one nominal second under throttle f is
    ``stretch(f) = u/f + (1 - u)`` (only the compute share scales)."""
    key: int
    n_chips: int
    u_compute: float
    step_time: float
    steps: int
    work_total: float = 0.0
    work_done: float = 0.0
    delay_s: float = 0.0        # pending wall delay (migration) before work
    fixed_s: Optional[float] = None   # remaining pinned/frozen wall duration
    pinned: bool = False        # fixed_s came from Job.duration_s, not frozen

    @property
    def progress(self) -> float:
        return self.work_done / self.work_total if self.work_total else 0.0

    def load(self) -> InstanceLoad:
        return InstanceLoad(self.n_chips, self.u_compute, self.step_time, 1)

    def stretch(self, f: float) -> float:
        return self.u_compute / f + (1.0 - self.u_compute)


class PodSimulator:
    """Progress-based execution engine for one pod's concurrent mix.

    The owner (``cluster.ClusterScheduler``) drives virtual time through
    ``advance`` between its events; every mutation (``admit`` / ``remove`` /
    ``resize`` / ``delay``) changes the mix, after which ``finish_times``
    re-solves the throttle and re-projects every live progress job. In
    ``frozen=True`` mode durations are fixed at admission with the exact
    legacy float expressions and ``finish_times`` projects nothing — the
    event stream is bit-identical to the PR 2 scheduler."""

    def __init__(self, pod: PodSpec = V5E_POD, frozen: bool = False):
        self.pod = pod
        self.frozen = frozen
        self.now = 0.0
        self.jobs: Dict[int, SimJob] = {}
        self._gen = 0          # bumped on every mix mutation
        self._cache_gen = -1
        self._cache: dict = {}

    @property
    def generation(self) -> int:
        """Monotone mix-mutation counter (``admit``/``remove``/``resize``/
        rollback ``invalidate``). Equal generations mean an identical
        instance mix — and therefore identical throttle/draw solutions —
        which is what the scheduler's ``ProbeCache`` keys on. ``advance``
        and ``delay`` do not move it: progress and start-delay burn-down
        never change a structural probe's outcome."""
        return self._gen

    def invalidate(self) -> None:
        """Drop the cached throttle/draw solution after external mutation
        of ``jobs`` (transaction rollback swaps the dict wholesale)."""
        self._gen += 1

    def _mix_cache(self) -> dict:
        """Throttle and draw depend only on the instance mix, which is
        constant between mutations — one linear back-off solve per mix
        generation instead of one per event. Keyed probes (``throttle``
        with an ``extra`` load) share the same lifetime."""
        if self._cache_gen != self._gen:
            self._cache_gen = self._gen
            self._cache = {"throttle": None, "draw": None, "extra": {}}
        return self._cache

    # -- mix queries ----------------------------------------------------
    def loads(self, extra: Optional[InstanceLoad] = None) -> List[InstanceLoad]:
        out = [j.load() for j in self.jobs.values()]
        if extra is not None:
            out.append(extra)
        return out

    def throttle(self, extra: Optional[InstanceLoad] = None) -> float:
        cache = self._mix_cache()
        if extra is None:
            if cache["throttle"] is None:
                cache["throttle"] = throttle_factor(self.loads(), self.pod)
            return cache["throttle"]
        f = cache["extra"].get(extra)
        if f is None:
            f = throttle_factor(self.loads(extra), self.pod)
            cache["extra"][extra] = f
        return f

    def draw(self, capped: bool = True) -> float:
        cache = self._mix_cache()
        if cache["draw"] is None:
            cache["draw"] = pod_draw(self.loads(), self.pod)
        d = cache["draw"]
        return min(d, self.pod.power_cap_watts) if capped else d

    # -- time -----------------------------------------------------------
    def advance(self, t: float) -> None:
        """Accrue progress (and burn down delays) to virtual time ``t``;
        the mix must not have changed since the last mutation."""
        dt = t - self.now
        if dt <= 0:
            self.now = max(self.now, t)
            return
        f = self.throttle() if self.jobs else 1.0
        for j in self.jobs.values():
            take = min(dt, j.delay_s)
            j.delay_s -= take
            run = dt - take
            if run <= 0:
                continue
            if j.fixed_s is not None:
                j.fixed_s = max(0.0, j.fixed_s - run)
            else:
                j.work_done = min(j.work_total,
                                  j.work_done + run / j.stretch(f))
        self.now = t

    # -- mutations ------------------------------------------------------
    def admit(self, key: int, n_chips: int, u_compute: float,
              step_time: float, steps: int, t: float, *,
              duration_s: Optional[float] = None,
              start_delay: float = 0.0,
              work_done: float = 0.0,
              fixed_remaining: Optional[float] = None) -> float:
        """Add an instance at time ``t``; returns its projected finish.

        Pinned ``duration_s`` → wall-clock duration regardless of throttle
        (crafted traces stay exactly deterministic). Frozen mode computes
        the duration once, with the legacy expression, at the admission-time
        throttle of the mix *including* the new instance.

        The resume-from-checkpoint path re-admits a previously evicted
        instance with its progress preserved: ``work_done`` (nominal
        unthrottled seconds already completed, progress jobs) or
        ``fixed_remaining`` (remaining wall seconds, frozen-mode jobs —
        overrides the legacy admission-time expression). A resumed pinned
        job simply passes its remaining wall time as ``duration_s``."""
        assert key not in self.jobs
        job = SimJob(key=key, n_chips=n_chips, u_compute=u_compute,
                     step_time=step_time, steps=steps, delay_s=start_delay)
        if duration_s is not None:
            job.fixed_s = duration_s
            job.pinned = True
            finish = t + start_delay + duration_s
        elif fixed_remaining is not None:
            job.fixed_s = fixed_remaining
            finish = t + start_delay + fixed_remaining
        elif self.frozen:
            # legacy float arithmetic, term for term (bit-identity contract)
            f = throttle_factor(self.loads(job.load()), self.pod)
            t_comp = step_time * u_compute
            dur = steps * (t_comp / f + (step_time - t_comp))
            job.fixed_s = dur
            finish = t + start_delay + dur
        else:
            job.work_total = steps * step_time
            job.work_done = min(work_done, job.work_total)
            f = throttle_factor(self.loads(job.load()), self.pod)
            finish = t + start_delay \
                + (job.work_total - job.work_done) * job.stretch(f)
        self.jobs[key] = job
        self._gen += 1
        return finish

    def remove(self, key: int) -> SimJob:
        self._gen += 1
        return self.jobs.pop(key)

    def delay(self, key: int, extra_s: float) -> None:
        """Add wall delay (migration) to one instance."""
        self.jobs[key].delay_s += extra_s

    def resize(self, key: int, n_chips: int, u_compute: float,
               step_time: float) -> None:
        """Elastic shrink/grow: move an instance to a different profile,
        preserving its *fraction* of work done — remaining work is re-based
        onto the new step time. Pinned wall-clock durations stay pinned;
        a frozen (fixed-at-admission) duration has its remaining wall time
        scaled by the step-time ratio."""
        j = self.jobs[key]
        if j.pinned:
            pass   # Job.duration_s is a wall-clock contract, profile-free
        elif j.fixed_s is not None:
            j.fixed_s *= step_time / j.step_time
        else:
            frac = j.progress
            j.work_total = j.steps * step_time
            j.work_done = frac * j.work_total
        j.n_chips = n_chips
        j.u_compute = u_compute
        j.step_time = step_time
        self._gen += 1

    # -- projection -----------------------------------------------------
    def projected_finish(self, key: int, t: float) -> float:
        """Projected finish of one instance (fixed or progress) at ``t``."""
        j = self.jobs[key]
        if j.fixed_s is not None:
            return t + j.delay_s + j.fixed_s
        return t + j.delay_s + (j.work_total - j.work_done) \
            * j.stretch(self.throttle())
    def finish_times(self, t: float) -> Dict[int, float]:
        """Projected finish for every *progress* job under the current mix
        (fixed-duration jobs are event-driven by the owner and never
        re-projected — that is the frozen/pinned contract)."""
        live = [j for j in self.jobs.values() if j.fixed_s is None]
        if not live:
            return {}
        f = self.throttle()
        return {j.key: t + j.delay_s + (j.work_total - j.work_done)
                * j.stretch(f) for j in live}
