"""OffloadPlanner — fine-grained host-memory offloading (paper §VI-A).

The paper's scheme: when a workload's footprint is *slightly* above a slice's
memory, offload part of its data to CPU memory over NVLink-C2C instead of
doubling the slice. TPU adaptation (DESIGN.md §2): the host link is PCIe-class
(~4 GB/s/chip vs 819 GB/s HBM), so where the paper could offload fairly hot
data (cacheline-coherent 450 GB/s), we must be *selective*: the planner ranks
offloadable tensors by bytes-freed per byte-of-host-traffic-added and spills
the coldest state first — optimizer moments (touched once per step), embedding
tables (one row gather per token), cold KV-cache tails — and only then
activations.

Plans are applied with real JAX memory kinds: ``NamedSharding(mesh, spec,
memory_kind="pinned_host")`` placements for spilled tensors (works on the CPU
backend of this container, and on real TPU runtimes), plus the
``remat="offload"`` activation policy in the model zoo.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding

from repro.core.hw import ChipSpec, HostSpec, V5E, V5E_HOST
from repro.core.slices import SliceProfile

PyTree = Any

# access multipliers: host-link bytes moved per step per resident byte if the
# tensor is offloaded (read + write counts per training/serving step)
GROUP_TRAFFIC = {
    "opt_state": 2.0,     # read m,v + write back, once per step
    "param": 3.0,         # read for fwd+bwd use, write after update
    "embed": 0.02,        # row-gather: tokens/step × row ≪ table size
    "kv_cache": 0.05,     # decode touches one position + appends
    "kv_cache_prefill": 2.0,
    "activation": 2.0,    # offload at save, fetch at bwd
}
# groups in preferred offload order when traffic ties
GROUP_PRIORITY = ("opt_state", "embed", "kv_cache", "param", "activation")


@dataclass(frozen=True)
class TensorInfo:
    name: str
    bytes: int
    group: str
    offloadable: bool = True
    divisible: bool = False  # can spill a fraction (KV tail, opt shard, rows)
    traffic_multiplier: Optional[float] = None  # override GROUP_TRAFFIC

    @property
    def traffic_per_step(self) -> float:
        m = (self.traffic_multiplier if self.traffic_multiplier is not None
             else GROUP_TRAFFIC.get(self.group, 2.0))
        return m * self.bytes


MIN_SPILL_BYTES = 64 * 1024 * 1024  # finest spill granule for divisible tensors


@dataclass(frozen=True)
class OffloadPlan:
    offloaded: Tuple[str, ...]             # fully-spilled tensor names
    partial: Tuple[Tuple[str, int], ...]   # (name, spilled_bytes)
    resident_bytes: int
    host_bytes: int
    host_traffic_per_step: float
    fits: bool
    # (name, tensor_total_bytes) for every partial entry — what turns the
    # raw spilled byte counts above into true fractions
    partial_totals: Tuple[Tuple[str, int], ...] = ()

    def is_offloaded(self, name: str) -> bool:
        return name in self.offloaded

    def spilled_fraction(self, name: str,
                         total_bytes: Optional[int] = None) -> float:
        """Fraction of ``name``'s bytes spilled to host: 1.0 fully offloaded,
        0.0 resident, and ``spilled/total`` for partial entries. ``total_bytes``
        overrides (or supplies, for hand-built plans without
        ``partial_totals``) the tensor's full size."""
        for n, b in self.partial:
            if n == name:
                total = (total_bytes if total_bytes is not None
                         else dict(self.partial_totals).get(name))
                if not total:
                    raise ValueError(
                        f"partial entry {name!r} has no recorded total size; "
                        f"pass total_bytes=")
                return min(1.0, b / total)
        return 1.0 if name in self.offloaded else 0.0

    @property
    def total_bytes(self) -> int:
        return self.resident_bytes + self.host_bytes


def plan_offload(inventory: Sequence[TensorInfo], hbm_budget: int,
                 host_budget: Optional[int] = None, *,
                 spill_granule: int = MIN_SPILL_BYTES) -> OffloadPlan:
    """Greedy knapsack: spill highest (bytes freed / host traffic added) first.

    *Fine-grained* in the paper's sense: ``divisible`` tensors (KV-cache
    tails, optimizer-state shards, embedding rows) are spilled only as far as
    needed to fit, never all-or-nothing — this is what keeps the added host
    traffic proportional to the *overhang* above the slice, not to the tensor.

    Returns ``fits=False`` if even spilling everything offloadable leaves the
    residents above budget (the caller must take a larger slice — the coarse
    step the paper wants to avoid — or shrink the workload).
    """
    total = sum(t.bytes for t in inventory)
    if total <= hbm_budget:
        return OffloadPlan((), (), total, 0, 0.0, True)

    def ratio(t: TensorInfo) -> float:
        return t.bytes / max(t.traffic_per_step, 1.0)

    prio = {g: i for i, g in enumerate(GROUP_PRIORITY)}
    candidates = sorted(
        [t for t in inventory if t.offloadable],
        key=lambda t: (-ratio(t), prio.get(t.group, len(prio)), -t.bytes))

    offloaded: List[str] = []
    partial: List[Tuple[str, int]] = []
    partial_totals: List[Tuple[str, int]] = []
    resident = total
    host = 0
    traffic = 0.0
    for t in candidates:
        need = resident - hbm_budget
        if need <= 0:
            break
        take = t.bytes
        if t.divisible and t.bytes > need:
            # spill only the overhang (rounded up to the spill granule;
            # ``spill_granule`` shrinks for reduced-scale demos/tests so the
            # partial path stays reachable below 64 MiB tensors)
            take = min(t.bytes, max(need, spill_granule))
        if host_budget is not None and host + take > host_budget:
            take = max(0, host_budget - host)
            # an indivisible tensor cannot spill a fraction: skip it rather
            # than record a partial no placement layer can realize
            if take == 0 or (not t.divisible and take < t.bytes):
                continue
        frac = take / t.bytes
        if take == t.bytes:
            offloaded.append(t.name)
        else:
            partial.append((t.name, int(take)))
            partial_totals.append((t.name, int(t.bytes)))
        resident -= take
        host += take
        traffic += t.traffic_per_step * frac
    return OffloadPlan(tuple(offloaded), tuple(partial), resident, host,
                       traffic, resident <= hbm_budget,
                       tuple(partial_totals))


# ---------------------------------------------------------------------------
# twin-offload co-execution (ZeRO-Offload++-style compute splitting)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TwinSpec:
    """Enablement knobs for twin-offload rungs (default-off at every caller).

    Hashable on purpose: ``perfmodel.get_model`` keys its process-wide memo
    on ``(chip, twin)``, and the spec is folded into ``PerfModel.profile_key``
    so probe caches never mix twin-on and twin-off pricing.
    """
    host: HostSpec = V5E_HOST
    min_speedup: float = 1.02      # emit a rung only if ≥2% faster than plain
    max_cpu_fraction: float = 1.0  # cap on any shard's CPU fraction


@dataclass(frozen=True)
class TwinShard:
    """One divisible compute-bearing shard split between GPU and CPU.

    ``flops``/``cpu_bytes`` describe the *whole* shard per step;
    ``cpu_fraction`` of it runs host-side. ``link_bytes``/``link_bytes_saved``
    are the chip<->host traffic a full (fraction 1.0) split adds/removes —
    the coherence-aware traffic model: running the consumer of spilled state
    on the CPU replaces the state's round trip with the (smaller)
    operand/result exchange.
    """
    name: str
    group: str
    cpu_fraction: float
    flops: float
    cpu_bytes: float
    link_bytes: float
    link_bytes_saved: float = 0.0


@dataclass(frozen=True)
class TwinOffloadPlan:
    """A memory plan plus a compute split: the two-resource schedule.

    The GPU-side terms (compute/HBM/collectives, collapsed into
    ``gpu_floor_s`` here) are deliberately NOT credited for the moved FLOPs —
    the eligible shards carry well under 1% of the counted step FLOPs, so the
    twin win is modeled entirely on the link (``t_link``) against the new CPU
    service time (``t_cpu``). Conservative by construction.
    """
    base: OffloadPlan
    shards: Tuple[TwinShard, ...]
    host: HostSpec
    n_hosts: int
    gpu_floor_s: float
    t_cpu: float
    t_link: float

    @property
    def cpu_fraction(self) -> float:
        total = sum(s.flops for s in self.shards)
        if total <= 0:
            return 0.0
        return sum(s.cpu_fraction * s.flops for s in self.shards) / total

    @property
    def link_traffic_per_step(self) -> float:
        delta = sum(s.cpu_fraction * (s.link_bytes - s.link_bytes_saved)
                    for s in self.shards)
        return max(0.0, self.base.host_traffic_per_step + delta)

    @property
    def step_time(self) -> float:
        return max(self.gpu_floor_s, self.t_cpu, self.t_link)


def plan_twin(base: OffloadPlan, candidates: Sequence[TwinShard], *,
              gpu_floor_s: float, link_bw: float, host: HostSpec = V5E_HOST,
              n_hosts: int = 1, max_cpu_fraction: float = 1.0,
              grid: int = 128) -> TwinOffloadPlan:
    """Choose CPU fractions minimizing ``max(t_gpu, t_cpu, t_link)``.

    ``candidates`` come in with ``cpu_fraction`` ignored; each is resolved
    greedily (best net-link-savings density first) by an exact scan over a
    ``grid``-point fraction lattice — all three terms are linear in the
    fraction, so the scan is a deterministic, float-order-stable LP solve.
    Fractions land in ``[0, max_cpu_fraction]`` and the smallest fraction
    achieving the minimum wins (no pointless CPU work on ties).
    """
    cpu_flops = host.cpu_flops * max(1, n_hosts)
    dram_bw = host.dram_bw * max(1, n_hosts)
    eff_link = link_bw * host.effective_link_scale()

    def service(c: TwinShard) -> float:
        """CPU seconds to run the whole shard host-side (compute or DRAM)."""
        return max(c.flops / cpu_flops, c.cpu_bytes / dram_bw)

    def density(c: TwinShard) -> float:
        saved = (c.link_bytes_saved - c.link_bytes) / eff_link
        return saved / max(service(c), 1e-12)

    order = sorted(range(len(candidates)),
                   key=lambda i: (-density(candidates[i]), i))
    fractions = [0.0] * len(candidates)
    t_cpu = 0.0
    traffic = base.host_traffic_per_step
    cap = min(1.0, max(0.0, max_cpu_fraction))
    for i in order:
        c = candidates[i]
        s, dlink = service(c), c.link_bytes - c.link_bytes_saved
        best_a, best_t = 0.0, max(gpu_floor_s, t_cpu,
                                  max(0.0, traffic) / eff_link)
        for k in range(1, grid + 1):
            a = cap * k / grid
            t = max(gpu_floor_s, t_cpu + a * s,
                    max(0.0, traffic + a * dlink) / eff_link)
            if t < best_t - 1e-15:
                best_a, best_t = a, t
        fractions[i] = best_a
        t_cpu += best_a * s
        traffic += best_a * dlink
    shards = tuple(replace(c, cpu_fraction=f)
                   for c, f in zip(candidates, fractions) if f > 0.0)
    return TwinOffloadPlan(base, shards, host, max(1, n_hosts), gpu_floor_s,
                           t_cpu, max(0.0, traffic) / eff_link)


# When GPU time and host traffic are comparable, the first granule of a
# step's host traffic cannot overlap the compute that produces/consumes it;
# the schedule pays a serial prefix proportional to the *second-largest*
# resource term. 0.1 matches the double-buffer depth the KV pool uses.
OVERLAP_SERIAL_FRACTION = 0.1


def overlap_step_time(t_gpu: float, t_cpu: float, t_link: float) -> float:
    """Two-resource overlap model: ``max(t_gpu, t_cpu, t_link)`` plus the
    non-overlappable serial prefix. Never better than the unconstrained
    ``max`` bound; converges to it when one term dominates."""
    terms = sorted((t_gpu, t_cpu, t_link))
    return terms[2] + OVERLAP_SERIAL_FRACTION * terms[1]


def estimated_step_slowdown(plan, base_step_time: float,
                            profile: SliceProfile, chip: ChipSpec = V5E,
                            host: Optional[HostSpec] = None) -> float:
    """New step time with host traffic overlapped against compute.

    Replaces the old ``max(base, t_host)`` form, which silently assumed the
    host traffic overlaps compute *perfectly* — wrong exactly in the
    crossover region ``base_step_time`` ≈ ``t_host``, where double-buffered
    DMA still serializes on the first granule. Accepts a plain
    ``OffloadPlan`` (no CPU co-execution: ``t_cpu = 0``) or a
    ``TwinOffloadPlan`` (its solved two-resource terms).
    """
    if isinstance(plan, TwinOffloadPlan):
        return overlap_step_time(max(base_step_time, plan.gpu_floor_s),
                                 plan.t_cpu, plan.t_link)
    scale = host.effective_link_scale() if host is not None else 1.0
    t_link = plan.host_traffic_per_step / (profile.host_link_bw(chip) * scale)
    return overlap_step_time(base_step_time, 0.0, t_link)


# ---------------------------------------------------------------------------
# inventory builders
# ---------------------------------------------------------------------------
def _group_for(path: str) -> Tuple[str, bool]:
    """(group, offloadable) from a tree path."""
    if re.search(r"(^|/)(mu|nu)(/|$)", path):
        return "opt_state", True
    if "tok_embed" in path or "pos_embed" in path:
        return "embed", True
    if re.search(r"(^|/)(k|v|cross_k|cross_v|ssm|conv|state)(/|$)", path):
        return "kv_cache", True
    return "param", True


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out.append((path, leaf))
    return out


def inventory_from_tree(tree: PyTree, *, default_group: Optional[str] = None
                        ) -> List[TensorInfo]:
    """Build a TensorInfo list from any pytree of (abstract) arrays."""
    out = []
    for path, leaf in _flatten_with_paths(tree):
        if not hasattr(leaf, "dtype"):
            continue
        nbytes = int(leaf.size) * leaf.dtype.itemsize
        group, off = (_group_for(path) if default_group is None
                      else (default_group, True))
        out.append(TensorInfo(path, nbytes, group, off))
    return out


# ---------------------------------------------------------------------------
# plan application (real memory kinds)
# ---------------------------------------------------------------------------
def _memory_kind(mesh, preferred: str) -> str:
    import jax as _jax
    dev = (mesh.devices.flat[0] if mesh is not None else _jax.devices()[0])
    kinds = {m.kind for m in dev.addressable_memories()}
    return preferred if preferred in kinds else dev.default_memory().kind


def host_memory_kind(mesh=None) -> str:
    """The host-tier memory kind this backend can address.

    ``pinned_host`` on runtimes that expose it (TPU, GPU); the CPU backend
    of the test container has a single ``unpinned_host`` space, so both
    tiers resolve to the same kind there — the spill is physically a no-op
    but every plan/placement code path still executes.
    """
    return _memory_kind(mesh, "pinned_host")


def device_memory_kind(mesh=None) -> str:
    """The device-tier (HBM) memory kind — ``device`` where it exists."""
    return _memory_kind(mesh, "device")


def shardings_with_offload(spec_tree: PyTree, plan: OffloadPlan, mesh, *,
                           partial_host_threshold: float = 0.5,
                           sizes: Optional[Dict[str, int]] = None) -> PyTree:
    """NamedShardings for jit in_shardings: offloaded leaves → pinned_host.

    Partial spills: a JAX sharding places the *whole* buffer in one memory
    kind, so at leaf granularity a partially spilled tensor is rounded to the
    majority side — ``pinned_host`` when the spilled fraction reaches
    ``partial_host_threshold``, ``device`` otherwise. ``sizes`` (leaf path →
    bytes) lets the caller supply real byte counts for the fraction; without
    it a partial entry's fraction is unknowable here and the leaf stays on
    device. The physically split hot-prefix/cold-tail placement the planner
    actually intends for KV pools lives in ``repro.serving.kv_pool.KVPool``,
    which divides the buffer along the sequence axis.
    """
    flat_specs = _flatten_with_paths(spec_tree)
    partial_bytes = dict(plan.partial)
    host_kind = host_memory_kind(mesh)
    dev_kind = device_memory_kind(mesh)

    def kind_for(path: str) -> str:
        if plan.is_offloaded(path):
            return host_kind
        if path in partial_bytes and sizes and sizes.get(path):
            frac = partial_bytes[path] / sizes[path]
            if frac >= partial_host_threshold:
                return host_kind
        return dev_kind

    flat = [NamedSharding(mesh, spec, memory_kind=kind_for(path))
            for path, spec in flat_specs]
    treedef = jax.tree_util.tree_structure(
        spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    return jax.tree_util.tree_unflatten(treedef, flat)


def place_tree(value_tree: PyTree, spec_tree: PyTree, plan: OffloadPlan, mesh,
               *, partial_host_threshold: float = 0.5) -> PyTree:
    """device_put each leaf to its planned memory kind (concrete arrays)."""
    sizes = {path: int(leaf.size) * leaf.dtype.itemsize
             for path, leaf in _flatten_with_paths(value_tree)
             if hasattr(leaf, "dtype")}
    shardings = shardings_with_offload(
        spec_tree, plan, mesh,
        partial_host_threshold=partial_host_threshold, sizes=sizes)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), value_tree, shardings)
