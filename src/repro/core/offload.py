"""OffloadPlanner — fine-grained host-memory offloading (paper §VI-A).

The paper's scheme: when a workload's footprint is *slightly* above a slice's
memory, offload part of its data to CPU memory over NVLink-C2C instead of
doubling the slice. TPU adaptation (DESIGN.md §2): the host link is PCIe-class
(~4 GB/s/chip vs 819 GB/s HBM), so where the paper could offload fairly hot
data (cacheline-coherent 450 GB/s), we must be *selective*: the planner ranks
offloadable tensors by bytes-freed per byte-of-host-traffic-added and spills
the coldest state first — optimizer moments (touched once per step), embedding
tables (one row gather per token), cold KV-cache tails — and only then
activations.

Plans are applied with real JAX memory kinds: ``NamedSharding(mesh, spec,
memory_kind="pinned_host")`` placements for spilled tensors (works on the CPU
backend of this container, and on real TPU runtimes), plus the
``remat="offload"`` activation policy in the model zoo.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding

from repro.core.hw import ChipSpec, V5E
from repro.core.slices import SliceProfile

PyTree = Any

# access multipliers: host-link bytes moved per step per resident byte if the
# tensor is offloaded (read + write counts per training/serving step)
GROUP_TRAFFIC = {
    "opt_state": 2.0,     # read m,v + write back, once per step
    "param": 3.0,         # read for fwd+bwd use, write after update
    "embed": 0.02,        # row-gather: tokens/step × row ≪ table size
    "kv_cache": 0.05,     # decode touches one position + appends
    "kv_cache_prefill": 2.0,
    "activation": 2.0,    # offload at save, fetch at bwd
}
# groups in preferred offload order when traffic ties
GROUP_PRIORITY = ("opt_state", "embed", "kv_cache", "param", "activation")


@dataclass(frozen=True)
class TensorInfo:
    name: str
    bytes: int
    group: str
    offloadable: bool = True
    divisible: bool = False  # can spill a fraction (KV tail, opt shard, rows)
    traffic_multiplier: Optional[float] = None  # override GROUP_TRAFFIC

    @property
    def traffic_per_step(self) -> float:
        m = (self.traffic_multiplier if self.traffic_multiplier is not None
             else GROUP_TRAFFIC.get(self.group, 2.0))
        return m * self.bytes


MIN_SPILL_BYTES = 64 * 1024 * 1024  # finest spill granule for divisible tensors


@dataclass(frozen=True)
class OffloadPlan:
    offloaded: Tuple[str, ...]             # fully-spilled tensor names
    partial: Tuple[Tuple[str, int], ...]   # (name, spilled_bytes) fractions
    resident_bytes: int
    host_bytes: int
    host_traffic_per_step: float
    fits: bool

    def is_offloaded(self, name: str) -> bool:
        return name in self.offloaded

    def spilled_fraction(self, name: str) -> float:
        for n, b in self.partial:
            if n == name:
                return b
        return 1.0 if name in self.offloaded else 0.0

    @property
    def total_bytes(self) -> int:
        return self.resident_bytes + self.host_bytes


def plan_offload(inventory: Sequence[TensorInfo], hbm_budget: int,
                 host_budget: Optional[int] = None) -> OffloadPlan:
    """Greedy knapsack: spill highest (bytes freed / host traffic added) first.

    *Fine-grained* in the paper's sense: ``divisible`` tensors (KV-cache
    tails, optimizer-state shards, embedding rows) are spilled only as far as
    needed to fit, never all-or-nothing — this is what keeps the added host
    traffic proportional to the *overhang* above the slice, not to the tensor.

    Returns ``fits=False`` if even spilling everything offloadable leaves the
    residents above budget (the caller must take a larger slice — the coarse
    step the paper wants to avoid — or shrink the workload).
    """
    total = sum(t.bytes for t in inventory)
    if total <= hbm_budget:
        return OffloadPlan((), (), total, 0, 0.0, True)

    def ratio(t: TensorInfo) -> float:
        return t.bytes / max(t.traffic_per_step, 1.0)

    prio = {g: i for i, g in enumerate(GROUP_PRIORITY)}
    candidates = sorted(
        [t for t in inventory if t.offloadable],
        key=lambda t: (-ratio(t), prio.get(t.group, len(prio)), -t.bytes))

    offloaded: List[str] = []
    partial: List[Tuple[str, int]] = []
    resident = total
    host = 0
    traffic = 0.0
    for t in candidates:
        need = resident - hbm_budget
        if need <= 0:
            break
        take = t.bytes
        if t.divisible and t.bytes > need:
            # spill only the overhang (rounded up to the spill granule)
            take = min(t.bytes, max(need, MIN_SPILL_BYTES))
        if host_budget is not None and host + take > host_budget:
            take = max(0, host_budget - host)
            if take == 0:
                continue
        frac = take / t.bytes
        if take == t.bytes:
            offloaded.append(t.name)
        else:
            partial.append((t.name, int(take)))
        resident -= take
        host += take
        traffic += t.traffic_per_step * frac
    return OffloadPlan(tuple(offloaded), tuple(partial), resident, host,
                       traffic, resident <= hbm_budget)


def estimated_step_slowdown(plan: OffloadPlan, base_step_time: float,
                            profile: SliceProfile, chip: ChipSpec = V5E
                            ) -> float:
    """New step time with host traffic overlapped against compute: the host
    term only binds if it exceeds the rest of the step (double-buffered DMA
    — the TPU-idiomatic version of the paper's 'direct access' finding)."""
    t_host = plan.host_traffic_per_step / profile.host_link_bw(chip)
    return max(base_step_time, t_host)


# ---------------------------------------------------------------------------
# inventory builders
# ---------------------------------------------------------------------------
def _group_for(path: str) -> Tuple[str, bool]:
    """(group, offloadable) from a tree path."""
    if re.search(r"(^|/)(mu|nu)(/|$)", path):
        return "opt_state", True
    if "tok_embed" in path or "pos_embed" in path:
        return "embed", True
    if re.search(r"(^|/)(k|v|cross_k|cross_v|ssm|conv|state)(/|$)", path):
        return "kv_cache", True
    return "param", True


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out.append((path, leaf))
    return out


def inventory_from_tree(tree: PyTree, *, default_group: Optional[str] = None
                        ) -> List[TensorInfo]:
    """Build a TensorInfo list from any pytree of (abstract) arrays."""
    out = []
    for path, leaf in _flatten_with_paths(tree):
        if not hasattr(leaf, "dtype"):
            continue
        nbytes = int(leaf.size) * leaf.dtype.itemsize
        group, off = (_group_for(path) if default_group is None
                      else (default_group, True))
        out.append(TensorInfo(path, nbytes, group, off))
    return out


# ---------------------------------------------------------------------------
# plan application (real memory kinds)
# ---------------------------------------------------------------------------
def shardings_with_offload(spec_tree: PyTree, value_tree: PyTree,
                           plan: OffloadPlan, mesh) -> PyTree:
    """NamedShardings for jit in_shardings: offloaded leaves → pinned_host."""
    paths = dict(_flatten_with_paths(value_tree))
    flat_specs = _flatten_with_paths(spec_tree)
    name_by_leaf = {}
    for path, _ in flat_specs:
        name_by_leaf[path] = path

    def make(path_spec):
        path, spec = path_spec
        kind = "pinned_host" if plan.is_offloaded(path) else "device"
        return NamedSharding(mesh, spec, memory_kind=kind)

    flat = [(p, make((p, s))) for p, s in flat_specs]
    # rebuild tree in original structure
    treedef = jax.tree_util.tree_structure(
        spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    return jax.tree_util.tree_unflatten(treedef, [s for _, s in flat])


def place_tree(value_tree: PyTree, spec_tree: PyTree, plan: OffloadPlan, mesh
               ) -> PyTree:
    """device_put each leaf to its planned memory kind (concrete arrays)."""
    shardings = shardings_with_offload(spec_tree, value_tree, plan, mesh)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), value_tree, shardings)
