"""Pod power / energy model with shared-cap throttling (paper §V-B, Figs 6-7).

MIG isolates compute and memory but *not power delivery*: the paper shows
seven concurrent compute-heavy instances collectively exceed the 700 W cap
and throttle, while a single instance never does. Same structure here: chips
draw idle + utilization-proportional dynamic power; the pod's provisioned cap
is below chips×max; when concurrent slices push total draw over the cap, the
whole pod frequency-scales, stretching every instance's compute term.

Synthetic calibration (DESIGN.md §7(4)); all outputs are labeled model-based.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.core.hw import ChipSpec, PodSpec, V5E_POD


@dataclass(frozen=True)
class InstanceLoad:
    n_chips: int
    u_compute: float       # roofline compute utilization in [0,1]
    step_time: float       # un-throttled step time (s)
    steps: int = 1


def chip_power(u: float, chip: ChipSpec) -> float:
    return chip.idle_watts + (chip.active_watts - chip.idle_watts) * min(max(u, 0.0), 1.0)


def pod_draw(instances: Sequence[InstanceLoad], pod: PodSpec = V5E_POD) -> float:
    used = sum(i.n_chips for i in instances)
    assert used <= pod.n_chips, "over-allocated pod"
    active = sum(i.n_chips * chip_power(i.u_compute, pod.chip) for i in instances)
    idle = (pod.n_chips - used) * pod.chip.idle_watts
    return active + idle


def throttle_factor(instances: Sequence[InstanceLoad], pod: PodSpec = V5E_POD
                    ) -> float:
    """Frequency-scale factor f ≤ 1 applied when draw exceeds the cap.
    Dynamic power ~ f (voltage held), so we solve a linear back-off on the
    dynamic share only — idle power cannot be throttled away."""
    draw = pod_draw(instances, pod)
    cap = pod.power_cap_watts
    if draw <= cap:
        return 1.0
    idle_floor = pod.n_chips * pod.chip.idle_watts
    dynamic = draw - idle_floor
    if dynamic <= 0:
        return 1.0
    return max(0.1, (cap - idle_floor) / dynamic)


def co_run(instances: Sequence[InstanceLoad], pod: PodSpec = V5E_POD
           ) -> Tuple[float, float, List[float]]:
    """Run all instances concurrently.
    Returns (makespan_s, energy_J, per-instance effective step times).

    The throttle factor is held at the full-mix value for every instance's
    whole run (re-solving it at each completion is what ``PodSimulator``
    does); energy is exact for these effective times."""
    f = throttle_factor(instances, pod)
    eff = []
    for i in instances:
        # only the compute share of the step stretches under throttling
        t_comp = i.step_time * i.u_compute
        t_rest = i.step_time - t_comp
        eff.append((t_comp / f + t_rest) * i.steps)
    makespan = max(eff) if eff else 0.0
    # energy integrates draw piecewise over completion events: when an
    # instance finishes, its chips fall back to idle draw for the rest of
    # the makespan (pod_draw counts unused chips at idle watts)
    cap = pod.power_cap_watts
    running = list(range(len(instances)))
    energy = 0.0
    prev = 0.0
    for idx in sorted(running, key=lambda i: eff[i]):
        t = eff[idx]
        if t > prev:
            draw = min(pod_draw([instances[i] for i in running], pod), cap)
            energy += draw * (t - prev)
            prev = t
        running.remove(idx)
    return makespan, energy, eff


def serial_run(instance: InstanceLoad, copies: int, pod: PodSpec = V5E_POD
               ) -> Tuple[float, float]:
    """Paper Fig. 5/6 baseline: run ``copies`` sequentially, each on the full
    pod (scaled step time given), idle chips still burn idle power."""
    makespan = instance.step_time * instance.steps * copies
    draw = pod_draw([instance], pod)
    return makespan, draw * makespan
