"""mamba2-130m [arXiv:2405.21060; unverified]. SSD (state-space duality), attn-free.

24L d_model=768, ssm_state=128, vocab=50280, d_inner=1536, 24 SSD heads of 64.
"""
from repro.configs.base import SSM, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family=SSM,
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    ssm_chunk=128,
    tie_embeddings=True,
)
