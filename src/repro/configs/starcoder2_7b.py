"""starcoder2-7b [arXiv:2402.19173; hf]. GQA, RoPE, plain-MLP with GELU.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family=DENSE,
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    use_bias=True,
    glu=False,
    act="gelu",
    norm="layernorm",
    rope_theta=100_000.0,
)
