"""qwen2-vl-72b [arXiv:2409.12191; hf]. VLM backbone: M-RoPE, dynamic resolution.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. The vision frontend is
a STUB: ``input_specs()`` provides precomputed patch embeddings plus the three
M-RoPE position streams (temporal / height / width).
"""
from repro.configs.base import VLM, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family=VLM,
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    mrope=True,
    use_bias=False,
    glu=True,
    act="silu",
    rope_theta=1_000_000.0,
    # 80 layers × d_model 8192: layer-boundary activations exceed HBM even at
    # maximum microbatching — sequence-sharded residuals are required to fit
    # (see DESIGN.md §5 and EXPERIMENTS.md §Dry-run).
    seq_shard_residuals=True,
)
