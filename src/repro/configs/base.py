"""Model configuration schema for every architecture in the zoo.

A single frozen dataclass covers all families (dense / MoE / SSM / hybrid /
enc-dec / VLM); family-specific fields are zero / empty when unused. Each
architecture file under ``repro/configs`` exports ``CONFIG`` built from public
literature numbers (sources quoted in the assignment).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional

DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
ENCDEC = "encdec"
VLM = "vlm"

FAMILIES = (DENSE, MOE, SSM, HYBRID, ENCDEC, VLM)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 8192  # split long sequences into routing sub-groups

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256

    # --- hybrid (Zamba2-style): shared attention block every N SSM layers ---
    attn_every: int = 0

    # --- encoder-decoder (Whisper backbone) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # precomputed frame embeddings (conv frontend stub)

    # --- architectural switches ---
    use_qk_norm: bool = False
    use_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False  # Qwen2-VL multimodal RoPE (3 position streams)
    learned_pos: bool = False  # GPT-2 / Whisper style absolute positions
    max_position: int = 1 << 20
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    act: str = "silu"
    glu: bool = True  # SwiGLU (gated) vs plain 2-matmul MLP

    # --- numerics / runtime knobs (not architecture) ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "layer"  # "none" | "layer" | "full" | "offload"
    # Megatron-style sequence parallelism for the residual stream: layer
    # boundaries are S-sharded over "model" (divides saved activations by the
    # model-axis size at the cost of per-layer gather/scatter collectives).
    seq_shard_residuals: bool = False
    attn_impl: str = "xla"  # "xla" (scan flash) | "pallas" (TPU kernel)
    attn_chunk: int = 1024  # KV-block size for the scan flash attention

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim is None and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == SSM

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode (500k) is feasible: SSM or hybrid."""
        return self.family in (SSM, HYBRID)

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameters (analytic; verified against init in tests)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim or 0
        qkv = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd)
        proj = (self.num_heads * hd) * d
        attn = qkv + proj
        if self.use_qk_norm:
            attn += 2 * hd
        if self.glu:
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.use_bias:
            attn += (self.num_heads + 2 * self.num_kv_heads) * hd + d
            mlp += (f + d) if not self.glu else (2 * f + d)
        norms = 2 * d

        if self.family == MOE:
            router = d * self.num_experts
            block = attn + norms + router + self.num_experts * mlp
            total = self.num_layers * block
        elif self.family == SSM:
            di, ns = self.d_inner, self.ssm_state
            nh = self.ssm_heads
            in_proj = d * (2 * di + 2 * ns + nh)  # z, x, B, C, dt
            conv = self.conv_width * (di + 2 * ns)
            out_proj = di * d
            block = in_proj + conv + out_proj + d + di + 2 * nh  # norms+A,dt_bias
            total = self.num_layers * block
        elif self.family == HYBRID:
            di, ns = self.d_inner, self.ssm_state
            nh = self.ssm_heads
            in_proj = d * (2 * di + 2 * ns + nh)
            conv = self.conv_width * (di + 2 * ns)
            out_proj = di * d
            mblock = in_proj + conv + out_proj + d + di + 2 * nh
            shared = attn + mlp + norms  # one shared attention+MLP block
            total = self.num_layers * mblock + shared
        elif self.family == ENCDEC:
            # encoder: self-attn + mlp; decoder: self-attn + cross-attn + mlp
            enc_block = attn + mlp + norms
            dec_block = 2 * attn + mlp + 3 * d
            total = self.encoder_layers * enc_block + self.num_layers * dec_block
            if self.learned_pos:
                total += (self.encoder_seq + self.max_position) * d
        else:  # dense / vlm
            block = attn + mlp + norms
            total = self.num_layers * block
        total += v * d  # token embedding
        if not self.tie_embeddings:
            total += v * d  # output head
        total += d  # final norm
        if self.learned_pos and self.family != ENCDEC:
            total += self.max_position * d
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE activates top-k experts only)."""
        if self.family != MOE:
            return self.param_count()
        full = self.param_count()
        mlp = (3 if self.glu else 2) * self.d_model * self.d_ff
        inactive = self.num_layers * (self.num_experts - self.experts_per_token) * mlp
        return full - inactive

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kv = max(1, min(self.num_kv_heads, 2))
        heads = max(kv, min(self.num_heads, 4))
        changes = dict(
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=min(self.d_ff, 128) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 256),
            max_position=4096 if self.learned_pos else self.max_position,
            attn_chunk=64,
        )
        if self.family == MOE:
            changes.update(num_experts=min(self.num_experts, 4),
                           experts_per_token=min(self.experts_per_token, 2))
        if self.family in (SSM, HYBRID):
            changes.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=16,
                           ssm_chunk=32)
        if self.family == HYBRID:
            changes.update(num_layers=4, attn_every=2)
        if self.family == ENCDEC:
            changes.update(encoder_layers=min(self.encoder_layers, 2),
                           encoder_seq=min(self.encoder_seq, 32))
        return replace(self, **changes)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)
