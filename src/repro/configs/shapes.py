"""The four assigned input-shape suites and per-(arch × shape) applicability.

``train_*`` shapes lower ``train_step``; ``prefill_*`` lowers the prefill
``serve_step``; ``decode_*`` / ``long_*`` lower the single-token decode
``serve_step`` with a KV/state cache of ``seq_len``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.configs.base import ENCDEC, HYBRID, SSM, ModelConfig

TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclass(frozen=True)
class ShapeSuite:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == DECODE:
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSuite("train_4k", TRAIN, 4_096, 256)
PREFILL_32K = ShapeSuite("prefill_32k", PREFILL, 32_768, 32)
DECODE_32K = ShapeSuite("decode_32k", DECODE, 32_768, 128)
LONG_500K = ShapeSuite("long_500k", DECODE, 524_288, 1)

SHAPES: Tuple[ShapeSuite, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def get_shape(name: str) -> ShapeSuite:
    try:
        return SHAPES_BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES_BY_NAME)}")


def applicable(config: ModelConfig, shape: ShapeSuite) -> Tuple[bool, Optional[str]]:
    """(runs?, reason-if-skipped) — mirrors DESIGN.md §Arch-applicability.

    ``long_500k`` needs sub-quadratic sequence mixing: run only for SSM /
    hybrid families, skip for pure full-attention archs (incl. the enc-dec
    backbone, whose decoder self-attention is full attention).
    """
    if shape.name == "long_500k" and not config.subquadratic:
        return False, "full-attention arch: 524k-token decode is quadratic; skipped per assignment"
    if shape.kind == DECODE and not config.has_decoder:
        return False, "encoder-only arch has no decode step"
    return True, None


def reduced_shape(shape: ShapeSuite) -> ShapeSuite:
    """Tiny same-kind shape for CPU smoke tests."""
    return ShapeSuite(shape.name + "-smoke", shape.kind,
                      seq_len=min(shape.seq_len, 128),
                      global_batch=min(shape.global_batch, 2))


def prefill_len_for(config: ModelConfig, shape: ShapeSuite) -> int:
    """Sequence length already in cache when lowering a decode step."""
    assert shape.kind == DECODE
    return shape.seq_len
