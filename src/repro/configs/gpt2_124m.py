"""gpt2-124m — the paper's own LLM-training workload (llm.c, paper Table III).

12L d_model=768 12H d_ff=3072 vocab=50257, learned positions, GELU MLP.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="gpt2-124m",
    family=DENSE,
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=50257,
    use_bias=True,
    glu=False,
    act="gelu",
    norm="layernorm",
    learned_pos=True,
    max_position=1024,
    tie_embeddings=True,
)
