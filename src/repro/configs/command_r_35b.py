"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01; unverified]. GQA, no-bias.

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family=DENSE,
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    use_bias=False,
    glu=True,
    act="silu",
    tie_embeddings=True,
    norm="layernorm",
    rope_theta=8_000_000.0,
)
