"""Architecture registry: ``get_config(arch_id)`` + the shape suites.

The ten assigned architectures (public-literature configs) plus the paper's
own two LLM workloads (GPT-2 training via llm.c, Llama-3-8B inference via
llama.cpp — paper Table III).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig
from repro.configs import shapes  # noqa: F401  (re-export)
from repro.configs.shapes import SHAPES, ShapeSuite, applicable, get_shape

# arch-id -> module name
_ARCH_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a6_6b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen3-32b": "qwen3_32b",
    "command-r-35b": "command_r_35b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-130m": "mamba2_130m",
    # paper's own workloads
    "gpt2-124m": "gpt2_124m",
    "llama3-8b": "llama3_8b",
}

ASSIGNED_ARCHS: List[str] = list(_ARCH_MODULES)[:10]
PAPER_ARCHS: List[str] = list(_ARCH_MODULES)[10:]
ALL_ARCHS: List[str] = list(_ARCH_MODULES)

_cache: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ALL_ARCHS}")
    if arch not in _cache:
        mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
        _cache[arch] = mod.CONFIG
    return _cache[arch]


def all_cells(archs=None, include_skipped: bool = False):
    """Yield (config, shape, skip_reason) for the assigned 10×4 grid."""
    for arch in archs or ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, reason = applicable(cfg, shape)
            if ok or include_skipped:
                yield cfg, shape, (None if ok else reason)


__all__ = [
    "ModelConfig", "ShapeSuite", "SHAPES", "get_config", "get_shape",
    "applicable", "all_cells", "ASSIGNED_ARCHS", "PAPER_ARCHS", "ALL_ARCHS",
]
