"""llama3-8b — the paper's own LLM-inference workload (llama.cpp, paper Table III).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family=DENSE,
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    use_bias=False,
    glu=True,
    act="silu",
    rope_theta=500_000.0,
)
