"""zamba2-1.2b [arXiv:2411.15242; hf]. Mamba2 backbone + shared attention block.

38 Mamba2 layers d_model=2048, ssm_state=64; one SHARED attention+MLP block
(32H kv=32, d_ff=8192) invoked every 6 SSM layers (weights reused each time).
"""
from repro.configs.base import HYBRID, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family=HYBRID,
    num_layers=38,           # Mamba2 layers
    attn_every=6,            # shared attn block applied after every 6th layer
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    use_bias=False,
    glu=True,
    act="silu",
    tie_embeddings=True,
)
