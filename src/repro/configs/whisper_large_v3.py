"""whisper-large-v3 [arXiv:2212.04356; unverified]. Encoder-decoder backbone.

32L (enc) + 32L (dec), d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866.
The conv audio frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings of shape (batch, encoder_seq, d_model).
"""
from repro.configs.base import ENCDEC, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family=ENCDEC,
    num_layers=32,           # decoder layers
    encoder_layers=32,
    encoder_seq=1500,        # 30 s of audio at 50 Hz after the conv stub
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    use_bias=True,
    glu=False,
    act="gelu",
    norm="layernorm",
    learned_pos=True,
    max_position=1 << 16,
    tie_embeddings=True,
)
