"""Serving driver: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --requests 16

Optionally places the KV pool in host memory (``--offload-kv``) via the
paper's offloading scheme — the slice-too-small-for-the-KV-pool scenario.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--offload-kv", action="store_true")
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    env = host_axis_env()
    model = build_model(cfg, env)
    params, _ = model.init(jax.random.PRNGKey(0))

    mesh = None
    if args.offload_kv:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(1, 1)

    engine = ServingEngine(model, params, slots=args.slots,
                           max_seq=args.max_seq, mesh=mesh,
                           offload_kv=args.offload_kv)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=rng.integers(4, 17)).astype(np.int32),
                    args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    out = engine.run(reqs)
    wall = time.time() - t0
    total_tokens = sum(len(v) for v in out.values())
    print(f"arch={cfg.name} requests={len(out)} tokens={total_tokens} "
          f"ticks={engine.ticks} wall={wall:.2f}s "
          f"tok/s={total_tokens / wall:.1f} offload_kv={args.offload_kv}")


if __name__ == "__main__":
    main()
