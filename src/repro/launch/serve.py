"""Serving driver: single-tenant continuous batching, or the multi-tenant
SliceRuntime.

Single tenant (the original path):

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --requests 16

Multi-tenant — pack several archs onto one pod's slices, each with its own
offload plan, and drive them concurrently:

    PYTHONPATH=src python -m repro.launch.serve \
        --tenants llama3-8b:2s.32c,gpt2-124m:1s.16c --requests 8

``--hbm-budget BYTES`` pins the *first* tenant's plan budget below its
footprint so the offload path engages at reduced scale (see
examples/slice_runtime_demo.py for the scripted version).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model
from repro.serving import Request, ServingEngine, SliceRuntime, TenantSpec


def run_single(args) -> None:
    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    env = host_axis_env()
    model = build_model(cfg, env)
    params, _ = model.init(jax.random.PRNGKey(0))

    mesh = None
    if args.offload_kv:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(1, 1)

    engine = ServingEngine(model, params, slots=args.slots,
                           max_seq=args.max_seq, mesh=mesh,
                           offload_kv=args.offload_kv)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=rng.integers(4, 17)).astype(np.int32),
                    args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    out = engine.run(reqs)
    wall = time.time() - t0
    total_tokens = sum(len(v) for v in out.values())
    print(f"arch={cfg.name} requests={len(out)} tokens={total_tokens} "
          f"ticks={engine.ticks} truncated={engine.stats.truncated} "
          f"rejected={engine.stats.rejected} "
          f"wall={wall:.2f}s tok/s={total_tokens / wall:.1f} "
          f"offload_kv={args.offload_kv}")


def run_multi(args) -> None:
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    rt = SliceRuntime(mesh=mesh)

    specs = []
    names = set()
    for i, entry in enumerate(args.tenants.split(",")):
        arch, _, prof = entry.partition(":")
        cfg = get_config(arch)
        if not args.full_size:
            cfg = cfg.reduced().with_(remat="none")
        budget = args.hbm_budget if i == 0 and args.hbm_budget else None
        name = arch if arch not in names else f"{arch}-{i}"
        names.add(name)
        specs.append(TenantSpec(
            name=name, cfg=cfg, profile=prof or None,
            slots=args.slots, max_seq=args.max_seq,
            hbm_budget=budget,
            spill_granule=4096 if budget else None))
    for spec in specs:
        t = rt.add_tenant(spec)
        print(f"tenant {t.name}: slice={t.alloc.profile.name} "
              f"rect={t.alloc.rect} offloaded={list(t.plan.offloaded)} "
              f"partial={[n for n, _ in t.plan.partial]}")

    rng = np.random.default_rng(0)
    for spec in specs:
        rt.submit(spec.name, [
            Request(i, rng.integers(0, spec.cfg.vocab_size,
                                    size=rng.integers(4, 13)).astype(np.int32),
                    args.max_new)
            for i in range(args.requests)])
    report = rt.run()
    for name, row in report["tenants"].items():
        print(f"{name}: profile={row['profile']} tokens={row['tokens_out']} "
              f"tok/s={row['tok_per_s']:.1f} completed={row['completed']} "
              f"truncated={row['truncated']}")
    print(f"pod_utilization={report['pod_utilization']:.2f} "
          f"throttle={report['modeled']['throttle']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--tenants", default=None,
                    help="comma list of arch[:profile] — multi-tenant mode")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--offload-kv", action="store_true")
    ap.add_argument("--hbm-budget", type=int, default=None,
                    help="pin tenant 0's plan budget (bytes) to force offload")
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()
    if args.tenants:
        run_multi(args)
    else:
        run_single(args)


if __name__ == "__main__":
    main()
