"""End-to-end training driver (runs for real on host devices).

    PYTHONPATH=src python -m repro.launch.train --arch gpt2-124m --steps 200

Composes: config → reduced-or-full model → slice allocation (partitioner) →
offload plan (host memory kinds when the slice HBM is overcommitted) →
data pipeline → fault-tolerant runner (checkpoint/restart, straggler
tracking) → AdamW train loop.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import ByteCorpusSource, DataPipeline, SyntheticSource
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model
from repro.optim import adamw
from repro.train import checkpoint as ckpt_mod
from repro.train.fault import FaultTolerantRunner, RunnerConfig, StepFailure


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-124m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced for CPU)")
    ap.add_argument("--corpus", default=None, help="byte-level corpus file")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a step failure (tests restart path)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced().with_(num_layers=min(cfg.num_layers, 4))
    env = host_axis_env()
    model = build_model(cfg, env)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                                total_steps=args.steps)

    source = (ByteCorpusSource(args.corpus) if args.corpus
              else SyntheticSource(cfg.vocab_size, seed=0))
    pipe = DataPipeline(source, args.batch, args.seq)

    def build_step(profile):
        params, _ = model.init(jax.random.PRNGKey(0))
        opt_state = adamw.init(params)
        state = {"params": params, "opt": opt_state}
        latest = ckpt_mod.latest_step(args.ckpt_dir)
        if latest is not None:
            state, _ = ckpt_mod.restore(args.ckpt_dir, state)

        @jax.jit
        def jit_step(state, batch):
            loss, grads = jax.value_and_grad(model.loss_fn)(state["params"],
                                                            batch)
            p, o, met = adamw.update(opt_cfg, grads, state["opt"],
                                     state["params"])
            met["loss"] = loss
            return {"params": p, "opt": o}, met

        def step(state, batch):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, met = jit_step(state, batch)
            return state, {k: float(v) for k, v in met.items()}
        return step, state

    from repro.core.partitioner import StaticPartitioner
    from repro.core.slices import get_profile
    part = StaticPartitioner()
    profile = get_profile("1s.16c")
    part.allocate(profile, tag="train")

    pending_failure = [args.inject_failure_at]  # mutable: fire exactly once

    def fail_hook(step):
        if step == pending_failure[0]:
            pending_failure[0] = -1
            part.fail_chips([(0, 0)])
            raise StepFailure(f"injected chip failure at step {step}")

    runner = FaultTolerantRunner(
        RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        part, profile, build_step,
        get_batch=pipe.batch_at,
        save_state=lambda s: s,
        fail_hook=fail_hook)

    t0 = time.time()
    stats = runner.run(args.steps)
    wall = time.time() - t0
    n = max(1, len(stats.losses))
    print(f"arch={cfg.name} steps={stats.steps_done} wall={wall:.1f}s "
          f"loss {stats.losses[0]:.3f} -> {np.mean(stats.losses[-10:]):.3f} "
          f"restarts={stats.restarts} stragglers={stats.straggler_events} "
          f"repartitions={stats.repartitions}")


if __name__ == "__main__":
    main()
