import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization). Everything below may import jax.

_DOC = """Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes and extract the roofline inputs (deliverables e/f/g).

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(*specs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / HLO collective parse
Artifacts go to benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both [--subprocess]
"""
__doc__ = _DOC

import argparse
import gc
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, ALL_ARCHS, get_config, get_shape
from repro.configs.shapes import DECODE, PREFILL, SHAPES, TRAIN, applicable
from repro.core.hw import GiB
from repro.core.roofline import analyze, model_flops_for
from repro.launch.mesh import make_production_mesh
from repro.models.common import AxisEnv
from repro.models.model_zoo import build_model
from repro.optim import adamw
from repro.train.train_step import TrainStepConfig, make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")


def _sh(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _auto_microbatches(cfg, shape, mesh, budget_bytes=3 * GiB) -> int:
    """Split the batch so per-device layer-boundary activations fit.

    Saved activations ≈ L × B_local × S × D × 2 bytes (bf16, replicated over
    the model axis — see DESIGN.md §5); family factors cover the extra live
    state of MoE capacity buffers and SSD intra-chunk tensors. Grows in
    powers of two while the local batch stays divisible."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_shards = axes.get("data", 1) * axes.get("pod", 1)
    if cfg.name in ("mamba2-130m", "gpt2-124m"):  # fsdp_only: joint batch
        data_shards *= axes.get("model", 1)
    b_local = max(1, shape.global_batch // data_shards)
    factor = {"moe": 2.0, "ssm": 3.0, "hybrid": 3.0}.get(cfg.family, 1.0)
    act = cfg.num_layers * b_local * shape.seq_len * cfg.d_model * 2 * factor
    mb = 1
    while act / mb > budget_bytes and b_local % (2 * mb) == 0:
        mb *= 2
    return mb


def lower_cell(arch: str, shape_name: str, mesh, *, remat: Optional[str] = None,
               compile_: bool = True, overrides: Optional[Dict] = None) -> Dict:
    """Lower (and compile) one cell; returns the roofline record."""
    cfg = get_config(arch)
    if remat:
        cfg = cfg.with_(remat=remat)
    forced_microbatches = None
    grad_compression = False
    if overrides:
        overrides = dict(overrides)
        forced_microbatches = overrides.pop("microbatches", None)
        grad_compression = bool(overrides.pop("grad_compression", False))
        cfg = cfg.with_(**overrides)
    shape = get_shape(shape_name)
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    model = build_model(cfg, mesh)
    t0 = time.time()
    with mesh:
        if shape.kind == TRAIN:
            microbatches = forced_microbatches or _auto_microbatches(cfg, shape, mesh)
            step_fn, shardings = make_train_step(
                model, mesh,
                TrainStepConfig(microbatches=microbatches,
                                grad_compression=grad_compression),
                {k: sp for k, (_, _, sp) in model.batch_specs(shape).items()})
            params, _ = model.abstract_params(mesh)
            opt = jax.eval_shape(adamw.init, params)
            opt = adamw.AdamWState(
                step=jax.ShapeDtypeStruct((), jnp.int32,
                                          sharding=NamedSharding(mesh, P())),
                mu=jax.tree_util.tree_map(
                    lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                       sharding=sh),
                    opt.mu, shardings["params"]),
                nu=jax.tree_util.tree_map(
                    lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                       sharding=sh),
                    opt.nu, shardings["params"]))
            batch = model.input_specs(shape, mesh)
            if grad_compression and "pod" in mesh.axis_names:
                err = jax.tree_util.tree_map(
                    lambda s, sh: jax.ShapeDtypeStruct(
                        s.shape, jnp.float32, sharding=sh),
                    params, shardings["params"])
                lowered = step_fn.lower(params, opt, batch, err)
            else:
                lowered = step_fn.lower(params, opt, batch)
            trip = cfg.num_layers
        elif shape.kind == PREFILL:
            params, specs = model.abstract_params(mesh)

            def prefill(p, b):
                logits, aux, cache = model.forward(p, b, return_cache=True,
                                                   last_token_only=True)
                return logits[:, 0, :], cache

            batch = model.input_specs(shape, mesh)
            env = model.env
            logits_spec = P(env.batch_axes(shape.global_batch),
                            env.tp if model.pol.vocab_sharded else None)
            cache_sh = _sh(mesh, model.cache_specs(shape.global_batch))
            lowered = jax.jit(
                prefill,
                in_shardings=(_sh(mesh, specs), None),
                out_shardings=(NamedSharding(mesh, logits_spec), cache_sh),
            ).lower(params, batch)
            trip = cfg.num_layers
        else:  # DECODE
            params, specs = model.abstract_params(mesh)
            cache = model.abstract_cache(shape.global_batch, shape.seq_len, mesh)
            batch = model.input_specs(shape, mesh)
            env = model.env
            logits_spec = P(env.batch_axes(shape.global_batch),
                            env.tp if model.pol.vocab_sharded else None)
            cache_sh = _sh(mesh, model.cache_specs(shape.global_batch))

            def decode(p, c, b):
                return model.decode(p, c, b)

            lowered = jax.jit(
                decode,
                in_shardings=(_sh(mesh, specs), None, None),
                out_shardings=(NamedSharding(mesh, logits_spec), cache_sh),
                donate_argnums=(1,)).lower(params, cache, batch)
            trip = cfg.num_layers
        t_lower = time.time() - t0

        rec = {"arch": arch, "shape": shape_name,
               "mesh": "x".join(map(str, mesh.devices.shape)),
               "n_devices": mesh.devices.size,
               "lower_s": round(t_lower, 2)}
        if not compile_:
            rec["compiled"] = False
            return rec

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gib": mem.argument_size_in_bytes / GiB,
            "output_gib": mem.output_size_in_bytes / GiB,
            "temp_gib": mem.temp_size_in_bytes / GiB,
            "alias_gib": mem.alias_size_in_bytes / GiB,
            "host_temp_gib": mem.host_temp_size_in_bytes / GiB,
            "host_arg_gib": mem.host_argument_size_in_bytes / GiB,
            # per-device live estimate: args + temps (aliased args reused)
            "per_device_gib": (mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes
                               + mem.output_size_in_bytes
                               - mem.alias_size_in_bytes) / GiB,
        }
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax: one dict per device
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        terms = analyze(cost, hlo, mesh.devices.size,
                        model_flops_for(cfg, shape), loop_trip_count=trip)
        rec["roofline"] = terms.as_dict()
        rec["roofline"]["xla_cost_analysis"] = terms.xla_cost_analysis
        rec["collectives"] = {
            "bytes_by_op": terms.collectives.bytes_by_op,
            "count_by_op": terms.collectives.count_by_op,
            "loop_trips": terms.collectives.scaled_computations[:8],
        }
        if shape.kind == TRAIN:
            rec["microbatches"] = microbatches
        hc = terms.hlo_cost
        rec["top_sites"] = {
            "flops": [{"op": s.op_name[-120:], "value": s.value, "x": s.multiplier}
                      for s in hc.top_flops_sites[:8]],
            "collective": [{"op": s.op_name[-120:], "kind": s.kind,
                            "value": s.value, "x": s.multiplier}
                           for s in hc.top_collective_sites[:8]],
            "bytes": [{"op": s.op_name[-120:], "value": s.value, "x": s.multiplier}
                      for s in hc.top_bytes_sites[:10]],
        }
        rec["compiled"] = True
        del compiled, lowered
        gc.collect()
        return rec


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             remat: Optional[str] = None, overrides: Optional[Dict] = None,
             tag: str = "") -> Dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        rec = lower_cell(arch, shape_name, mesh, remat=remat,
                         overrides=overrides)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    if overrides:
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def summarize(rec: Dict) -> str:
    if rec.get("skipped"):
        return f"SKIP  {rec['arch']:24s} {rec['shape']:12s} ({rec['skipped'][:40]})"
    if rec.get("error"):
        return f"FAIL  {rec['arch']:24s} {rec['shape']:12s} {rec['error'][:80]}"
    r = rec["roofline"]
    m = rec["memory"]
    return (f"OK    {rec['arch']:24s} {rec['shape']:12s} "
            f"mem/dev={m['per_device_gib']:6.2f}GiB "
            f"dom={r['dominant']:10s} step={r['step_time_s']*1e3:8.2f}ms "
            f"mfu={r['roofline_mfu']*100:5.1f}% "
            f"useful={r['useful_flops_ratio']*100:5.1f}% "
            f"[lower {rec.get('lower_s')}s compile {rec.get('compile_s')}s]")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="sweep all assigned (arch × shape) cells")
    ap.add_argument("--include-paper-archs", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. attn_impl=xla_cv)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "true"):
            v = True
        if v in ("False", "false"):
            v = False
        overrides[k] = v

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        archs = ALL_ARCHS if args.include_paper_archs else ASSIGNED_ARCHS
        cells = [(a, s.name) for a in archs for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for mk in meshes:
        out_dir = os.path.join(args.out, mk)
        for arch, shape in cells:
            rec = run_cell(arch, shape, mk, out_dir, remat=args.remat,
                           overrides=overrides or None, tag=args.tag)
            print(summarize(rec), flush=True)
            failures += 1 if rec.get("error") else 0
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
