"""Cluster driver: run a seeded job trace through the ClusterScheduler.

    PYTHONPATH=src python -m repro.launch.cluster --pods 2 --trace-seed 0

Generates a deterministic mixed trace (serving tenants, training runs,
low-utilization batch jobs — Poisson arrivals), schedules it onto N
statically partitioned pods under the chosen placement policy, and prints
the per-job placements plus the aggregate metrics table (utilization, SLO
attainment, fragmentation, modeled energy).

Serving jobs execute through **real** ``SliceRuntime`` tenants (reduced-
scale configs on the host backend, on the exact slice rectangle the
scheduler chose); pass ``--no-execute`` for a pure-model run. ``--showcase``
replays the crafted fragmentation trace from ``cluster/trace.py`` instead
of a generated one — with ``--policy first_fit`` the big job strands, with
the default ``frag_repack`` it places after one repack. The other crafted
stories: ``--elastic-showcase`` (shrink rescues an SLO), ``--preemption-
showcase`` (checkpoint-evicting a low-priority batch job rescues an SLO a
shrink cannot; the victim resumes with its progress preserved), and
``--grow-showcase`` (a running job absorbs freed neighbour chips via
``extend()`` and finishes earlier).
"""
from __future__ import annotations

import argparse

from repro.cluster import (ClusterScheduler, TraceConfig, elastic_showcase,
                           format_metrics, fragmentation_showcase,
                           generate_trace, grow_showcase,
                           preemption_showcase)
from repro.cluster.placement import POLICY_NAMES


def _job_rows(records) -> str:
    header = ("job", "kind", "arch", "prio", "arrive", "profile", "pod",
              "origin", "queue_s", "finish", "slo", "ckpt", "tokens")
    rows = [header]
    for r in sorted(records, key=lambda r: r.job.job_id):
        j = r.job
        ckpt = (f"evict x{r.preemptions}" if r.preemptions and not r.resumes
                else f"resume x{r.resumes}" if r.resumes else "-")
        if r.placed:
            slo = ("-" if r.deadline_s is None else
                   "miss" if not r.finished or r.finish_s > r.deadline_s
                   else "ok")
            rows.append((
                str(j.job_id), j.kind, j.arch, str(j.priority),
                f"{j.arrival_s:.0f}",
                r.profile_name + ("*" if r.shrunk else "")
                + ("+" if r.grown else ""),
                str(r.pod_idx), str(r.origin),
                f"{r.place_s - j.arrival_s:.0f}",
                f"{r.finish_s:.0f}" if r.finished else
                ("suspended" if r.suspended is not None else "running"),
                slo, ckpt, str(r.tokens_out) if r.executed else "-"))
        else:
            rows.append((str(j.job_id), j.kind, j.arch, str(j.priority),
                         f"{j.arrival_s:.0f}",
                         "-", "-", "-", "-", "QUEUED", "miss", ckpt, "-"))
    widths = [max(len(row[i]) for row in rows) for i in range(len(header))]
    return "\n".join("  ".join(c.ljust(w) for c, w in zip(row, widths))
                     for row in rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--jobs", type=int, default=24)
    ap.add_argument("--policy", default="frag_repack", choices=POLICY_NAMES)
    ap.add_argument("--mean-interarrival", type=float, default=45.0)
    ap.add_argument("--horizon", type=float, default=None,
                    help="virtual-time cutoff (s); default: run to drain")
    ap.add_argument("--min-throttle", type=float, default=0.8)
    ap.add_argument("--requests", type=int, default=2,
                    help="live requests per serving job")
    ap.add_argument("--no-execute", action="store_true",
                    help="model serving jobs instead of running SliceRuntime")
    ap.add_argument("--showcase", action="store_true",
                    help="replay the crafted fragmentation-stranding trace "
                         "(forces --pods 1, default horizon 3000 s)")
    ap.add_argument("--elastic-showcase", action="store_true",
                    help="replay the crafted SLO-rescue trace (forces "
                         "--pods 1 --elastic, default horizon 3000 s)")
    ap.add_argument("--preemption-showcase", action="store_true",
                    help="replay the crafted checkpoint-eviction trace "
                         "(forces --pods 1 --priorities)")
    ap.add_argument("--grow-showcase", action="store_true",
                    help="replay the crafted elastic-grow trace (forces "
                         "--pods 1 --grow)")
    ap.add_argument("--elastic", action="store_true",
                    help="allow shrinking running batch jobs to save a "
                         "queued deadline job's SLO (priced as migration)")
    ap.add_argument("--priorities", action="store_true",
                    help="allow checkpoint-evicting lower-priority batch "
                         "jobs for a blocked deadline job (suspend/resume "
                         "priced as checkpoint save/restore volume)")
    ap.add_argument("--grow", action="store_true",
                    help="let running jobs absorb freed neighbour chips "
                         "via the partitioner's extend() (priced as "
                         "migration, power-gated)")
    ap.add_argument("--frozen-durations", action="store_true",
                    help="legacy mode: freeze durations at admission-time "
                         "throttle instead of re-solving on mix changes")
    args = ap.parse_args()

    if args.showcase:
        jobs = fragmentation_showcase()
        args.pods = 1    # the stranding story is a single-pod timeline
        if args.horizon is None:
            args.horizon = 3000.0
    elif args.elastic_showcase:
        jobs = elastic_showcase()
        args.pods = 1
        args.elastic = True
        if args.horizon is None:
            args.horizon = 3000.0
    elif args.preemption_showcase:
        jobs = preemption_showcase()
        args.pods = 1
        args.priorities = True
    elif args.grow_showcase:
        jobs = grow_showcase()
        args.pods = 1
        args.grow = True
    else:
        jobs = generate_trace(TraceConfig(
            seed=args.trace_seed, n_jobs=args.jobs,
            mean_interarrival_s=args.mean_interarrival,
            requests_per_serving=args.requests))
    sched = ClusterScheduler(
        n_pods=args.pods, policy=args.policy,
        min_throttle=args.min_throttle, horizon_s=args.horizon,
        frozen_durations=args.frozen_durations, elastic=args.elastic,
        priorities=args.priorities, grow=args.grow,
        execute_serving=not args.no_execute)
    records, metrics = sched.run(jobs)

    n_exec = sum(1 for r in records if r.executed)
    print(f"# policy={args.policy} pods={args.pods} seed={args.trace_seed} "
          f"jobs={len(jobs)} live_serving_tenants={n_exec}")
    print(_job_rows(records))
    print()
    print(format_metrics([metrics]))


if __name__ == "__main__":
    main()
