"""Cluster driver: run a seeded job trace through the ClusterScheduler.

    PYTHONPATH=src python -m repro.launch.cluster --pods 2 --trace-seed 0

Generates a deterministic mixed trace (serving tenants, training runs,
low-utilization batch jobs — Poisson arrivals), schedules it onto N
statically partitioned pods under the chosen placement policy, and prints
the per-job placements plus the aggregate metrics table (utilization, SLO
attainment, fragmentation, modeled energy).

The elastic surface is the Action API: ``--actions`` is the
``PolicySpec`` allowlist (comma list from ``shrink``, ``preempt``,
``grow``, ``migrate``) and ``--policy {greedy,lookahead,search}`` picks
the ``SchedulerPolicy`` that selects among the allowed actions. The old
``--elastic/--priorities/--grow`` flags are still accepted as deprecated
aliases for ``--actions shrink/preempt/grow``. (``--placement`` chooses
the candidate-enumeration policy, previously called ``--policy``.)

Serving jobs execute through **real** ``SliceRuntime`` tenants (reduced-
scale configs on the host backend, on the exact slice rectangle the
scheduler chose); pass ``--no-execute`` for a pure-model run. The crafted
stories: ``--showcase`` (fragmentation stranding + repack),
``--elastic-showcase`` (a shrink rescues an SLO), ``--preemption-
showcase`` (checkpoint-eviction rescues an SLO a shrink cannot),
``--grow-showcase`` (a running job absorbs freed neighbour chips), and
``--migration-showcase`` (a load-imbalanced two-pod trace where only a
DCN-priced ``MigrateAcrossPods`` meets the deadline),
``--lookahead-showcase`` (no single action rescues the job; the
look-ahead's two-eviction chain does), ``--search-showcase``
(a three-eviction chain beyond the two-step look-ahead's depth; only
the budgeted best-first ``SearchPolicy`` finds it), and
``--reconfigure-showcase`` (a bandwidth-starved deadline job on mi300
pods that no eviction rescues — draining a pod and switching its
partition mode to NPS4 does).

Hardware is selectable: ``--chip {v5e,mi300}`` picks the chip family,
``--mode NAME`` boots every pod in a specific partition mode (default:
the chip's own default), and ``--modes`` prints the chip's partition-mode
table (per-mode FLOP/bandwidth/capacity deltas and slice-ladder floor)
and exits.
"""
from __future__ import annotations

import argparse
import warnings

from repro.core.hw import CHIPS, PodSpec, get_chip, partition_modes
from repro.cluster import (AutoscaleController, AutoscaleSpec,
                           ClusterScheduler, PolicySpec, TraceConfig,
                           elastic_showcase, format_metrics,
                           fragmentation_showcase, generate_trace,
                           grow_showcase, load_csv, lookahead_showcase,
                           migration_showcase, parse_actions,
                           preemption_showcase, reconfigure_showcase,
                           search_showcase, serving_workload,
                           twin_showcase, ACTION_KINDS, CURVE_NAMES,
                           SCHEDULER_POLICY_NAMES)
from repro.cluster.placement import POLICY_NAMES


def _mode_table(chip_name: str) -> str:
    """The partition-mode table ``--modes`` prints: one row per mode with
    its compute/memory split, resource deltas and slice-ladder floor."""
    chip = get_chip(chip_name)
    header = ("mode", "compute", "memory", "flops", "hbm bw", "capacity",
              "min slice", "switch")
    rows = [header]
    for name, m in sorted(partition_modes(chip).items()):
        rows.append((name, m.compute, m.memory,
                     f"x{m.flops_scale:.2f}", f"x{m.hbm_bw_scale:.2f}",
                     f"x{m.hbm_capacity_scale:.2f}",
                     f"{m.min_slice_chips} chips",
                     f"{m.switch_downtime_s:.0f} s"))
    widths = [max(len(r[i]) for r in rows) for i in range(len(header))]
    lines = [f"# partition modes for chip {chip.name!r}"]
    for i, row in enumerate(rows):
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
        if i == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def _job_rows(records) -> str:
    header = ("job", "kind", "arch", "prio", "arrive", "profile", "pod",
              "origin", "queue_s", "finish", "slo", "ckpt", "mig", "tokens")
    rows = [header]
    for r in sorted(records, key=lambda r: r.job.job_id):
        j = r.job
        ckpt = (f"evict x{r.preemptions}" if r.preemptions and not r.resumes
                else f"resume x{r.resumes}" if r.resumes else "-")
        mig = f"dcn x{r.migrations}" if r.migrations else "-"
        if r.placed:
            slo = ("-" if r.deadline_s is None else
                   "miss" if not r.finished or r.finish_s > r.deadline_s
                   else "ok")
            rows.append((
                str(j.job_id), j.kind, j.arch, str(j.priority),
                f"{j.arrival_s:.0f}",
                (r.rung or r.profile_name) + ("*" if r.shrunk else "")
                + ("+" if r.grown else ""),
                str(r.pod_idx), str(r.origin),
                f"{r.place_s - j.arrival_s:.0f}",
                f"{r.finish_s:.0f}" if r.finished else
                ("suspended" if r.suspended is not None else "running"),
                slo, ckpt, mig, str(r.tokens_out) if r.executed else "-"))
        else:
            rows.append((str(j.job_id), j.kind, j.arch, str(j.priority),
                         f"{j.arrival_s:.0f}",
                         "-", "-", "-", "-", "QUEUED", "miss", ckpt, mig,
                         "-"))
    widths = [max(len(row[i]) for row in rows) for i in range(len(header))]
    return "\n".join("  ".join(c.ljust(w) for c, w in zip(row, widths))
                     for row in rows)


def add_policy_args(ap: argparse.ArgumentParser) -> None:
    """The Action-API flags, shared with ``benchmarks/bench_cluster.py``:
    ``--policy``/``--actions`` plus the deprecated boolean aliases."""
    ap.add_argument("--policy", default="greedy",
                    choices=SCHEDULER_POLICY_NAMES,
                    help="action-selection policy: greedy commits the "
                         "cheapest single rescue, lookahead may chain "
                         "two, search runs budgeted best-first over "
                         "deeper enabler chains (cheapest SLO-preserving "
                         "chain wins)")
    ap.add_argument("--actions", default=None,
                    help="comma-separated PolicySpec allowlist from "
                         f"{','.join(ACTION_KINDS)} (default: none)")
    ap.add_argument("--elastic", action="store_true",
                    help="DEPRECATED alias for --actions shrink")
    ap.add_argument("--priorities", action="store_true",
                    help="DEPRECATED alias for --actions preempt")
    ap.add_argument("--grow", action="store_true",
                    help="DEPRECATED alias for --actions grow")


def spec_from_args(args) -> PolicySpec:
    """Fold ``--policy``/``--actions`` (and the deprecated boolean
    aliases, with a DeprecationWarning) into one ``PolicySpec``."""
    actions = set(parse_actions(args.actions) if args.actions else ())
    if args.elastic:
        actions.add("shrink")
    if args.priorities:
        actions.add("preempt")
    if args.grow:
        actions.add("grow")
    if args.elastic or args.priorities or args.grow:
        warnings.warn(
            "--elastic/--priorities/--grow are deprecated; use "
            "--actions shrink,preempt,grow", DeprecationWarning,
            stacklevel=2)
    return PolicySpec(selector=args.policy, actions=tuple(actions))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--jobs", type=int, default=24)
    ap.add_argument("--placement", default="frag_repack",
                    choices=POLICY_NAMES,
                    help="placement (candidate-enumeration) policy")
    ap.add_argument("--chip", default="v5e", choices=sorted(CHIPS),
                    help="chip family the pods are built from "
                         "(core.hw.CHIPS)")
    ap.add_argument("--mode", default=None, metavar="NAME",
                    help="boot every pod in this partition mode (default: "
                         "the chip's default mode; see --modes)")
    ap.add_argument("--modes", action="store_true",
                    help="print the chip's partition-mode table and exit")
    ap.add_argument("--mean-interarrival", type=float, default=45.0)
    ap.add_argument("--horizon", type=float, default=None,
                    help="virtual-time cutoff (s); default: run to drain")
    ap.add_argument("--min-throttle", type=float, default=0.8)
    ap.add_argument("--requests", type=int, default=2,
                    help="live requests per serving job")
    ap.add_argument("--no-execute", action="store_true",
                    help="model serving jobs instead of running SliceRuntime")
    ap.add_argument("--trace-csv", default=None, metavar="PATH",
                    help="replay a public-trace CSV (Philly/Alibaba-style "
                         "schema: submit time, duration, GPU request, job "
                         "class) instead of generating a synthetic trace")
    ap.add_argument("--showcase", action="store_true",
                    help="replay the crafted fragmentation-stranding trace "
                         "(forces --pods 1, default horizon 3000 s)")
    ap.add_argument("--elastic-showcase", action="store_true",
                    help="replay the crafted SLO-rescue trace (forces "
                         "--pods 1 --actions shrink, default horizon 3000 s)")
    ap.add_argument("--preemption-showcase", action="store_true",
                    help="replay the crafted checkpoint-eviction trace "
                         "(forces --pods 1 --actions preempt)")
    ap.add_argument("--grow-showcase", action="store_true",
                    help="replay the crafted elastic-grow trace (forces "
                         "--pods 1 --actions grow)")
    ap.add_argument("--migration-showcase", action="store_true",
                    help="replay the crafted cross-pod migration trace "
                         "(forces --pods 2 --actions migrate): only a "
                         "DCN-priced MigrateAcrossPods meets the deadline")
    ap.add_argument("--autoscale", action="store_true",
                    help="run the SLO-driven hysteresis autoscaler over a "
                         "day of seeded serving load (tenants start small "
                         "and are resized through the priced Action API); "
                         "implies --load-curve diurnal unless given")
    ap.add_argument("--load-curve", default=None, choices=CURVE_NAMES,
                    help="serving load shape for the day-in-the-life run; "
                         "without --autoscale the tenants are provisioned "
                         "fixed at peak size (the comparison baseline) and "
                         "the controller only observes")
    ap.add_argument("--tenants", type=int, default=2,
                    help="serving tenants in the autoscale/load-curve run")
    ap.add_argument("--day", type=float, default=86400.0,
                    help="virtual day length (s) for the autoscale run; "
                         "--horizon overrides")
    ap.add_argument("--lookahead-showcase", action="store_true",
                    help="replay the crafted two-eviction trace (forces "
                         "--pods 1 --policy lookahead --actions "
                         "shrink,preempt)")
    ap.add_argument("--search-showcase", action="store_true",
                    help="replay the crafted three-eviction trace (forces "
                         "--pods 1 --policy search --actions "
                         "shrink,preempt): the rescue chain is one action "
                         "deeper than the two-step look-ahead explores")
    ap.add_argument("--reconfigure-showcase", action="store_true",
                    help="replay the crafted partition-mode trace (forces "
                         "--pods 2 --chip mi300 --actions "
                         "migrate,reconfigure): no eviction rescues the "
                         "bandwidth-starved deadline job; draining a pod "
                         "and switching it to NPS4 does")
    ap.add_argument("--twin", action="store_true",
                    help="enable twin-offload co-execution pricing: the "
                         "PerfModel also emits '+cpuX.XX' rungs that run "
                         "the consumer of spilled state host-side "
                         "(default off; scores are bit-identical without)")
    ap.add_argument("--twin-showcase", action="store_true",
                    help="replay the crafted twin-offload trace (forces "
                         "--pods 1 --actions shrink,preempt): the deadline "
                         "job is only rescuable by shrinking onto a twin "
                         "rung — run with and without --twin to flip the "
                         "SLO verdict")
    add_policy_args(ap)
    ap.add_argument("--frozen-durations", action="store_true",
                    help="legacy mode: freeze durations at admission-time "
                         "throttle instead of re-solving on mix changes")
    args = ap.parse_args()

    if args.modes:
        print(_mode_table(args.chip))
        return

    spec = spec_from_args(args)
    autoscaler = None
    if args.autoscale or args.load_curve:
        # day-in-the-life serving run: the load curves are calibrated the
        # same whichever starting profile is used, so --autoscale (start
        # small, controller resizes) and the bare --load-curve baseline
        # (fixed peak-size slices, controller only observes) face
        # identical traffic. Analytic path: serving is modeled, not
        # executed (a modeled day is millions of requests).
        curve = args.load_curve or "diurnal"
        if args.horizon is None:
            args.horizon = args.day
        jobs, curves = serving_workload(
            n_tenants=args.tenants, curve=curve, horizon_s=args.horizon,
            seed=args.trace_seed,
            start_profile="1s.16c" if args.autoscale else "8s.128c")
        autoscaler = AutoscaleController(
            curves,
            AutoscaleSpec(mode="hysteresis" if args.autoscale
                          else "observe"),
            seed=args.trace_seed)
        args.no_execute = True
    elif args.showcase:
        jobs = fragmentation_showcase()
        args.pods = 1    # the stranding story is a single-pod timeline
        if args.horizon is None:
            args.horizon = 3000.0
    elif args.elastic_showcase:
        jobs = elastic_showcase()
        args.pods = 1
        spec = PolicySpec(selector=spec.selector,
                          actions=tuple(set(spec.actions) | {"shrink"}))
        if args.horizon is None:
            args.horizon = 3000.0
    elif args.preemption_showcase:
        jobs = preemption_showcase()
        args.pods = 1
        spec = PolicySpec(selector=spec.selector,
                          actions=tuple(set(spec.actions) | {"preempt"}))
    elif args.grow_showcase:
        jobs = grow_showcase()
        args.pods = 1
        spec = PolicySpec(selector=spec.selector,
                          actions=tuple(set(spec.actions) | {"grow"}))
    elif args.migration_showcase:
        jobs = migration_showcase()
        args.pods = 2
        spec = PolicySpec(selector=spec.selector,
                          actions=tuple(set(spec.actions) | {"migrate"}))
    elif args.lookahead_showcase:
        jobs = lookahead_showcase()
        args.pods = 1
        spec = PolicySpec(selector="lookahead",
                          actions=tuple(set(spec.actions)
                                        | {"shrink", "preempt"}))
    elif args.search_showcase:
        jobs = search_showcase()
        args.pods = 1
        spec = PolicySpec(selector="search",
                          actions=tuple(set(spec.actions)
                                        | {"shrink", "preempt"}))
    elif args.twin_showcase:
        jobs = twin_showcase()
        args.pods = 1
        spec = PolicySpec(selector=spec.selector,
                          actions=tuple(set(spec.actions)
                                        | {"shrink", "preempt"}))
    elif args.reconfigure_showcase:
        jobs = reconfigure_showcase()
        args.pods = 2
        args.chip = "mi300"
        args.no_execute = True
        spec = PolicySpec(selector=spec.selector,
                          actions=tuple(set(spec.actions)
                                        | {"migrate", "reconfigure"}))
    elif args.trace_csv:
        jobs = load_csv(args.trace_csv,
                        requests_per_serving=args.requests,
                        chip=args.chip)
    else:
        jobs = generate_trace(TraceConfig(
            seed=args.trace_seed, n_jobs=args.jobs,
            mean_interarrival_s=args.mean_interarrival,
            requests_per_serving=args.requests))
    sched = ClusterScheduler(
        n_pods=args.pods, policy=args.placement,
        pod=PodSpec(chip=get_chip(args.chip)),
        min_throttle=args.min_throttle, horizon_s=args.horizon,
        frozen_durations=args.frozen_durations, spec=spec,
        execute_serving=not args.no_execute, autoscaler=autoscaler,
        twin=args.twin, mode=args.mode)
    records, metrics = sched.run(jobs)

    n_exec = sum(1 for r in records if r.executed)
    print(f"# placement={args.placement} policy={spec.selector} "
          f"actions={','.join(spec.actions) or '-'} pods={args.pods} "
          f"chip={args.chip} mode={sched.base_mode} "
          f"seed={args.trace_seed} jobs={len(jobs)} "
          f"live_serving_tenants={n_exec}")
    if metrics.reconfigs:
        modes = ",".join(p.mode for p in sched.pods)
        print(f"# pod modes after run: {modes}")
    print(_job_rows(records))
    print()
    print(format_metrics([metrics]))
    if autoscaler is not None and autoscaler.action_log:
        print()
        print("# autoscale actions (t, tenant, kind):")
        for t, jid, kind in autoscaler.action_log:
            print(f"#   {t:>10,.0f}s  tenant {jid}  {kind}")


if __name__ == "__main__":
    main()
