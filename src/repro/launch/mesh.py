"""Production meshes. Importing this module never touches jax device state —
mesh construction happens only inside the factory functions."""
from __future__ import annotations

from typing import Optional, Tuple


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: ``AxisType`` (and the
    ``axis_types`` kwarg) only exist in newer releases; older ones default
    to auto axes anyway."""
    import jax
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except ImportError:
        return jax.make_mesh(shape, axes)


_make_mesh = make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi-pod adds a leading DCN "pod" axis
    (2 pods = 512 chips). Parameters never shard over "pod" (DESIGN.md §5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_slice_mesh(devices_2d, axis_names: Tuple[str, str] = ("data", "model")):
    """Mesh over one StaticPartitioner slice rectangle."""
    from jax.sharding import Mesh
    return Mesh(devices_2d, axis_names)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host (CPU) devices for tests/examples."""
    import jax
    n = data * model
    avail = len(jax.devices())
    if avail < n:
        raise RuntimeError(
            f"need {n} devices, have {avail}; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"importing jax")
    return _make_mesh((data, model), ("data", "model"))
