"""repro.launch"""
