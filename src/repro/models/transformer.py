"""Decoder-only LM assembly: dense / MoE / VLM / SSM / hybrid.

Structure: scan-over-layers with stacked parameters (keeps HLO size O(1) in
depth — required for 80-layer configs to compile with 512 host devices on one
CPU core), configurable remat per layer, GSPMD sharding via the role system in
``repro.models.common``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import PartitionSpec as P

from repro.configs.base import DENSE, HYBRID, MOE, SSM, VLM, ModelConfig
from repro.models import attention as attn
from repro.models import layers as nn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import AxisEnv, ParamBuilder, ShardingPolicy

PyTree = Any


# ---------------------------------------------------------------------------
# remat policy
# ---------------------------------------------------------------------------
def remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "offload":
        policy = jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=[],
            names_which_can_be_offloaded=["layer_act"],
            offload_src="device", offload_dst="pinned_host")
        return jax.checkpoint(fn, policy=policy)
    # "layer" (default): save nothing inside the layer; scan carries boundaries
    return jax.checkpoint(fn)


def act_sharding(env: AxisEnv, pol: ShardingPolicy, batch: int):
    if pol.profile == "fsdp_only":
        return P(env.batch_axes_joint(batch), None)
    baxes = env.batch_axes(batch)
    seq_ax = env.tp if pol.seq_sharded_acts else None
    return P(baxes, seq_ax)


def unembed_spec(env: AxisEnv, pol: ShardingPolicy, batch: int):
    """Sequence-sharded spec for the unembed input when the vocab dim cannot
    be model-sharded (uneven vocab) — see layers.unembed."""
    if env.size(env.tp) <= 1:
        return None
    if pol.profile == "fsdp_only":
        baxes = env.batch_axes_joint(batch)
        if baxes and env.tp not in baxes:
            # model axis idle for this batch: spread the logits' token dim
            return P(baxes, env.tp)
        return None
    if pol.profile == "tp" and not pol.vocab_sharded and not pol.seq_sharded_acts:
        return P(env.batch_axes(batch), env.tp)
    return None


def constrain(x, env: AxisEnv, pol: ShardingPolicy, batch: int):
    if all(s == 1 for s in env.axis_sizes.values()):
        return x  # single device: no mesh context required
    spec = act_sharding(env, pol, batch)
    # pad spec to rank with Nones
    full = P(*(tuple(spec) + (None,) * (x.ndim - len(spec))))
    return jax.lax.with_sharding_constraint(x, full)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_decoder_only(cfg: ModelConfig, key, pol: ShardingPolicy, env: AxisEnv,
                      *, abstract: bool = False) -> Tuple[PyTree, PyTree]:
    b = ParamBuilder(cfg, pol, env, key, abstract=abstract)
    nn.init_embeddings(b)
    lb = b.child("layers")
    if cfg.family in (DENSE, MOE, VLM):
        attn.init_attention(lb, stacked=True)
        nn.init_norm(lb, "norm1", stacked=True)
        nn.init_norm(lb, "norm2", stacked=True)
        if cfg.family == MOE:
            moe_mod.init_moe(lb, stacked=True)
        else:
            nn.init_mlp(lb, stacked=True)
    elif cfg.family == SSM:
        ssm_mod.init_ssm(lb, stacked=True)
        nn.init_norm(lb, "norm1", stacked=True)
    elif cfg.family == HYBRID:
        ssm_mod.init_ssm(lb, stacked=True)
        nn.init_norm(lb, "norm1", stacked=True)
        sb = b.child("shared")  # one shared attention + MLP block (Zamba2)
        attn.init_attention(sb, stacked=False)
        nn.init_mlp(sb)
        nn.init_norm(sb, "norm1")
        nn.init_norm(sb, "norm2")
    else:
        raise ValueError(cfg.family)
    return b.params, b.specs


# ---------------------------------------------------------------------------
# layer bodies (shared by train / prefill / decode)
# ---------------------------------------------------------------------------
def _attn_mlp_layer(cfg: ModelConfig, lp, x, positions, cache=None,
                    cache_pos=None, ep_spec=None):
    """Standard pre-norm block. Returns (x, new_kv_or_None, aux_loss)."""
    h = nn.apply_norm(cfg, lp, "norm1", x)
    if cache is None:
        a, (k, v) = attn.self_attention(cfg, lp, h, positions)
        new_kv = (k, v)
    else:
        ck, cv = cache
        a, ck, cv = attn.decode_self_attention(cfg, lp, h, ck, cv, cache_pos,
                                               positions)
        new_kv = (ck, cv)
    x = x + a
    h = nn.apply_norm(cfg, lp, "norm2", x)
    if cfg.family == MOE:
        f = moe_mod.apply_moe(cfg, lp, h, ep_spec=ep_spec)
        aux = moe_mod.load_balance_loss(cfg, lp, h)
    else:
        f = nn.apply_mlp(cfg, lp, h)
        aux = jnp.zeros((), jnp.float32)
    return x + f, new_kv, aux


def moe_ep_spec(env: AxisEnv, pol: ShardingPolicy, batch: int):
    """Dispatch-buffer spec (groups, E, C, d): experts on the model axis."""
    if pol.experts_sharded:
        return P(env.batch_axes(batch), env.tp, None, None)
    return None


def _ssm_layer(cfg: ModelConfig, lp, x, cache=None):
    h = nn.apply_norm(cfg, lp, "norm1", x)
    y, new_cache = ssm_mod.apply_ssm(cfg, lp, h, cache)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _embed_input(cfg: ModelConfig, params, batch) -> Tuple[jnp.ndarray, Any]:
    """Returns (x, positions)."""
    if cfg.family == VLM:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        positions = batch["positions"]  # (3, B, S) M-RoPE streams
    else:
        tokens = batch["tokens"]
        S = tokens.shape[1]
        start = batch.get("pos", None)
        if start is None:
            positions = jnp.arange(S)[None, :]
        else:
            start = jnp.asarray(start)
            if start.ndim == 1:  # per-row positions (ragged decode)
                positions = start[:, None] + jnp.arange(S)[None, :]
            else:
                positions = start + jnp.arange(S)[None, :]
        x = nn.embed_tokens(cfg, params, tokens, positions if cfg.learned_pos else None)
    return x, positions


def forward_decoder_only(cfg: ModelConfig, params, batch, env: AxisEnv,
                         pol: ShardingPolicy, *, return_cache: bool = False,
                         last_token_only: bool = False):
    """Full-sequence forward. Returns (logits, aux_loss, cache_or_None)."""
    x, positions = _embed_input(cfg, params, batch)
    B = x.shape[0]
    x = constrain(x, env, pol, B)
    lp_all = params["layers"]

    if cfg.family in (DENSE, MOE, VLM):
        ep = moe_ep_spec(env, pol, B) if cfg.family == MOE else None

        def body(x, lp):
            x = checkpoint_name(x, "layer_act")
            x2, kv, aux = _attn_mlp_layer(cfg, lp, x, positions, ep_spec=ep)
            x2 = constrain(x2, env, pol, B)
            ys = (kv if return_cache else None, aux)
            return x2, ys
        x, (kvs, auxs) = jax.lax.scan(remat_wrap(cfg, body), x, lp_all)
        aux = jnp.sum(auxs)
        cache = None
        if return_cache:
            cache = {"k": kvs[0], "v": kvs[1]}  # (L, B, S, KV, hd)
    elif cfg.family == SSM:
        def body(x, lp):
            x = checkpoint_name(x, "layer_act")
            x2, c = _ssm_layer(cfg, lp, x,
                               ssm_mod.init_ssm_cache(cfg, B, x.dtype)
                               if return_cache else None)
            x2 = constrain(x2, env, pol, B)
            return x2, (c if return_cache else None)
        x, caches = jax.lax.scan(remat_wrap(cfg, body), x, lp_all)
        aux = jnp.zeros((), jnp.float32)
        cache = {"ssm": caches} if return_cache else None
    elif cfg.family == HYBRID:
        x, aux, cache = _forward_hybrid(cfg, params, x, positions, env, pol,
                                        return_cache)
    else:
        raise ValueError(cfg.family)

    if last_token_only:
        x = x[:, -1:, :]  # prefill: only the next-token logits are needed
    logits = nn.unembed(cfg, params, x,
                        seq_shard_spec=unembed_spec(env, pol, B))
    return logits, aux, cache


def _hybrid_split(cfg: ModelConfig):
    n_groups = cfg.num_layers // cfg.attn_every
    tail = cfg.num_layers - n_groups * cfg.attn_every
    return n_groups, tail


def _forward_hybrid(cfg: ModelConfig, params, x, positions, env, pol,
                    return_cache: bool):
    """Zamba2: groups of ``attn_every`` SSM layers, shared attn block between."""
    B = x.shape[0]
    n_groups, tail = _hybrid_split(cfg)
    lp_all = params["layers"]
    sp = params["shared"]
    g = cfg.attn_every

    def split_tree(t):
        head = jax.tree_util.tree_map(
            lambda a: a[: n_groups * g].reshape((n_groups, g) + a.shape[1:]), t)
        rest = jax.tree_util.tree_map(lambda a: a[n_groups * g:], t)
        return head, rest

    lp_groups, lp_tail = split_tree(lp_all)

    def ssm_body(x, lp):
        x = checkpoint_name(x, "layer_act")
        x2, c = _ssm_layer(cfg, lp, x,
                           ssm_mod.init_ssm_cache(cfg, B, x.dtype)
                           if return_cache else None)
        return constrain(x2, env, pol, B), (c if return_cache else None)

    def group_body(x, lp_g):
        x = checkpoint_name(x, "layer_act")
        x, ssm_c = jax.lax.scan(remat_wrap(cfg, ssm_body), x, lp_g)
        a, kv = attn.self_attention(cfg, sp, nn.apply_norm(cfg, sp, "norm1", x),
                                    positions)
        x = x + a
        x = x + nn.apply_mlp(cfg, sp, nn.apply_norm(cfg, sp, "norm2", x))
        x = constrain(x, env, pol, B)
        return x, (ssm_c, kv if return_cache else None)

    # remat the whole group (shared attention included) — without this the
    # shared block's attention residuals are saved per application and blow
    # the activation budget (observed: zamba2 train_4k 24 GiB/dev).
    group_body = remat_wrap(cfg, group_body)

    x, (ssm_groups, kvs) = jax.lax.scan(group_body, x, lp_groups)
    ssm_tail = None
    if tail:
        x, ssm_tail = jax.lax.scan(remat_wrap(cfg, ssm_body), x, lp_tail)

    cache = None
    if return_cache:
        def merge(a, b):
            flat = a.reshape((n_groups * g,) + a.shape[2:])
            return jnp.concatenate([flat, b], axis=0) if tail else flat
        ssm_all = (jax.tree_util.tree_map(merge, ssm_groups, ssm_tail)
                   if tail else jax.tree_util.tree_map(
                       lambda a: a.reshape((n_groups * g,) + a.shape[2:]), ssm_groups))
        cache = {"ssm": ssm_all, "k": kvs[0], "v": kvs[1]}  # kv: (n_groups,B,S,KV,hd)
    return x, jnp.zeros((), jnp.float32), cache


# ---------------------------------------------------------------------------
# decode (single token, layer-scanned over stacked cache)
# ---------------------------------------------------------------------------
def decode_decoder_only(cfg: ModelConfig, params, cache, batch, env: AxisEnv,
                        pol: ShardingPolicy):
    """One-token decode. cache arrays are layer-stacked (L leading).
    Returns (logits, new_cache)."""
    x, positions = _embed_input(cfg, params, batch)
    B = x.shape[0]
    x = constrain(x, env, pol, B)
    pos = batch["pos"]
    lp_all = params["layers"]

    if cfg.family in (DENSE, MOE, VLM):
        def body(x, inp):
            lp, ck, cv = inp
            x2, (ck, cv), _ = _attn_mlp_layer(cfg, lp, x, positions,
                                              cache=(ck, cv), cache_pos=pos)
            return x2, (ck, cv)
        x, (ks, vs) = jax.lax.scan(body, x, (lp_all, cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}
    elif cfg.family == SSM:
        def body(x, inp):
            lp, c = inp
            x2, c2 = _ssm_layer(cfg, lp, x, c)
            return x2, c2
        x, cs = jax.lax.scan(body, x, (lp_all, cache["ssm"]))
        new_cache = {"ssm": cs}
    elif cfg.family == HYBRID:
        x, new_cache = _decode_hybrid(cfg, params, x, positions, pos, cache)
    else:
        raise ValueError(cfg.family)

    logits = nn.unembed(cfg, params, x[:, 0:1, :])[:, 0, :]
    return logits, new_cache


def _decode_hybrid(cfg: ModelConfig, params, x, positions, pos, cache):
    n_groups, tail = _hybrid_split(cfg)
    g = cfg.attn_every
    sp = params["shared"]
    lp_all = params["layers"]

    def take(t, lo, hi):
        return jax.tree_util.tree_map(lambda a: a[lo:hi], t)

    def reshape_g(t):
        return jax.tree_util.tree_map(
            lambda a: a[: n_groups * g].reshape((n_groups, g) + a.shape[1:]), t)

    def ssm_body(x, inp):
        lp, c = inp
        x2, c2 = _ssm_layer(cfg, lp, x, c)
        return x2, c2

    def group_body(x, inp):
        lp_g, ssm_c, ck, cv = inp
        x, ssm_c2 = jax.lax.scan(ssm_body, x, (lp_g, ssm_c))
        h = nn.apply_norm(cfg, sp, "norm1", x)
        a, ck, cv = attn.decode_self_attention(cfg, sp, h, ck, cv, pos, positions)
        x = x + a
        x = x + nn.apply_mlp(cfg, sp, nn.apply_norm(cfg, sp, "norm2", x))
        return x, (ssm_c2, ck, cv)

    lp_groups = reshape_g(lp_all)
    ssm_groups = reshape_g(cache["ssm"])
    x, (ssm_new, ks, vs) = jax.lax.scan(
        group_body, x, (lp_groups, ssm_groups, cache["k"], cache["v"]))
    ssm_new = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups * g,) + a.shape[2:]), ssm_new)
    if tail:
        lp_tail = take(lp_all, n_groups * g, cfg.num_layers)
        ssm_tail = take(cache["ssm"], n_groups * g, cfg.num_layers)
        x, ssm_tail2 = jax.lax.scan(ssm_body, x, (lp_tail, ssm_tail))
        ssm_new = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), ssm_new, ssm_tail2)
    return x, {"ssm": ssm_new, "k": ks, "v": vs}


# ---------------------------------------------------------------------------
# cache construction + sharding specs
# ---------------------------------------------------------------------------
def init_cache_decoder_only(cfg: ModelConfig, batch: int, max_seq: int,
                            dtype=jnp.bfloat16) -> PyTree:
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.family in (DENSE, MOE, VLM):
        shape = (cfg.num_layers, batch, max_seq, KV, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if cfg.family == SSM:
        c = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        return {"ssm": jax.tree_util.tree_map(
            lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), c)}
    if cfg.family == HYBRID:
        n_groups, _ = _hybrid_split(cfg)
        c = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        kv_shape = (n_groups, batch, max_seq, KV, hd)
        return {
            "ssm": jax.tree_util.tree_map(
                lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), c),
            "k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype),
        }
    raise ValueError(cfg.family)


def cache_specs_decoder_only(cfg: ModelConfig, batch: int, env: AxisEnv,
                             pol: ShardingPolicy) -> PyTree:
    """PartitionSpecs matching init_cache: KV caches shard batch over the
    batch axes; the second sharding axis is KV-heads when divisible (keeps
    the per-token cache append shard-local), else the sequence dim."""
    baxes = env.batch_axes(batch)
    if pol.kv_sharded:
        kv_spec = P(None, baxes, None, env.tp, None)
    else:
        kv_spec = P(None, baxes, env.tp, None, None)
    if cfg.family in (DENSE, MOE, VLM):
        return {"k": kv_spec, "v": kv_spec}
    ssm_axis = env.tp if pol.ssm_sharded else None
    ssm_spec = ssm_mod.SSMCache(
        conv=P(None, baxes, None, None),
        state=P(None, baxes, ssm_axis, None, None))
    if cfg.family == SSM:
        return {"ssm": ssm_spec}
    return {"ssm": ssm_spec, "k": kv_spec, "v": kv_spec}
