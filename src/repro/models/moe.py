"""Mixture-of-Experts with capacity-based dispatch (GShard-style semantics).

Memory-sane formulation: rather than materializing the (tokens, E, C) one-hot
dispatch tensor of the GShard einsum (20 TB at 1M tokens), routing is computed
per *group* (= one batch row) with local cumsum + scatter/gather:

  1. top-k experts per token, position-in-expert via cumsum (local per group),
  2. slot = expert*C + position; tokens beyond capacity C are DROPPED
     (classic capacity-factor semantics — the padding/drop waste shows up
     honestly in the roofline "useful FLOPs" ratio),
  3. gather tokens into (E, C, d) buffers, run expert FFNs as batched
     einsum with the expert dim model-sharded (expert parallelism),
  4. scatter-add back with combine weights.

Under GSPMD, step-3's einsum against E-sharded expert weights slices the
(replicated-over-model) dispatch buffers locally per expert shard, and step 4
reduces across the model axis — the same collective volume as a dense TP MLP.

Decode path (S == 1): per-token capacity dispatch degenerates, and decode is
weight-bandwidth-bound anyway, so we compute all experts densely and combine
with router weights — optimal HBM traffic (every expert weight read once),
inflated-but-tiny FLOPs.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder


def init_moe(b: ParamBuilder, *, stacked: bool = False):
    cfg = b.cfg
    L = (cfg.num_layers,) if stacked else ()
    lr = ("none",) if stacked else ()
    E = cfg.num_experts
    b.add("router", L + (cfg.d_model, E), lr + ("d_fsdp", "none"), scale=0.02)
    b.add("w_in", L + (E, cfg.d_model, cfg.d_ff), lr + ("experts", "d_fsdp", "none"))
    if cfg.glu:
        b.add("w_gate", L + (E, cfg.d_model, cfg.d_ff), lr + ("experts", "d_fsdp", "none"))
    b.add("w_out", L + (E, cfg.d_ff, cfg.d_model), lr + ("experts", "none", "d_fsdp"))


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    c = int(cfg.experts_per_token * group_tokens * cfg.capacity_factor
            // cfg.num_experts)
    return max(c, cfg.experts_per_token)


def route(cfg: ModelConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Router logits -> (top-k weights, top-k expert ids). x: (..., d)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    return top_w, top_e


def _dispatch_group(cfg: ModelConfig, x_g, top_w_g, top_e_g, C: int):
    """Per-group dispatch. x_g: (S, d); top_*: (S, k). Returns
    (gathered (E*C, d), slot_token (E*C,), keep_w (S, k), slot (S, k))."""
    S, k = top_e_g.shape
    E = cfg.num_experts
    flat_e = top_e_g.reshape(S * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (S*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                           # pos within expert
    pos = jnp.sum(pos * onehot, axis=-1)                           # (S*k,)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)                # drop -> OOB
    token_id = jnp.arange(S * k) // k
    # slot -> token mapping (scatter; OOB drops)
    slot_token = jnp.full((E * C + 1,), S, jnp.int32)              # S = pad token
    slot_token = slot_token.at[slot].set(token_id, mode="drop")[:E * C]
    x_pad = jnp.concatenate([x_g, jnp.zeros((1, x_g.shape[-1]), x_g.dtype)], axis=0)
    gathered = jnp.take(x_pad, slot_token, axis=0)                 # (E*C, d)
    keep_w = jnp.where(keep.reshape(S, k), top_w_g, 0.0)
    return gathered, slot_token, keep_w, slot.reshape(S, k)


def apply_moe(cfg: ModelConfig, p, x, ep_spec=None):
    """Capacity-dispatch MoE FFN. x: (B, S, d) — one group per batch row;
    long sequences are split into ``moe_group_size`` routing sub-groups so
    capacity buffers stay bounded (32k-prefill would otherwise materialize
    (B, E, 5120, d) dispatch buffers). ``ep_spec``: PartitionSpec for the
    (groups, E, C, d) dispatch buffers — expert dim on "model" keeps them
    expert-parallel instead of replicated."""
    B, S, d = x.shape
    if S == 1:
        return _apply_moe_decode(cfg, p, x)
    gs = cfg.moe_group_size
    if S > gs and S % gs == 0:
        n = S // gs
        out = _apply_moe_grouped(cfg, p, x.reshape(B * n, gs, d), ep_spec)
        return out.reshape(B, S, d)
    return _apply_moe_grouped(cfg, p, x, ep_spec)


def _apply_moe_grouped(cfg: ModelConfig, p, x, ep_spec=None):
    from jax.sharding import PartitionSpec as P
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = capacity(cfg, S)
    tok_spec = P(ep_spec[0], None, None) if ep_spec is not None else None
    if tok_spec is not None:
        # pin the dispatch gather to batch-sharded/d-replicated — without
        # this GSPMD (with a pod axis present) shards the gather's d-dim over
        # "model" and then fully rematerializes to reshard (observed: 64 GiB)
        x = jax.lax.with_sharding_constraint(x, tok_spec)
    top_w, top_e = route(cfg, p, x)                                # (B,S,k)

    gathered, slot_token, keep_w, slot = jax.vmap(
        lambda xg, wg, eg: _dispatch_group(cfg, xg, wg, eg, C)
    )(x, top_w, top_e)
    if tok_spec is not None:
        gathered = jax.lax.with_sharding_constraint(gathered, tok_spec)
    expert_in = gathered.reshape(B, E, C, d)
    if ep_spec is not None:
        expert_in = jax.lax.with_sharding_constraint(expert_in, ep_spec)

    act = jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu
    h = jnp.einsum("becd,edf->becf", expert_in, p["w_in"].astype(x.dtype))
    if cfg.glu:
        g = jnp.einsum("becd,edf->becf", expert_in, p["w_gate"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    out_e = jnp.einsum("becf,efd->becd", h, p["w_out"].astype(x.dtype))
    out_e = out_e.reshape(B, E * C, d)

    # combine: out[s] += w[s,j] * out_e[slot[s,j]]
    def _combine(out_eg, slot_g, w_g):
        out_pad = jnp.concatenate([out_eg, jnp.zeros((1, d), out_eg.dtype)], axis=0)
        sel = jnp.take(out_pad, jnp.minimum(slot_g, E * C), axis=0)  # (S,k,d)
        return jnp.einsum("skd,sk->sd", sel, w_g.astype(out_eg.dtype))
    return jax.vmap(_combine)(out_e, slot, keep_w)


def _apply_moe_decode(cfg: ModelConfig, p, x):
    """Dense-all-experts decode path (weight-bandwidth optimal)."""
    top_w, top_e = route(cfg, p, x)                                # (B,1,k)
    # dense per-token expert weights: sum_j w_j * onehot(e_j)
    w_full = jnp.sum(
        top_w[..., None] * jax.nn.one_hot(top_e, cfg.num_experts,
                                          dtype=jnp.float32), axis=-2)
    act = jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu
    h = jnp.einsum("bsd,edf->besf", x, p["w_in"].astype(x.dtype))
    if cfg.glu:
        g = jnp.einsum("bsd,edf->besf", x, p["w_gate"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    out_e = jnp.einsum("besf,efd->besd", h, p["w_out"].astype(x.dtype))
    return jnp.einsum("besd,bse->bsd", out_e, w_full.astype(x.dtype))


def load_balance_loss(cfg: ModelConfig, p, x) -> jnp.ndarray:
    """Auxiliary load-balancing loss (Switch-style): E * sum(f_e * p_e)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    frac = jnp.mean(jax.nn.one_hot(top_e, cfg.num_experts, dtype=jnp.float32),
                    axis=tuple(range(top_e.ndim)))
    mean_p = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return cfg.num_experts * jnp.sum(frac * mean_p)
