"""Mamba2 — SSD (state-space duality) layer, chunked train/prefill + decode.

Implements the chunked SSD algorithm (arXiv:2405.21060 §6): the sequence is
split into chunks of Q tokens; within a chunk the recurrence is computed in
matmul ("attention-like") form, across chunks a small recurrent state of shape
(heads, head_dim, state) is carried by a lax.scan. This makes training compute
MXU-friendly (the paper's SSD insight) while keeping the inter-chunk scan
cheap — the same structure the Pallas kernel (`repro.kernels.ssd_scan`) tiles
into VMEM.

Layout: x (B, S, nh, hp); A (nh,) negative decay; dt (B, S, nh) softplus-ed;
B_, C_ (B, S, N) with a single state group shared across heads (G=1).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder
from repro.models.layers import rms_norm_vec


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def init_ssm(b: ParamBuilder, *, stacked: bool = False, layers: Optional[int] = None):
    cfg = b.cfg
    nL = layers if layers is not None else cfg.num_layers
    L = (nL,) if stacked else ()
    lr = ("none",) if stacked else ()
    di, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    # z (gate) and x projections — model-shardable (head-aligned)
    b.add("in_zx", L + (cfg.d_model, 2 * di), lr + ("d_fsdp", "ssm_inner"))
    # B, C, dt projections — replicated columns (state shared across heads)
    b.add("in_bcdt", L + (cfg.d_model, 2 * N + nh), lr + ("d_fsdp", "none"))
    b.add("conv_x", L + (cfg.conv_width, di), lr + ("none", "ssm_inner"))
    b.add("conv_bc", L + (cfg.conv_width, 2 * N), lr + ("none", "none"))
    b.add("A_log", L + (nh,), lr + ("ssm_inner",), init="zeros")
    b.add("dt_bias", L + (nh,), lr + ("ssm_inner",), init="zeros")
    b.add("D_skip", L + (nh,), lr + ("ssm_inner",), init="ones")
    b.add("ssm_norm", L + (di,), lr + ("ssm_inner",), init="ones")
    b.add("out_proj", L + (di, cfg.d_model), lr + ("ssm_inner", "d_fsdp"))


class SSMCache(NamedTuple):
    conv: jnp.ndarray  # (B, W-1, di + 2N) rolling conv window
    state: jnp.ndarray  # (B, nh, hp, N)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    di, N = cfg.d_inner, cfg.ssm_state
    nh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    return SSMCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, di + 2 * N), dtype),
        state=jnp.zeros((batch, nh, hp, N), jnp.float32),
    )


# ---------------------------------------------------------------------------
# projections shared by train & decode
# ---------------------------------------------------------------------------
def _proj_in(cfg: ModelConfig, p, u):
    """u: (B,S,D) -> z (B,S,di), xbc (B,S,di+2N) pre-conv, dt (B,S,nh)."""
    di, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zx = jnp.einsum("bsd,dn->bsn", u, p["in_zx"].astype(u.dtype))
    z, x = zx[..., :di], zx[..., di:]
    bcdt = jnp.einsum("bsd,dn->bsn", u, p["in_bcdt"].astype(u.dtype))
    bc, dt = bcdt[..., :2 * N], bcdt[..., 2 * N:]
    xbc = jnp.concatenate([x, bc], axis=-1)
    return z, xbc, dt


def _causal_conv(cfg: ModelConfig, p, xbc, cache: Optional[jnp.ndarray] = None):
    """Depthwise causal conv width W over (B,S,C); optional cache prefix."""
    W = cfg.conv_width
    kern = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1).astype(xbc.dtype)
    if cache is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = cache.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1], :] * kern[i] for i in range(W))
    new_cache = full[:, -(W - 1):, :]
    return jax.nn.silu(out), new_cache


# ---------------------------------------------------------------------------
# chunked SSD (train / prefill)
# ---------------------------------------------------------------------------
def ssd_chunked(x, dt, A, B_, C_, chunk: int,
                init_state: Optional[jnp.ndarray] = None):
    """SSD scan. x: (B,S,nh,hp); dt: (B,S,nh) (already softplus+bias);
    A: (nh,) negative; B_, C_: (B,S,N). Returns (y, final_state).
    State: (B, nh, hp, N), fp32."""
    Bb, S, nh, hp = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:  # pad to a chunk multiple; dt=0 makes padding a no-op
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    xf = x.astype(jnp.float32).reshape(Bb, nc, Q, nh, hp)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, Q, nh)
    Bf = B_.astype(jnp.float32).reshape(Bb, nc, Q, N)
    Cf = C_.astype(jnp.float32).reshape(Bb, nc, Q, N)
    Af = A.astype(jnp.float32)

    dA = dtf * Af  # (B,nc,Q,nh) negative increments
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    seg_total = cum[:, :, -1, :]                       # (B,nc,nh)

    # intra-chunk (matmul form): L[i,j] = exp(cum_i - cum_j) for i>=j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,Q,Q,nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    G = jnp.einsum("bcqn,bckn->bcqk", Cf, Bf)                  # (B,nc,Q,Q)
    M = G[..., None] * Lmat                                    # (B,nc,Q,Q,nh)
    xdt = xf * dtf[..., None]                                  # (B,nc,Q,nh,hp)
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", M, xdt)

    # per-chunk input state contribution: sum_j exp(total - cum_j) dt_j B_j x_j
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)     # (B,nc,Q,nh)
    S_chunk = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                         Bf, decay_to_end * dtf, xf)           # (B,nc,nh,hp,N)

    # inter-chunk recurrence
    def body(s, inp):
        seg, sc = inp                                          # (B,nh), (B,nh,hp,N)
        s_out = s                                              # state entering chunk
        s = s * jnp.exp(seg)[:, :, None, None] + sc
        return s, s_out

    s0 = (jnp.zeros((Bb, nh, hp, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    seg_t = jnp.moveaxis(seg_total, 1, 0)                      # (nc,B,nh)
    sc_t = jnp.moveaxis(S_chunk, 1, 0)                         # (nc,B,nh,hp,N)
    final_state, states_in = jax.lax.scan(body, s0, (seg_t, sc_t))
    states_in = jnp.moveaxis(states_in, 0, 1)                  # (B,nc,nh,hp,N)

    # inter-chunk output: y_off = C_i * exp(cum_i) @ state_in
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                       Cf, states_in, jnp.exp(cum))
    y = (y_diag + y_off).reshape(Bb, S, nh, hp)[:, :S_orig]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One-token recurrence. state: (B,nh,hp,N); x_t: (B,nh,hp);
    dt_t: (B,nh); B_t, C_t: (B,N)."""
    dA = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))   # (B,nh)
    upd = jnp.einsum("bn,bh,bhp->bhpn", B_t.astype(jnp.float32),
                     dt_t.astype(jnp.float32), x_t.astype(jnp.float32))
    state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C_t.astype(jnp.float32))
    return state, y.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# full layer
# ---------------------------------------------------------------------------
def apply_ssm(cfg: ModelConfig, p, u, cache: Optional[SSMCache] = None
              ) -> Tuple[jnp.ndarray, Optional[SSMCache]]:
    """Mamba2 block. u: (B,S,D). If ``cache`` given and S==1, decode path."""
    di, N, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _proj_in(cfg, p, u)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))

    decode = cache is not None and u.shape[1] == 1
    xbc_conv, new_conv = _causal_conv(cfg, p, xbc,
                                      cache.conv if decode else None)
    x = xbc_conv[..., :di]
    B_ = xbc_conv[..., di:di + N]
    C_ = xbc_conv[..., di + N:]
    xh = x.reshape(x.shape[0], x.shape[1], nh, hp)

    if decode:
        state, y = ssd_decode_step(cache.state, xh[:, 0], dt[:, 0],
                                   A, B_[:, 0], C_[:, 0])
        y = y[:, None]
        new_cache = SSMCache(conv=new_conv, state=state)
    else:
        y, state = ssd_chunked(xh, dt, A, B_, C_, cfg.ssm_chunk,
                               init_state=cache.state if cache else None)
        new_cache = SSMCache(conv=new_conv, state=state) if cache is not None else None

    y = y + xh * p["D_skip"].astype(jnp.float32)[None, None, :, None].astype(y.dtype)
    y = y.reshape(u.shape[0], u.shape[1], di)
    y = rms_norm_vec(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                     p["ssm_norm"], cfg.norm_eps)
    return jnp.einsum("bsn,nd->bsd", y, p["out_proj"].astype(y.dtype)), new_cache
