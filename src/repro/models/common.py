"""Sharding policy, axis environment, and parameter construction helpers.

Design (see DESIGN.md §5):
  * mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
    multi-pod. Parameters never shard over "pod" (pure DP across pods, grad
    all-reduce over DCN once per step); batch shards over ("pod", "data").
  * parameters are FSDP-sharded over "data" on their d_model-sized dim and
    tensor-sharded over "model" on their heads/ffn/experts/vocab dim
    (ZeRO-3: XLA all-gathers one layer slice per scan iteration).
  * archs whose head counts do not divide the model axis (starcoder2: 36,
    whisper: 20) use sequence-parallel attention; tiny archs (mamba2-130m,
    gpt2-124m) use pure-FSDP ("fsdp_only") with model-axis-replicated compute
    — the resulting waste is *the paper's subject* and shows up honestly in
    the roofline table.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any


# ---------------------------------------------------------------------------
# axis environment
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AxisEnv:
    """Logical → physical mesh-axis mapping for one mesh."""
    mesh_axes: Tuple[str, ...]           # e.g. ("pod", "data", "model")
    axis_sizes: Dict[str, int] = field(default_factory=dict)

    @property
    def fsdp(self) -> str:
        return "data"

    @property
    def tp(self) -> str:
        return "model"

    @property
    def has_pod(self) -> bool:
        return "pod" in self.mesh_axes

    def batch_axes(self, global_batch: int) -> Optional[Tuple[str, ...]]:
        """Largest prefix of ("pod","data") that evenly divides the batch."""
        axes: Tuple[str, ...] = ("pod", "data") if self.has_pod else ("data",)
        size = math.prod(self.axis_sizes[a] for a in axes)
        if global_batch % size == 0:
            return axes
        if "data" in axes and global_batch % self.axis_sizes["data"] == 0:
            return ("data",)
        return None  # replicate (e.g. long_500k batch=1)

    def batch_axes_joint(self, global_batch: int) -> Optional[Tuple[str, ...]]:
        """Largest divisible prefix of ("pod","data","model") — used by the
        fsdp_only profile, where the model axis carries no tensor parallelism
        and would otherwise replicate every activation."""
        base = ("pod", "data", "model") if self.has_pod else ("data", "model")
        for end in range(len(base), 0, -1):
            axes = base[:end]
            size = math.prod(self.axis_sizes[a] for a in axes)
            if global_batch % size == 0:
                return axes
        return None

    def size(self, axis: str) -> int:
        return self.axis_sizes[axis]

    @staticmethod
    def from_mesh(mesh) -> "AxisEnv":
        return AxisEnv(tuple(mesh.axis_names),
                       {a: int(s) for a, s in zip(mesh.axis_names, mesh.devices.shape)})


def host_axis_env(model_parallel: int = 1) -> AxisEnv:
    """Single-host env for smoke tests (1 device)."""
    return AxisEnv(("data", "model"), {"data": 1, "model": model_parallel})


# ---------------------------------------------------------------------------
# sharding policy
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardingPolicy:
    profile: str            # "tp" | "fsdp_only"
    head_sharded: bool      # q-heads divisible by model axis
    kv_sharded: bool        # kv-heads divisible by model axis
    vocab_sharded: bool
    ffn_sharded: bool
    experts_sharded: bool
    ssm_sharded: bool       # ssm heads divisible
    seq_parallel_attn: bool # used when heads are not shardable
    seq_residuals: bool = False  # Megatron SP: S-sharded layer boundaries

    @property
    def seq_sharded_acts(self) -> bool:
        return self.seq_parallel_attn or self.seq_residuals


def make_policy(cfg: ModelConfig, env: AxisEnv) -> ShardingPolicy:
    tp = env.size(env.tp)
    if cfg.name in ("mamba2-130m", "gpt2-124m") and tp > 1:
        profile = "fsdp_only"
    else:
        profile = "tp"
    if profile == "fsdp_only" or tp == 1:
        return ShardingPolicy(profile, False, False, False, False, False,
                              False, False, False)
    head_ok = cfg.num_heads > 0 and cfg.num_heads % tp == 0
    kv_ok = cfg.num_kv_heads > 0 and cfg.num_kv_heads % tp == 0
    vocab_ok = cfg.vocab_size % tp == 0
    ffn_ok = cfg.d_ff > 0 and cfg.d_ff % tp == 0
    exp_ok = cfg.num_experts > 0 and cfg.num_experts % tp == 0
    ssm_ok = cfg.ssm_state > 0 and cfg.ssm_heads % tp == 0
    seq_par = cfg.num_heads > 0 and not head_ok
    if seq_par:
        # sequence-parallel archs (starcoder2: 36 heads, whisper: 20) keep
        # activations S-sharded over "model"; weights stay data-FSDP only so
        # every einsum is token-local (KV all-gather is the only attn comm).
        kv_ok = vocab_ok = ffn_ok = False
    return ShardingPolicy(profile, head_ok, kv_ok, vocab_ok, ffn_ok, exp_ok,
                          ssm_ok, seq_par,
                          seq_residuals=cfg.seq_shard_residuals and not seq_par)


# dim roles used by param constructors
def role_axis(role: str, pol: ShardingPolicy, env: AxisEnv):
    """Mesh axis (or None) for a logical dim role."""
    if pol.profile == "fsdp_only":
        return (env.fsdp, env.tp) if role == "d_fsdp" else None
    table = {
        "d_fsdp": env.fsdp,
        "vocab": env.tp if pol.vocab_sharded else None,
        "qout": env.tp if pol.head_sharded else None,
        "kvout": env.tp if pol.kv_sharded else None,
        "ffn": env.tp if pol.ffn_sharded else None,
        "experts": env.tp if pol.experts_sharded else None,
        "ssm_inner": env.tp if pol.ssm_sharded else None,
        "none": None,
    }
    return table[role]


def spec_of(roles: Tuple[str, ...], pol: ShardingPolicy, env: AxisEnv) -> P:
    return P(*[role_axis(r, pol, env) for r in roles])


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------
class ParamBuilder:
    """Builds parallel (params, specs) pytrees.

    All constructors take dim-role tuples so the PartitionSpec is declared at
    the same site as the shape — keeps sharding rules impossible to desync.
    """

    def __init__(self, cfg: ModelConfig, pol: ShardingPolicy, env: AxisEnv, key,
                 *, abstract: bool = False):
        self.cfg = cfg
        self.pol = pol
        self.env = env
        self._key = key
        self.abstract = abstract
        self.params: Dict[str, Any] = {}
        self.specs: Dict[str, Any] = {}

    def _next_key(self):
        if self.abstract:
            return None
        self._key, sub = jax.random.split(self._key)
        return sub

    def add(self, name: str, shape: Tuple[int, ...], roles: Tuple[str, ...],
            *, scale: Optional[float] = None, init: str = "normal"):
        assert len(shape) == len(roles), (name, shape, roles)
        dtype = jnp.dtype(self.cfg.param_dtype)
        if self.abstract:
            arr = jax.ShapeDtypeStruct(shape, dtype)
        elif init == "zeros":
            arr = jnp.zeros(shape, dtype)
        elif init == "ones":
            arr = jnp.ones(shape, dtype)
        else:
            if scale is None:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / math.sqrt(max(fan_in, 1))
            arr = scale * jax.random.normal(self._next_key(), shape, dtype)
        self.params[name] = arr
        self.specs[name] = spec_of(roles, self.pol, self.env)

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self.cfg, self.pol, self.env, self._next_key(),
                           abstract=self.abstract)
        self.params[name] = sub.params
        self.specs[name] = sub.specs
        return sub


def stack_roles(roles: Tuple[str, ...]) -> Tuple[str, ...]:
    """Prepend the scanned layer dim (never sharded)."""
    return ("none",) + tuple(roles)


# ---------------------------------------------------------------------------
# misc numeric helpers shared across model files
# ---------------------------------------------------------------------------
def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def cast_tree(tree: PyTree, dtype) -> PyTree:
    def _c(x):
        if isinstance(x, jax.Array) or hasattr(x, "dtype"):
            if jnp.issubdtype(x.dtype, jnp.floating):
                return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_c, tree)


def tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "dtype"))
