"""Shared neural blocks: norms, RoPE / M-RoPE, MLPs, embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------
def init_norm(b: ParamBuilder, name: str, dim_role: str = "none",
              *, stacked: bool = False):
    cfg = b.cfg
    L = (cfg.num_layers,) if stacked else ()
    lr = ("none",) if stacked else ()
    b.add(f"{name}_scale", L + (cfg.d_model,), lr + (dim_role,), init="ones")
    if cfg.norm == "layernorm":
        b.add(f"{name}_bias", L + (cfg.d_model,), lr + (dim_role,), init="zeros")


def apply_norm(cfg: ModelConfig, p, name: str, x):
    scale = p[f"{name}_scale"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * scale + p[f"{name}_bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * scale
    return y.astype(x.dtype)


def rms_norm_vec(x, scale, eps: float = 1e-5):
    """RMSNorm over the last dim with an explicit scale vector (SSM gated norm)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings (+ Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_thw, theta: float, sections=(2, 3, 3)):
    """Qwen2-VL multimodal RoPE.

    positions_thw: (3, ..., S) — temporal / height / width position streams.
    The hd/2 frequency dims are split into three contiguous groups in ratio
    ``sections`` (2:3:3 following the 16:24:24 split of hd=128), each rotated
    by its own position stream.
    """
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections:
        acc += s
        bounds.append(half * acc // total)
    freqs = rope_freqs(hd, theta)                       # (half,)
    dim_idx = jnp.arange(half)
    stream = jnp.sum(dim_idx[None, :] >= jnp.asarray([0] + bounds[:-1])[:, None], axis=0) - 1
    # per-dim position: pick the stream's positions
    pos = jnp.take(positions_thw, stream, axis=0)       # (half, ..., S) -> moveaxis
    pos = jnp.moveaxis(pos, 0, -1)                      # (..., S, half)
    ang = pos.astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or plain 2-matmul)
# ---------------------------------------------------------------------------
def init_mlp(b: ParamBuilder, stacked: bool = False):
    cfg = b.cfg
    L = (cfg.num_layers,) if stacked else ()
    lr = ("none",) if stacked else ()
    b.add("w_in", L + (cfg.d_model, cfg.d_ff), lr + ("d_fsdp", "ffn"))
    if cfg.glu:
        b.add("w_gate", L + (cfg.d_model, cfg.d_ff), lr + ("d_fsdp", "ffn"))
    b.add("w_out", L + (cfg.d_ff, cfg.d_model), lr + ("ffn", "d_fsdp"))
    if cfg.use_bias:
        b.add("b_in", L + (cfg.d_ff,), lr + ("ffn",), init="zeros")
        if cfg.glu:
            b.add("b_gate", L + (cfg.d_ff,), lr + ("ffn",), init="zeros")
        b.add("b_out", L + (cfg.d_model,), lr + ("none",), init="zeros")


def _act(cfg: ModelConfig, x):
    return jax.nn.gelu(x) if cfg.act == "gelu" else jax.nn.silu(x)


def apply_mlp(cfg: ModelConfig, p, x):
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(x.dtype))
    if cfg.use_bias:
        h = h + p["b_in"].astype(x.dtype)
    if cfg.glu:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
        if cfg.use_bias:
            g = g + p["b_gate"].astype(x.dtype)
        h = _act(cfg, g) * h
    else:
        h = _act(cfg, h)
    out = jnp.einsum("...f,fd->...d", h, p["w_out"].astype(x.dtype))
    if cfg.use_bias:
        out = out + p["b_out"].astype(x.dtype)
    return out


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------
def init_embeddings(b: ParamBuilder):
    cfg = b.cfg
    b.add("tok_embed", (cfg.vocab_size, cfg.d_model), ("vocab", "d_fsdp"), scale=0.02)
    if cfg.learned_pos:
        b.add("pos_embed", (cfg.max_position, cfg.d_model), ("none", "d_fsdp"),
              scale=0.02)
    init_norm(b, "final_norm")
    if not cfg.tie_embeddings:
        b.add("lm_head", (cfg.d_model, cfg.vocab_size), ("d_fsdp", "vocab"))


def embed_tokens(cfg: ModelConfig, p, tokens, positions: Optional[jnp.ndarray] = None):
    x = jnp.take(p["tok_embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.learned_pos:
        if positions is None:
            positions = jnp.arange(tokens.shape[-1])[None, :]
        x = x + jnp.take(p["pos_embed"], positions, axis=0).astype(x.dtype)
    return x


def unembed(cfg: ModelConfig, p, x, seq_shard_spec=None):
    x = apply_norm(cfg, p, "final_norm", x)
    if seq_shard_spec is not None and x.shape[-2] > 1:
        # vocab not model-shardable (uneven) -> shard the TOKEN dim of the
        # logits instead; the loss is per-token so this is communication-free
        # and caps the (B, S, V) fp32 buffer at 1/model_axis per device.
        x = jax.lax.with_sharding_constraint(x, seq_shard_spec)
    w = p["tok_embed"].T if cfg.tie_embeddings else p["lm_head"]
    return jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
