"""Attention: XLA flash (scan + online softmax), GQA, RoPE/M-RoPE, decode.

Two implementations share one signature:
  * ``attn_impl="xla"`` — a lax.scan over KV chunks with online softmax; this
    is the path used by the dry-run and all training lowering. Peak memory is
    O(Sq * chunk) instead of O(Sq * Sk), which is what makes the 32k-prefill
    cells compile with sane footprints.
  * ``attn_impl="pallas"`` — the TPU kernel in ``repro.kernels.flash_attention``
    (validated against ``repro.kernels.ref`` in interpret mode).

GQA is handled by gather-expanding K/V head-wise (a local gather — verified to
introduce zero collectives when Q-heads are model-sharded and KV replicated).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder
from repro.models.layers import apply_mrope, apply_rope, rms_norm_vec


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------
def init_attention(b: ParamBuilder, *, stacked: bool = False, prefix: str = "",
                   cross: bool = False):
    cfg = b.cfg
    L = (cfg.num_layers,) if stacked else ()
    lr = ("none",) if stacked else ()
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b.add(prefix + "wq", L + (cfg.d_model, H * hd), lr + ("d_fsdp", "qout"))
    b.add(prefix + "wk", L + (cfg.d_model, KV * hd), lr + ("d_fsdp", "kvout"))
    b.add(prefix + "wv", L + (cfg.d_model, KV * hd), lr + ("d_fsdp", "kvout"))
    b.add(prefix + "wo", L + (H * hd, cfg.d_model), lr + ("qout", "d_fsdp"))
    if cfg.use_bias:
        b.add(prefix + "bq", L + (H * hd,), lr + ("qout",), init="zeros")
        b.add(prefix + "bk", L + (KV * hd,), lr + ("kvout",), init="zeros")
        b.add(prefix + "bv", L + (KV * hd,), lr + ("kvout",), init="zeros")
        b.add(prefix + "bo", L + (cfg.d_model,), lr + ("none",), init="zeros")
    if cfg.use_qk_norm and not cross:
        b.add(prefix + "q_norm", L + (hd,), lr + ("none",), init="ones")
        b.add(prefix + "k_norm", L + (hd,), lr + ("none",), init="ones")


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------
def _rope(cfg: ModelConfig, x, positions, use_rope: bool):
    if not use_rope:
        return x
    if cfg.mrope:
        return apply_mrope(x, positions, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_theta)


def q_proj(cfg: ModelConfig, p, x, positions, *, prefix: str = "",
           use_rope: bool = True):
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,dn->bsn", x, p[prefix + "wq"].astype(x.dtype))
    if cfg.use_bias:
        q = q + p[prefix + "bq"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    if cfg.use_qk_norm:
        q = rms_norm_vec(q, p[prefix + "q_norm"], cfg.norm_eps)
    return _rope(cfg, q, positions, use_rope and not cfg.learned_pos)


def kv_proj(cfg: ModelConfig, p, x, positions, *, prefix: str = "",
            use_rope: bool = True):
    B, S, _ = x.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dn->bsn", x, p[prefix + "wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dn->bsn", x, p[prefix + "wv"].astype(x.dtype))
    if cfg.use_bias:
        k = k + p[prefix + "bk"].astype(x.dtype)
        v = v + p[prefix + "bv"].astype(x.dtype)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.use_qk_norm:
        k = rms_norm_vec(k, p[prefix + "k_norm"], cfg.norm_eps)
    k = _rope(cfg, k, positions, use_rope and not cfg.learned_pos)
    return k, v


def out_proj(cfg: ModelConfig, p, attn, *, prefix: str = ""):
    B, S = attn.shape[:2]
    out = jnp.einsum("bsn,nd->bsd", attn.reshape(B, S, -1),
                     p[prefix + "wo"].astype(attn.dtype))
    if cfg.use_bias:
        out = out + p[prefix + "bo"].astype(attn.dtype)
    return out


def expand_kv(k, num_heads: int):
    """Gather-expand GQA KV heads to ``num_heads`` (local when KV replicated)."""
    KV = k.shape[2]
    if KV == num_heads:
        return k
    mapping = jnp.arange(num_heads) // (num_heads // KV)
    return k[:, :, mapping, :]


# ---------------------------------------------------------------------------
# flash attention (scan over KV chunks, online softmax)
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool, q_offset=0,
                    kv_len: Optional[jnp.ndarray] = None,
                    chunk: int = 1024, scale: Optional[float] = None):
    """q: (B,Sq,H,hd); k,v: (B,Sk,H,hd) (already head-expanded).

    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``kv_len``: dynamic count of valid KV entries (mask the tail).
    Differentiable (jax differentiates through the scan); pair with remat at
    the layer level for training.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    chunk = min(chunk, Sk)
    if Sk % chunk:
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = jnp.asarray(Sk, jnp.int32)
    n_chunks = k.shape[1] // chunk

    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)   # (B,H,Sq,hd)
    kc = k.transpose(0, 2, 1, 3).reshape(B, H, n_chunks, chunk, hd)
    vc = v.transpose(0, 2, 1, 3).reshape(B, H, n_chunks, chunk, hd)
    kc = jnp.moveaxis(kc, 2, 0)                                   # (nc,B,H,ck,hd)
    vc = jnp.moveaxis(vc, 2, 0)

    pos_q = q_offset + jnp.arange(Sq)

    def body(carry, inputs):
        m, l, acc = carry
        j, kj, vj = inputs
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj.astype(jnp.float32))
        pos_k = j * chunk + jnp.arange(chunk)
        mask = jnp.ones((1, 1, Sq, chunk), bool)
        if causal:
            mask &= (pos_q[:, None] >= pos_k[None, :])[None, None]
        if kv_len is not None:
            kvl = jnp.asarray(kv_len)
            if kvl.ndim == 0:
                mask &= (pos_k < kvl)[None, None, None, :]
            else:  # per-row valid lengths (ragged continuous batching)
                mask &= (pos_k[None, :] < kvl[:, None])[:, None, None, :]
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> use safe m
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)            # (B,Sq,H,hd)


def decode_attention(q, k, v, *, kv_len=None, scale: Optional[float] = None):
    """Single-pass attention for Sq == 1 over a (possibly S-sharded) cache.

    No KV chunk scan: with the decode cache sequence-sharded over "model",
    a chunked scan forces GSPMD to all-gather the cache per chunk; the
    single-pass einsum keeps scores S-sharded and reduces only the (tiny)
    softmax stats and the (B,1,H,hd) output across the model axis.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    # mixed-precision dots (bf16 in, f32 accumulate) — an explicit
    # .astype(f32) on the cache slice gets hoisted out of the layer scan by
    # XLA and materializes the WHOLE stacked cache in f32 (observed: +6 GiB
    # on phi3-mini decode_32k); preferred_element_type avoids the convert.
    qs = (q.astype(jnp.float32) * scale).astype(q.dtype)
    s = jax.lax.dot_general(qs, k, (((3,), (3,)), ((0, 2), (0, 2))),
                            preferred_element_type=jnp.float32)  # (B,H,Sq,Sk)
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        pos_k = jnp.arange(Sk)
        if kvl.ndim == 0:
            mask = (pos_k < kvl)[None, None, None, :]
        else:
            mask = (pos_k[None, :] < kvl[:, None])[:, None, None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)   # (B,H,Sq,Sk)
    out = jax.lax.dot_general(p, v, (((3,), (1,)), ((0, 1), (0, 2))),
                              preferred_element_type=jnp.float32)  # (B,H,Sq,hd)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# flash attention with custom VJP (hillclimb: §Perf iteration 1)
#
# The plain scan implementation lets jax's reverse-mode save per-chunk score
# residuals — a (chunks, B, H, Sq, chunk) stack per layer that the dry-run
# shows as the dominant HBM-traffic site in training (read-modify-write
# convert fusions ×layers×microbatches). The custom VJP saves only the
# (B, H, Sq) logsumexp stats and recomputes p per chunk in the backward —
# the textbook flash-attention backward, here at the XLA level.
# ---------------------------------------------------------------------------
def _flash_fwd_stats(q, k, v, *, causal, chunk, scale):
    """Like flash_attention but also returns lse = m + log(l)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    assert Sk % chunk == 0, (Sk, chunk)
    n_chunks = Sk // chunk
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)
    kc = jnp.moveaxis(k.transpose(0, 2, 1, 3).reshape(B, H, n_chunks, chunk, hd), 2, 0)
    vc = jnp.moveaxis(v.transpose(0, 2, 1, 3).reshape(B, H, n_chunks, chunk, hd), 2, 0)
    pos_q = jnp.arange(Sq)

    def body(carry, inputs):
        m, l, acc = carry
        j, kj, vj = inputs
        s = jax.lax.dot_general(qf, kj, (((3,), (3,)), ((0, 1), (0, 1))),
                                preferred_element_type=jnp.float32)
        if causal:
            pos_k = j * chunk + jnp.arange(chunk)
            s = jnp.where((pos_q[:, None] >= pos_k[None, :])[None, None],
                          s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        if causal:
            p = jnp.where((pos_q[:, None] >= pos_k[None, :])[None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jax.lax.dot_general(
            p.astype(vj.dtype), vj, (((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(n_chunks), kc, vc))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).transpose(0, 2, 1, 3)
    lse = jnp.where(jnp.isfinite(m), m, 0.0) + jnp.log(jnp.maximum(l, 1e-30))
    return out.astype(q.dtype), lse  # lse: (B, H, Sq)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_cv(q, k, v, causal: bool, chunk: int, scale: float):
    out, _ = _flash_fwd_stats(q, k, v, causal=causal, chunk=chunk, scale=scale)
    return out


def _flash_cv_fwd(q, k, v, causal, chunk, scale):
    out, lse = _flash_fwd_stats(q, k, v, causal=causal, chunk=chunk, scale=scale)
    return out, (q, k, v, out, lse)


def _flash_cv_bwd(causal, chunk, scale, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    n_chunks = Sk // chunk
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)   # (B,H,Sq,hd)
    do = dout.astype(jnp.float32).transpose(0, 2, 1, 3)
    of = out.astype(jnp.float32).transpose(0, 2, 1, 3)
    D = jnp.sum(do * of, axis=-1)                                # (B,H,Sq)
    kc = jnp.moveaxis(k.transpose(0, 2, 1, 3).reshape(B, H, n_chunks, chunk, hd), 2, 0)
    vc = jnp.moveaxis(v.transpose(0, 2, 1, 3).reshape(B, H, n_chunks, chunk, hd), 2, 0)
    pos_q = jnp.arange(Sq)

    def body(dq_acc, inputs):
        j, kj, vj = inputs
        s = jax.lax.dot_general(qf, kj, (((3,), (3,)), ((0, 1), (0, 1))),
                                preferred_element_type=jnp.float32)
        p = jnp.exp(s - lse[..., None])                          # (B,H,Sq,ck)
        if causal:
            pos_k = j * chunk + jnp.arange(chunk)
            p = jnp.where((pos_q[:, None] >= pos_k[None, :])[None, None], p, 0.0)
        dp = jax.lax.dot_general(do, vj, (((3,), (3,)), ((0, 1), (0, 1))),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - D[..., None])                             # (B,H,Sq,ck)
        dq_acc = dq_acc + jax.lax.dot_general(
            ds, kj, (((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)
        dk_j = jax.lax.dot_general(ds, qf, (((2,), (2,)), ((0, 1), (0, 1))),
                                   preferred_element_type=jnp.float32)
        dv_j = jax.lax.dot_general(p, do, (((2,), (2,)), ((0, 1), (0, 1))),
                                   preferred_element_type=jnp.float32)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (jnp.arange(n_chunks), kc, vc))
    dq = (dq * scale).transpose(0, 2, 1, 3).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 2).reshape(B, H, Sk, hd).transpose(0, 2, 1, 3)
    dv = jnp.moveaxis(dvs, 0, 2).reshape(B, H, Sk, hd).transpose(0, 2, 1, 3)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_cv.defvjp(_flash_cv_fwd, _flash_cv_bwd)


def attention_core(cfg: ModelConfig, q, k, v, *, causal: bool, q_offset=0,
                   kv_len=None):
    """Dispatch on ``cfg.attn_impl``; expands GQA heads first."""
    k = expand_kv(k, cfg.num_heads)
    v = expand_kv(v, cfg.num_heads)
    if q.shape[1] == 1 and not causal:
        return decode_attention(q, k, v, kv_len=kv_len)
    if cfg.attn_impl == "pallas" and causal and q.shape[1] == k.shape[1]:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True)
    if (cfg.attn_impl == "xla_cv" and causal and kv_len is None
            and k.shape[1] % min(cfg.attn_chunk, k.shape[1]) == 0):
        return flash_attention_cv(q, k, v, True, cfg.attn_chunk,
                                  cfg.head_dim ** -0.5)
    return flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                           kv_len=kv_len, chunk=cfg.attn_chunk)


# ---------------------------------------------------------------------------
# full layer applications
# ---------------------------------------------------------------------------
def self_attention(cfg: ModelConfig, p, x, positions, *, causal: bool = True,
                   prefix: str = "") -> Tuple[jnp.ndarray, Tuple]:
    """Training / prefill self-attention. Returns (out, (k, v)) for caching."""
    q = q_proj(cfg, p, x, positions, prefix=prefix)
    k, v = kv_proj(cfg, p, x, positions, prefix=prefix)
    attn = attention_core(cfg, q, k, v, causal=causal)
    return out_proj(cfg, p, attn, prefix=prefix), (k, v)


def decode_self_attention(cfg: ModelConfig, p, x, cache_k, cache_v, cache_pos,
                          positions, *, prefix: str = ""):
    """Single-token decode: insert new KV at ``cache_pos``, attend over cache.

    cache_k/v: (B, S_max, KV, hd). ``cache_pos`` is a scalar, or a (B,)
    vector of per-row positions (ragged continuous batching).
    Returns (out, new_k, new_v).
    """
    q = q_proj(cfg, p, x, positions, prefix=prefix)
    k_new, v_new = kv_proj(cfg, p, x, positions, prefix=prefix)
    pos = jnp.asarray(cache_pos)
    if pos.ndim == 0:
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), pos, axis=1)
    else:  # per-row scatter (Sq == 1)
        rows = jnp.arange(cache_k.shape[0])
        cache_k = cache_k.at[rows, pos].set(k_new[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, pos].set(v_new[:, 0].astype(cache_v.dtype))
    attn = attention_core(cfg, q, cache_k, cache_v, causal=False,
                          kv_len=pos + x.shape[1])
    return out_proj(cfg, p, attn, prefix=prefix), cache_k, cache_v


def cross_attention(cfg: ModelConfig, p, x, enc_k, enc_v, *, prefix: str = "cross_"):
    """Decoder cross-attention over precomputed encoder KV (no mask, no rope)."""
    positions = jnp.arange(x.shape[1])[None, :]
    q = q_proj(cfg, p, x, positions, prefix=prefix, use_rope=False)
    attn = attention_core(cfg, q, enc_k, enc_v, causal=False)
    return out_proj(cfg, p, attn, prefix=prefix)
