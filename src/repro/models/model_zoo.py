"""Unified model API over all families + ``input_specs`` for the dry-run.

``Model`` wires a ModelConfig to (init, loss_fn, prefill, decode, caches) and
produces the ShapeDtypeStruct stand-ins used by ``launch/dryrun.py`` — weak-
type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ENCDEC, VLM, ModelConfig
from repro.configs.shapes import DECODE, PREFILL, TRAIN, ShapeSuite
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.models.common import AxisEnv, ShardingPolicy, make_policy

PyTree = Any


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    env: AxisEnv
    pol: ShardingPolicy

    # ------------------------------------------------------------------
    def init(self, key, *, abstract: bool = False) -> Tuple[PyTree, PyTree]:
        """Returns (params, spec-tree). abstract=True -> ShapeDtypeStructs."""
        if self.cfg.family == ENCDEC:
            return encdec_mod.init_encdec(self.cfg, key, self.pol, self.env,
                                          abstract=abstract)
        return tfm.init_decoder_only(self.cfg, key, self.pol, self.env,
                                     abstract=abstract)

    def abstract_params(self, mesh) -> Tuple[PyTree, PyTree]:
        """(ShapeDtypeStructs with shardings, spec tree) — no allocation."""
        shapes, specs = self.init(None, abstract=True)
        out = jax.tree_util.tree_map(
            lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                               sharding=NamedSharding(mesh, sp)),
            shapes, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        return out, specs

    # ------------------------------------------------------------------
    def forward(self, params, batch, *, return_cache: bool = False,
                last_token_only: bool = False):
        if self.cfg.family == ENCDEC:
            return encdec_mod.forward_encdec(
                self.cfg, params, batch, self.env, self.pol,
                return_cache=return_cache, last_token_only=last_token_only)
        return tfm.forward_decoder_only(
            self.cfg, params, batch, self.env, self.pol,
            return_cache=return_cache, last_token_only=last_token_only)

    def loss_fn(self, params, batch) -> jnp.ndarray:
        logits, aux, _ = self.forward(params, batch)
        loss = softmax_xent(logits, batch["labels"])
        return loss + 0.01 * aux

    def decode(self, params, cache, batch):
        if self.cfg.family == ENCDEC:
            return encdec_mod.decode_encdec(self.cfg, params, cache, batch,
                                            self.env, self.pol)
        return tfm.decode_decoder_only(self.cfg, params, cache, batch,
                                       self.env, self.pol)

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        if self.cfg.family == ENCDEC:
            return encdec_mod.init_cache_encdec(self.cfg, batch, max_seq, dtype)
        return tfm.init_cache_decoder_only(self.cfg, batch, max_seq, dtype)

    def cache_specs(self, batch: int) -> PyTree:
        if self.cfg.family == ENCDEC:
            return encdec_mod.cache_specs_encdec(self.cfg, batch, self.env, self.pol)
        return tfm.cache_specs_decoder_only(self.cfg, batch, self.env, self.pol)

    def abstract_cache(self, batch: int, max_seq: int, mesh,
                       dtype=jnp.bfloat16) -> PyTree:
        shapes = jax.eval_shape(lambda: self.init_cache(batch, max_seq, dtype))
        specs = self.cache_specs(batch)
        return jax.tree_util.tree_map(
            lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                               sharding=NamedSharding(mesh, sp)),
            shapes, specs)

    # ------------------------------------------------------------------
    def serving_inventory(self, params: PyTree, cache: PyTree):
        """TensorInfo inventory of this tenant's *real* serving state.

        The offload planner otherwise works from the analytic
        ``WorkloadEstimate``; a live runtime knows its actual params and KV
        pool, so the plan can be cut against the true byte counts. Leaf
        paths are prefixed ``params/`` and ``kv/`` so the same names flow
        through plan → ``shardings_with_offload`` / ``KVPool`` placement.
        KV leaves are divisible (the pool spills a cold tail of the
        sequence axis — paper §VI-A's fine-grained spill) and so are
        embedding tables (row granularity).
        """
        from dataclasses import replace
        from repro.core.offload import TensorInfo, inventory_from_tree
        inv = inventory_from_tree({"params": params, "kv": cache})
        out = []
        for t in inv:
            if t.name.startswith("kv/"):
                t = TensorInfo(t.name, t.bytes, "kv_cache",
                               offloadable=True, divisible=True)
            elif t.group == "embed":
                t = replace(t, divisible=True)
            out.append(t)
        return out

    def cache_bytes(self, batch: int, max_seq: int, dtype=jnp.bfloat16) -> int:
        """KV/state pool footprint without allocating it."""
        shapes = jax.eval_shape(lambda: self.init_cache(batch, max_seq, dtype))
        return sum(int(s.size) * s.dtype.itemsize
                   for s in jax.tree_util.tree_leaves(shapes))

    # ------------------------------------------------------------------
    def batch_specs(self, shape: ShapeSuite) -> Dict[str, Tuple]:
        """(shape, dtype, PartitionSpec) per input — the single source of
        truth for both input_specs (dry-run) and synthetic batches (smoke)."""
        cfg, env = self.cfg, self.env
        B = shape.global_batch
        S = 1 if shape.kind == DECODE else shape.seq_len
        if self.pol.profile == "fsdp_only":
            baxes = env.batch_axes_joint(B)
        else:
            baxes = env.batch_axes(B)
        seq_ax = env.tp if (self.pol.seq_sharded_acts and shape.kind != DECODE) else None
        out: Dict[str, Tuple] = {}
        if cfg.family == VLM:
            out["embeds"] = ((B, S, cfg.d_model), jnp.bfloat16, P(baxes, seq_ax, None))
            out["positions"] = ((3, B, S), jnp.int32, P(None, baxes, None))
        elif cfg.family == ENCDEC:
            if shape.kind != DECODE:
                out["frames"] = ((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16,
                                 P(baxes, None, None))
            out["tokens"] = ((B, S), jnp.int32, P(baxes, None))
        else:
            out["tokens"] = ((B, S), jnp.int32, P(baxes, seq_ax))
        if shape.kind == TRAIN:
            out["labels"] = ((B, S), jnp.int32, P(baxes, seq_ax))
        if shape.kind == DECODE:
            out["pos"] = ((), jnp.int32, P())
        return out

    def input_specs(self, shape: ShapeSuite, mesh) -> Dict[str, jax.ShapeDtypeStruct]:
        return {
            name: jax.ShapeDtypeStruct(shp, dt, sharding=NamedSharding(mesh, sp))
            for name, (shp, dt, sp) in self.batch_specs(shape).items()
        }

    def synthetic_batch(self, shape: ShapeSuite, key=None) -> Dict[str, jnp.ndarray]:
        key = key if key is not None else jax.random.PRNGKey(0)
        out = {}
        for name, (shp, dt, _) in self.batch_specs(shape).items():
            key, sub = jax.random.split(key)
            if dt == jnp.int32:
                hi = self.cfg.vocab_size if name in ("tokens", "labels") else max(
                    1, min(shp[-1] if shp else 1, 4096))
                out[name] = (jnp.zeros(shp, dt) if not shp else
                             jax.random.randint(sub, shp, 0, hi, dt))
            else:
                out[name] = 0.02 * jax.random.normal(sub, shp, dt)
        if "pos" in out:
            out["pos"] = jnp.asarray(0, jnp.int32)
        return out


def softmax_xent(logits, labels) -> jnp.ndarray:
    """Mean token cross-entropy; one-hot matmul form (vocab-sharding safe)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, lf.shape[-1], dtype=lf.dtype)
    ll = jnp.einsum("...v,...v->...", lf, onehot)
    return jnp.mean(lse - ll)


def build_model(cfg: ModelConfig, mesh_or_env) -> Model:
    env = (mesh_or_env if isinstance(mesh_or_env, AxisEnv)
           else AxisEnv.from_mesh(mesh_or_env))
    return Model(cfg=cfg, env=env, pol=make_policy(cfg, env))
