"""repro.models"""
