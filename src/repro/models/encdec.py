"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: inputs are precomputed
frame embeddings (batch, encoder_seq, d_model). The encoder runs bidirectional
self-attention; the decoder runs causal self-attention + cross-attention over
the encoder output. Decode shapes lower the decoder ``serve_step`` with
per-layer cross-KV precomputed at prefill.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as nn
from repro.models.common import AxisEnv, ParamBuilder, ShardingPolicy
from repro.models.transformer import constrain, remat_wrap, unembed_spec

PyTree = Any


def init_encdec(cfg: ModelConfig, key, pol: ShardingPolicy, env: AxisEnv,
                *, abstract: bool = False) -> Tuple[PyTree, PyTree]:
    b = ParamBuilder(cfg, pol, env, key, abstract=abstract)
    nn.init_embeddings(b)
    b.add("enc_pos_embed", (cfg.encoder_seq, cfg.d_model), ("none", "d_fsdp"),
          scale=0.02)

    eb = b.child("encoder")
    eb.cfg = cfg.with_(num_layers=cfg.encoder_layers)
    attn.init_attention(eb, stacked=True)
    nn.init_mlp(eb, stacked=True)
    nn.init_norm(eb, "norm1", stacked=True)
    nn.init_norm(eb, "norm2", stacked=True)
    nn.init_norm(eb, "enc_final")

    db = b.child("decoder")
    attn.init_attention(db, stacked=True)
    attn.init_attention(db, stacked=True, prefix="cross_", cross=True)
    nn.init_mlp(db, stacked=True)
    nn.init_norm(db, "norm1", stacked=True)
    nn.init_norm(db, "norm2", stacked=True)
    nn.init_norm(db, "norm3", stacked=True)
    return b.params, b.specs


def encode(cfg: ModelConfig, params, frames, env: AxisEnv, pol: ShardingPolicy):
    """frames: (B, enc_seq, D) precomputed embeddings -> (B, enc_seq, D)."""
    B = frames.shape[0]
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + params["enc_pos_embed"][None, : x.shape[1]].astype(x.dtype)
    x = constrain(x, env, pol, B)
    positions = jnp.arange(x.shape[1])[None, :]
    ecfg = cfg.with_(num_layers=cfg.encoder_layers)

    def body(x, lp):
        h = nn.apply_norm(ecfg, lp, "norm1", x)
        a, _ = attn.self_attention(ecfg, lp, h, positions, causal=False)
        x = x + a
        x = x + nn.apply_mlp(ecfg, lp, nn.apply_norm(ecfg, lp, "norm2", x))
        return constrain(x, env, pol, B), None

    layer_p = {k: v for k, v in params["encoder"].items()
               if not k.startswith("enc_final")}
    x, _ = jax.lax.scan(remat_wrap(cfg, body), x, layer_p)
    return nn.apply_norm(ecfg, params["encoder"], "enc_final", x)


def _dec_layer(cfg, lp, x, positions, enc_k, enc_v, cache=None, cache_pos=None):
    h = nn.apply_norm(cfg, lp, "norm1", x)
    if cache is None:
        a, kv = attn.self_attention(cfg, lp, h, positions)
    else:
        ck, cv = cache
        a, ck, cv = attn.decode_self_attention(cfg, lp, h, ck, cv, cache_pos,
                                               positions)
        kv = (ck, cv)
    x = x + a
    h = nn.apply_norm(cfg, lp, "norm2", x)
    x = x + attn.cross_attention(cfg, lp, h, enc_k, enc_v)
    x = x + nn.apply_mlp(cfg, lp, nn.apply_norm(cfg, lp, "norm3", x))
    return x, kv


def forward_encdec(cfg: ModelConfig, params, batch, env: AxisEnv,
                   pol: ShardingPolicy, *, return_cache: bool = False,
                   last_token_only: bool = False):
    """Teacher-forced training / prefill. batch: frames + tokens."""
    enc_out = encode(cfg, params, batch["frames"], env, pol)
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    x = nn.embed_tokens(cfg, params, tokens, positions)
    x = constrain(x, env, pol, B)

    def body(x, lp):
        # cross KV from encoder output (per decoder layer)
        ek, ev = attn.kv_proj(cfg, lp, enc_out, None, prefix="cross_",
                              use_rope=False)
        x2, kv = _dec_layer(cfg, lp, x, positions, ek, ev)
        x2 = constrain(x2, env, pol, B)
        ys = (kv, (ek, ev)) if return_cache else None
        return x2, ys

    x, ys = jax.lax.scan(remat_wrap(cfg, body), x, params["decoder"])
    cache = None
    if return_cache:
        (ks, vs), (eks, evs) = ys
        cache = {"k": ks, "v": vs, "cross_k": eks, "cross_v": evs}
    if last_token_only:
        x = x[:, -1:, :]
    logits = nn.unembed(cfg, params, x,
                        seq_shard_spec=unembed_spec(env, pol, B))
    return logits, jnp.zeros((), jnp.float32), cache


def decode_encdec(cfg: ModelConfig, params, cache, batch, env: AxisEnv,
                  pol: ShardingPolicy):
    """Single-token decode against cached self-KV + cross-KV."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    pos = batch["pos"]
    positions = pos + jnp.arange(1)[None, :]
    x = nn.embed_tokens(cfg, params, tokens, positions)
    x = constrain(x, env, pol, B)

    def body(x, inp):
        lp, ck, cv, ek, ev = inp
        x2, (ck, cv) = _dec_layer(cfg, lp, x, positions, ek, ev,
                                  cache=(ck, cv), cache_pos=pos)
        return x2, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    new_cache = {"k": ks, "v": vs,
                 "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    logits = nn.unembed(cfg, params, x[:, 0:1, :])[:, 0, :]
    return logits, new_cache


def init_cache_encdec(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16) -> PyTree:
    KV, hd, L = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_seq, KV, hd), dtype),
        "v": jnp.zeros((L, batch, max_seq, KV, hd), dtype),
        "cross_k": jnp.zeros((L, batch, cfg.encoder_seq, KV, hd), dtype),
        "cross_v": jnp.zeros((L, batch, cfg.encoder_seq, KV, hd), dtype),
    }


def cache_specs_encdec(cfg: ModelConfig, batch: int, env: AxisEnv,
                       pol: ShardingPolicy) -> PyTree:
    from jax.sharding import PartitionSpec as P
    baxes = env.batch_axes(batch)
    kv = P(None, baxes, env.tp, None, None)
    cross = P(None, baxes, None, None, None)  # 1500 frames not tp-divisible
    return {"k": kv, "v": kv, "cross_k": cross, "cross_v": cross}
