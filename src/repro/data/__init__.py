"""repro.data"""
