"""Deterministic sharded token pipeline with host-side prefetch.

Two sources:
  * SyntheticSource — seeded Zipf-ish token stream (default for benches/tests;
    fully deterministic per (seed, step) so restarts resume exactly);
  * ByteCorpusSource — byte-level LM over any file (the paper's llm.c
    tinystories/shakespeare workload shape).

``DataPipeline`` yields {tokens, labels} of (global_batch, seq+1) split into
next-token pairs, placed with the train batch sharding; a background thread
keeps ``prefetch`` batches ready so input never serializes the step
(host-side analogue of overlapping data movement with compute).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np


class SyntheticSource:
    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        self.seed = seed

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        # Zipf-ish marginal — more realistic logits than uniform
        ranks = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
        return (ranks % self.vocab).astype(np.int32)


class ByteCorpusSource:
    def __init__(self, path: str, seed: int = 0):
        with open(path, "rb") as f:
            self.data = np.frombuffer(f.read(), dtype=np.uint8)
        if self.data.size < 2:
            raise ValueError(f"corpus {path} too small")
        self.seed = seed

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 7_777_777 + step)
        starts = rng.integers(0, max(1, self.data.size - seq - 1), size=batch)
        rows = [self.data[s:s + seq + 1].astype(np.int32) for s in starts]
        return np.stack(rows)


@dataclass
class DataPipeline:
    source: object
    global_batch: int
    seq_len: int
    sharding: Optional[jax.sharding.Sharding] = None
    prefetch: int = 2
    start_step: int = 0

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            step = self.start_step
            while not stop.is_set():
                arr = self.source.batch(step, self.global_batch, self.seq_len)
                q.put((step, arr))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                _, arr = q.get()
                tokens, labels = arr[:, :-1], arr[:, 1:]
                if self.sharding is not None:
                    tokens = jax.device_put(tokens, self.sharding)
                    labels = jax.device_put(labels, self.sharding)
                yield {"tokens": tokens, "labels": labels}
        finally:
            stop.set()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic random access — exact restart after failure."""
        arr = self.source.batch(step, self.global_batch, self.seq_len)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
