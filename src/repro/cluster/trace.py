"""Seeded synthetic job traces for the cluster scheduler.

Three job classes mirror the paper's §V co-scheduling mix:

* ``serving``  — LLM inference tenants from the model zoo (decode-shaped,
  memory-bound: the paper's Fig. 2 "GPU busy but half-idle" class). These
  are the jobs ``launch/cluster.py`` can execute through a real
  ``SliceRuntime`` at reduced scale.
* ``training`` — compute-heavy runs (the NekRS-like HPC analogue): long
  holders of large slices, the jobs that create and suffer fragmentation.
* ``batch``    — analytics-style jobs with paper-style low utilization
  (§IV Figs. 2-3): short, small, pinned to single-digit compute
  utilization so they throttle nobody but still occupy chips.

Arrivals are Poisson (exponential inter-arrival gaps) from a single seeded
``numpy`` generator, so a trace is a pure function of its ``TraceConfig`` —
every scheduler comparison in ``benchmarks/bench_cluster.py`` replays the
identical stream under each policy.
"""
from __future__ import annotations

import csv
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

SERVING = "serving"
TRAINING = "training"
BATCH = "batch"
KINDS = (SERVING, TRAINING, BATCH)

# arch pools per job class (all resolvable via repro.configs.get_config;
# the serving pool is restricted to decoder-only archs the live
# TenantEngine can execute at reduced scale)
SERVING_ARCHS = ("gpt2-124m", "llama3-8b", "phi3-mini-3.8b", "qwen3-32b")
TRAINING_ARCHS = ("llama3-8b", "starcoder2-7b", "qwen3-32b", "command-r-35b")
BATCH_ARCHS = ("gpt2-124m", "mamba2-130m", "zamba2-1.2b")

KIND_SHAPE = {SERVING: "decode_32k", TRAINING: "train_4k", BATCH: "decode_32k"}

# default priority class per job kind (higher preempts lower): serving
# tenants are latency-critical, training runs hold reservations, batch
# analytics are the paper's low-utilization opportunistic filler — the
# class MISO-style schedulers reclaim chips from first
KIND_PRIORITY = {SERVING: 2, TRAINING: 1, BATCH: 0}


@dataclass(frozen=True)
class Job:
    """One unit of the arrival stream. Modeled fields (steps/shape) drive
    the analytic duration; the optional pinned fields let crafted traces
    (tests, the fragmentation showcase) control timing exactly.

    Units: ``arrival_s``/``duration_s`` are virtual seconds, ``steps`` are
    model steps (duration = steps × modeled step time unless pinned),
    ``priority`` is an integer class (higher may checkpoint-evict strictly
    lower-priority *batch* jobs when the scheduler runs with priorities
    enabled)."""
    job_id: int
    kind: str                       # serving | training | batch
    arch: str
    shape: str                      # ShapeSuite name for WorkloadEstimate
    arrival_s: float
    steps: int
    slo_factor: float = 4.0         # deadline = arrival + factor × ideal
    profile: Optional[str] = None   # pin the slice profile (skip scoring)
    duration_s: Optional[float] = None  # pin duration (skip roofline model)
    u_compute: Optional[float] = None   # pin power-model utilization
    requests: int = 0               # serving: live requests to execute
    priority: int = 0               # preemption class (higher evicts lower)

    @property
    def tag(self) -> str:
        return f"job{self.job_id}.{self.kind}.{self.arch}"


@dataclass(frozen=True)
class TraceConfig:
    seed: int = 0
    n_jobs: int = 24
    mean_interarrival_s: float = 45.0
    mix: Tuple[float, float, float] = (0.5, 0.25, 0.25)  # serving/train/batch
    serving_steps: Tuple[int, int] = (100, 400)
    training_steps: Tuple[int, int] = (20, 80)
    batch_steps: Tuple[int, int] = (50, 200)
    slo_range: Tuple[float, float] = (2.5, 6.0)
    batch_u_range: Tuple[float, float] = (0.03, 0.15)
    requests_per_serving: int = 2


def generate_trace(cfg: TraceConfig = TraceConfig()) -> List[Job]:
    """Deterministic mixed trace: same config (incl. seed) → same jobs."""
    rng = np.random.default_rng(cfg.seed)
    probs = np.asarray(cfg.mix, dtype=float)
    probs = probs / probs.sum()
    jobs: List[Job] = []
    t = 0.0
    for jid in range(cfg.n_jobs):
        t += float(rng.exponential(cfg.mean_interarrival_s))
        kind = KINDS[int(rng.choice(len(KINDS), p=probs))]
        if kind == SERVING:
            arch = SERVING_ARCHS[int(rng.integers(len(SERVING_ARCHS)))]
            steps = int(rng.integers(*cfg.serving_steps))
            extra = {"requests": cfg.requests_per_serving}
        elif kind == TRAINING:
            arch = TRAINING_ARCHS[int(rng.integers(len(TRAINING_ARCHS)))]
            steps = int(rng.integers(*cfg.training_steps))
            extra = {}
        else:
            arch = BATCH_ARCHS[int(rng.integers(len(BATCH_ARCHS)))]
            steps = int(rng.integers(*cfg.batch_steps))
            extra = {"u_compute": float(rng.uniform(*cfg.batch_u_range))}
        jobs.append(Job(
            job_id=jid, kind=kind, arch=arch, shape=KIND_SHAPE[kind],
            arrival_s=round(t, 3), steps=steps,
            slo_factor=round(float(rng.uniform(*cfg.slo_range)), 2),
            priority=KIND_PRIORITY[kind],   # by class: no rng draw, so the
            **extra))                       # arrival stream is unchanged
    return jobs


# ---------------------------------------------------------------------------
# public-trace loader (Philly / Alibaba-style CSV schemas)
# ---------------------------------------------------------------------------
# accepted header aliases, per field (first match in file-header order wins)
_CSV_ARRIVAL = ("submit_time_s", "submit_time", "submitted_time",
                "arrival_s", "arrival", "timestamp")
_CSV_DURATION = ("duration_s", "duration", "run_time_s", "run_time",
                 "runtime")
_CSV_GPUS = ("gpus", "gpu_request", "num_gpus", "gpu_num", "plan_gpu")
_CSV_CLASS = ("class", "job_class", "kind", "type")

# public-trace job-class vocabulary → the three paper classes
_CSV_KINDS = {
    SERVING: SERVING, "inference": SERVING, "latency": SERVING,
    TRAINING: TRAINING, "train": TRAINING, "production": TRAINING,
    BATCH: BATCH, "best_effort": BATCH, "best-effort": BATCH,
    "opportunistic": BATCH, "analytics": BATCH, "spot": BATCH,
}

def _profile_ladder() -> List[Tuple[str, int]]:
    """Slice profiles by ascending chip count, for the GPU-request →
    profile mapping (derived from the canonical table, not hand-pinned)."""
    from repro.core.slices import PROFILES
    return sorted(((p.name, p.n_chips) for p in PROFILES),
                  key=lambda x: x[1])


def _csv_col(header: List[str], aliases: Tuple[str, ...],
             what: str) -> str:
    for name in header:
        if name.strip().lower() in aliases:
            return name
    raise ValueError(
        f"trace CSV is missing a {what} column (any of: "
        f"{', '.join(aliases)}); got header {header}")


def _profile_for_gpus(gpus: int) -> str:
    """Smallest slice profile with at least ``gpus`` chips. A request
    larger than the largest profile is a schema error, not something to
    silently clamp: a clamped job would replay on a quarter of the chips
    the trace says it used, skewing every throughput number downstream."""
    ladder = _profile_ladder()
    for name, chips in ladder:
        if chips >= gpus:
            return name
    raise ValueError(
        f"GPU request {gpus} exceeds the largest slice profile "
        f"({ladder[-1][0]}, {ladder[-1][1]} chips)")


def load_csv(path: str, *, default_kind: str = BATCH,
             requests_per_serving: int = 2, chip: str = "v5e") -> List[Job]:
    """Load a Philly/Alibaba-style public trace CSV into ``Job``s.

    The schema is the common denominator of the production GPU-cluster
    traces the scale benchmarks replay: one row per job with a **submit
    time** (seconds), a **duration** (seconds), a **GPU request** (chip
    count) and optionally a **job class**. Header names are matched
    case-insensitively against the usual aliases (``submitted_time`` /
    ``run_time`` / ``num_gpus`` à la Philly, ``gpu_num`` / ``plan_gpu``
    à la Alibaba, plus the obvious ``arrival_s``/``duration_s`` forms).

    Mapping onto the synthetic-trace vocabulary:

    * job class → ``serving`` / ``training`` / ``batch`` via the usual
      public-trace labels (``inference``→serving, ``production``→training,
      ``best_effort``/``spot``→batch, …); a missing class column assigns
      ``default_kind``. Priorities follow ``KIND_PRIORITY`` exactly as
      :func:`generate_trace` does.
    * GPU request → the smallest slice profile with that many chips,
      pinned via ``Job.profile``; a request beyond the largest profile
      (256 chips) raises rather than clamps.
    * duration → pinned wall-clock ``Job.duration_s`` (public traces
      record observed runtimes, not model steps), so a loaded trace
      replays deterministically under any policy.
    * arch → round-robin over the kind's arch pool by row order, so the
      resident-state pricing (checkpoint/migration bytes) varies across
      jobs the way the synthetic traces' does. The pool is restricted to
      archs whose workload actually fits the pinned profile (a 3.8B
      decode tenant cannot live on a 16-chip slice); if none fit, the
      profile escalates to the next size up — the request is a floor,
      never a reachability trap.

    Optional per-row columns override the defaults where present:
    ``job_id``, ``slo_factor``, ``u_compute``, ``arch``. Rows are sorted
    by (submit time, row order) — the scheduler consumes arrivals in
    order. Zero/negative durations, zero-GPU rows, oversized GPU
    requests and duplicate ``job_id``s are rejected.

    ``chip`` names the target chip family (``core.hw.CHIPS``) the
    arch-fit scoring runs against — an arch whose resident state fits a
    24 GiB-HBM mi300 slice may not fit the same slice on a 16 GiB v5e,
    so the fit must be chip-aware, not hard-wired to the default chip.
    Unknown names raise the registry's ``ValueError`` listing the valid
    family names."""
    with open(path, newline="") as fh:
        reader = csv.DictReader(fh)
        if reader.fieldnames is None:
            raise ValueError(f"trace CSV {path!r} is empty")
        header = list(reader.fieldnames)
        rows = list(reader)
    col_t = _csv_col(header, _CSV_ARRIVAL, "submit-time")
    col_d = _csv_col(header, _CSV_DURATION, "duration")
    col_g = _csv_col(header, _CSV_GPUS, "GPU-request")
    try:
        col_k: Optional[str] = _csv_col(header, _CSV_CLASS, "job-class")
    except ValueError:
        col_k = None
    lower = {name.strip().lower(): name for name in header}
    arch_pools: Dict[str, Tuple[str, ...]] = {
        SERVING: SERVING_ARCHS, TRAINING: TRAINING_ARCHS,
        BATCH: BATCH_ARCHS}
    parsed = []
    for i, row in enumerate(rows):
        arrival = float(row[col_t])
        duration = float(row[col_d])
        gpus = int(float(row[col_g]))
        if duration <= 0:
            raise ValueError(f"{path}:{i + 2}: non-positive duration "
                             f"{duration}")
        if gpus <= 0:
            raise ValueError(f"{path}:{i + 2}: non-positive GPU request "
                             f"{gpus}")
        if col_k is not None and row[col_k].strip():
            label = row[col_k].strip().lower()
            kind = _CSV_KINDS.get(label)
            if kind is None:
                raise ValueError(f"{path}:{i + 2}: unknown job class "
                                 f"{label!r} (known: "
                                 f"{', '.join(sorted(_CSV_KINDS))})")
        else:
            kind = default_kind
        parsed.append((arrival, i, duration, gpus, kind, row))

    def _opt(row, name: str) -> Optional[str]:
        col = lower.get(name)
        v = row.get(col) if col else None
        return v.strip() if v and v.strip() else None

    from repro.configs import get_config, get_shape
    from repro.core.hw import get_chip
    from repro.core.perfmodel import get_model
    perf = get_model(get_chip(chip))
    ladder = _profile_ladder()

    def _fit(kind: str, gpus: int, pinned_arch: Optional[str],
             i: int) -> Tuple[str, str]:
        """(profile, arch) honouring the GPU request as a floor: walk the
        profile ladder up from the request until an arch in the kind's
        pool (or the pinned arch) fits the slice."""
        from repro.core.slices import get_profile
        shape = get_shape(KIND_SHAPE[kind])
        pool = (pinned_arch,) if pinned_arch else arch_pools[kind]
        floor = _profile_for_gpus(gpus)
        start = next(k for k, (name, _) in enumerate(ladder)
                     if name == floor)
        for name, _ in ladder[start:]:
            prof = get_profile(name)
            fits = [a for a in pool
                    if perf.score(get_config(a), shape, prof) is not None]
            if fits:
                return name, fits[i % len(fits)]
        raise ValueError(
            f"no arch in the {kind} pool fits any profile >= "
            f"{gpus} chips")

    jobs: List[Job] = []
    seen_ids: Dict[int, int] = {}
    for arrival, i, duration, gpus, kind, row in sorted(
            parsed, key=lambda p: (p[0], p[1])):
        jid = int(_opt(row, "job_id") or len(jobs))
        if jid in seen_ids:
            raise ValueError(
                f"{path}:{i + 2}: duplicate job_id {jid} (first seen at "
                f"row {seen_ids[jid] + 2}); the scheduler keys records "
                f"by job_id, so duplicates would silently merge jobs")
        seen_ids[jid] = i
        pinned_arch = _opt(row, "arch")
        if pinned_arch is not None:
            from repro.configs import ALL_ARCHS
            if pinned_arch not in ALL_ARCHS:
                raise ValueError(
                    f"{path}:{i + 2}: unknown arch {pinned_arch!r} "
                    f"(known: {', '.join(sorted(ALL_ARCHS))})")
        profile, arch = _fit(kind, gpus, pinned_arch, i)
        slo = _opt(row, "slo_factor")
        u = _opt(row, "u_compute")
        jobs.append(Job(
            job_id=jid, kind=kind, arch=arch, shape=KIND_SHAPE[kind],
            arrival_s=arrival, steps=1,
            slo_factor=float(slo) if slo else 4.0,
            profile=profile, duration_s=duration,
            u_compute=float(u) if u else None,
            requests=requests_per_serving if kind == SERVING else 0,
            priority=KIND_PRIORITY[kind]))
    return jobs


def fragmentation_showcase(long_s: float = 10_000.0,
                           short_s: float = 100.0) -> List[Job]:
    """A deterministic single-pod stream where first-fit strands a large job.

    Timeline on one 16×16 pod:

    1. t=0: eight 4×4 jobs fill the top half (first-fit packs rows 0-7);
       alternating short/long durations.
    2. t=0: two 8×8 jobs fill the bottom half — one short, one long.
    3. t=``short_s``: the five short jobs finish → 128 chips free, but
       scattered as four 4×4 holes plus one 8×8 hole.
    4. t=``short_s``+1: an 8×16 job (exactly 128 chips) arrives. It fits
       by chip count and by *no* aligned rectangle — the arXiv 2512.16099
       stranding case. ``repack()`` compacts the five live slices into the
       top half and frees rows 8-15 for it; plain first-fit leaves it
       queued until the long jobs end at ``long_s`` (beyond the horizon
       the benchmark runs with).
    """
    jobs: List[Job] = []
    jid = 0
    for i in range(8):
        jobs.append(Job(
            job_id=jid, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="1s.16c",
            duration_s=(short_s if i % 2 == 0 else long_s),
            u_compute=0.1))
        jid += 1
    for i in range(2):
        jobs.append(Job(
            job_id=jid, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="4s.64c",
            duration_s=(short_s if i == 0 else long_s),
            u_compute=0.3))
        jid += 1
    jobs.append(Job(
        job_id=jid, kind=TRAINING, arch="qwen3-32b", shape="train_4k",
        arrival_s=short_s + 1.0, steps=1, profile="8s.128c",
        duration_s=short_s, u_compute=0.3))
    return jobs


def elastic_showcase(long_s: float = 10_000.0,
                     deadline_dur_s: float = 400.0) -> List[Job]:
    """A deterministic single-pod stream where only an elastic shrink saves
    a deadline job's SLO.

    Timeline on one 16×16 pod:

    1. t=0: a low-priority batch job (8×16) and a training job (8×16) fill
       the pod for ``long_s`` seconds each.
    2. t=10: a deadline training job arrives needing an 8×8 slice for
       ``deadline_dur_s`` seconds, with ``slo_factor=2`` — its deadline
       (arrival + 2×ideal) passes long before either holder finishes.

    Without elastic resizing the job queues until ``long_s`` and misses.
    With ``"shrink"`` in the ``PolicySpec`` allowlist (the deprecated
    ``elastic=True`` shim) the scheduler shrinks the batch
    job to the smallest profile its workload fits (priced as a repack-style
    migration over the pod's host links) and places the deadline job
    immediately — an SLO miss turned into an SLO hit on the same trace.
    """
    return [
        Job(job_id=0, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=0.05),
        Job(job_id=1, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=0.3),
        Job(job_id=2, kind=TRAINING, arch="qwen3-32b", shape="train_4k",
            arrival_s=10.0, steps=1, profile="4s.64c",
            duration_s=deadline_dur_s, u_compute=0.3, slo_factor=2.0),
    ]


def _steps_for(arch: str, shape: str, profile: str, nominal_s: float) -> int:
    """Step count whose modeled nominal duration on ``profile`` is closest
    to ``nominal_s`` — lets a crafted job be *progress-based* (so eviction
    can preserve its ``work_done``) while still lasting a chosen virtual
    time. Deterministic: the shared PerfModel is a pure function."""
    from repro.core.perfmodel import get_model
    step = get_model().options(
        Job(job_id=-1, kind=BATCH, arch=arch, shape=shape, arrival_s=0.0,
            steps=1, profile=profile))[0].step_time
    return max(1, round(nominal_s / step))


def preemption_showcase(long_s: float = 10_000.0,
                        deadline_dur_s: float = 400.0) -> List[Job]:
    """A deterministic single-pod stream where only checkpoint-eviction
    saves a deadline job's SLO — shrinking cannot.

    Timeline on one 16×16 pod:

    1. t=0: a low-priority **progress-based** batch job (8×16, priority 0,
       ~``long_s`` nominal seconds of work) takes the top half; a
       priority-1 training job (8×16, pinned ``long_s``) takes the bottom.
    2. t=10: a priority-2 deadline training job arrives needing its own
       8×16 slice for ``deadline_dur_s`` seconds with ``slo_factor=2`` —
       its deadline passes long before either holder finishes.

    Shrinking cannot rescue it: a shrunk victim stays at its origin, so no
    aligned 8×16 rectangle is ever minted. With priorities enabled the
    scheduler checkpoint-evicts the batch job (suspend priced as the
    ``train/checkpoint.py`` save volume over the pod's host links), places
    the deadline job in its rectangle, and resumes the victim from its
    checkpoint once the rectangle frees — ``work_done`` preserved, the
    only loss being the priced save/restore delay.
    """
    return [
        Job(job_id=0, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, profile="8s.128c", u_compute=0.05, priority=0,
            steps=_steps_for("gpt2-124m", "decode_32k", "8s.128c", long_s)),
        Job(job_id=1, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=0.3, priority=1),
        Job(job_id=2, kind=TRAINING, arch="qwen3-32b", shape="train_4k",
            arrival_s=10.0, steps=1, profile="8s.128c",
            duration_s=deadline_dur_s, u_compute=0.3, slo_factor=2.0,
            priority=2),
    ]


def migration_showcase(long_s: float = 10_000.0,
                       deadline_dur_s: float = 400.0) -> List[Job]:
    """A deterministic load-imbalanced **two-pod** stream where only a
    cross-pod migration (``MigrateAcrossPods``, DCN-priced) saves a
    deadline job's SLO — every in-pod rescue is structurally or
    power-infeasible.

    Timeline on two 16×16 pods (fragmentation-aware placement):

    1. t=0: three long training holders arrive. Two *cold* ones
       (``u_compute=0.2``) fill pod 0 (8×16 each, job 0 top / job 2
       bottom); one *hot* one (``u_compute=1.0``, job 1) takes the top
       half of pod 1. Pod 0 is full-but-cool; pod 1 is half-empty-but-hot
       — the load imbalance.
    2. t=10: a priority-2 **hot** deadline training job (8×16,
       ``deadline_dur_s`` seconds, ``slo_factor=2``) arrives. The only
       free rectangle is pod 1's bottom half, but two full-power 128-chip
       tenants exceed the shared cap (throttle 0.786 < the 0.8 gate), so
       the placement is power-blocked. In-pod rescues all fail: every
       holder is a *training* job, and shrink/preempt only ever touch
       batch victims; repack has nothing to compact.
    3. With ``"migrate"`` in the ``PolicySpec`` allowlist the scheduler
       relocates the cold job 0 to pod 1 (cold next to hot stays under
       the cap), paying its resident bytes over the **DCN**
       (``PodSpec.dcn_bw``), and places the deadline job in the drained
       pod-0 rectangle next to the other cold holder — the cluster is
       re-balanced hot/cold per pod and the SLO flips from miss to hit.
    """
    return [
        Job(job_id=0, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=0.2, priority=1),
        Job(job_id=1, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=1.0, priority=1),
        Job(job_id=2, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=0.2, priority=1),
        Job(job_id=3, kind=TRAINING, arch="qwen3-32b", shape="train_4k",
            arrival_s=10.0, steps=1, profile="8s.128c",
            duration_s=deadline_dur_s, u_compute=1.0, slo_factor=2.0,
            priority=2),
    ]


def lookahead_showcase(long_s: float = 10_000.0,
                       deadline_dur_s: float = 400.0) -> List[Job]:
    """A deterministic single-pod stream where no *single* rescue action
    saves a deadline job, but the ``LookAheadPolicy``'s two-action chain
    (evict an enabler victim, then a second eviction places the job) does.

    Timeline on one 16×16 pod:

    1. t=0: two low-priority batch jobs (8×8 each, jobs 0-1) fill the top
       half side by side; a priority-1 training job (8×16, job 2) holds
       the bottom half. All run ``long_s`` seconds.
    2. t=10: a priority-2 deadline training job (8×16,
       ``deadline_dur_s`` seconds, ``slo_factor=2``) arrives. Evicting
       *either* batch job alone frees one 8×8 — no 8×16 origin is ever
       minted, so the greedy selector (one action per rescue) queues the
       job to an SLO miss. The look-ahead trial-applies the first
       eviction, re-probes, finds the second eviction now mints the
       origin, and commits the pair — both checkpoint drains are charged
       to the beneficiary's start delay.
    """
    return [
        Job(job_id=0, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="4s.64c",
            duration_s=long_s, u_compute=0.05, priority=0),
        Job(job_id=1, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="4s.64c",
            duration_s=long_s, u_compute=0.05, priority=0),
        Job(job_id=2, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=0.3, priority=1),
        Job(job_id=3, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=10.0, steps=1, profile="8s.128c",
            duration_s=deadline_dur_s, u_compute=0.3, slo_factor=2.0,
            priority=2),
    ]


def search_showcase(long_s: float = 10_000.0,
                    deadline_dur_s: float = 400.0) -> List[Job]:
    """A deterministic single-pod stream whose deadline job needs a
    *three*-action chain — beyond the two-step ``LookAheadPolicy``, found
    only by ``SearchPolicy(max_depth=3)``.

    Timeline on one 16×16 pod:

    1. t=0: two low-priority batch jobs (8×8, jobs 0-1) fill the top half
       and a third batch job (8×16, job 2) holds the bottom half — the
       pod is completely full. All run ``long_s`` seconds.
    2. t=10: a priority-2 deadline training job pinned to the **full
       pod** (16×16, ``deadline_dur_s`` seconds, ``slo_factor=2``)
       arrives. No single rescue mints a 16×16 origin (greedy queues it),
       and the look-ahead's one enabler plus one closer releases at most
       two of the three resident rectangles — its closer probe still
       finds no full-pod origin, so the chain never lands and the job
       misses. The search policy trial-applies two evictions (recorded,
       nested) and closes with a third, beneficiary-bound eviction whose
       probe now sees an empty grid: a cheapest three-eviction chain,
       every checkpoint drain charged to the beneficiary's start delay.
    """
    return [
        Job(job_id=0, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="4s.64c",
            duration_s=long_s, u_compute=0.05, priority=0),
        Job(job_id=1, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="4s.64c",
            duration_s=long_s, u_compute=0.05, priority=0),
        Job(job_id=2, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=0.05, priority=0),
        Job(job_id=3, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=10.0, steps=1, profile="16s.256c",
            duration_s=deadline_dur_s, u_compute=0.3, slo_factor=2.0,
            priority=2),
    ]


def grow_showcase(short_s: float = 50.0,
                  long_nominal_s: float = 2_000.0) -> List[Job]:
    """A deterministic single-pod stream where a running job absorbs freed
    neighbour chips via the partitioner's ``extend()`` primitive.

    Timeline on one 16×16 pod:

    1. t=0: a **progress-based** training job (8×8, ~``long_nominal_s``
       nominal seconds of work) and a short pinned batch job (8×8,
       ``short_s`` wall seconds) are placed side by side in the top half.
    2. t=``short_s``: the batch job completes and its rectangle frees.
       With ``"grow"`` in the ``PolicySpec`` allowlist (the deprecated
       ``grow=True`` shim) the training job extends its
       slice into the freed neighbours (priced as a host-link migration,
       symmetric to the elastic shrink), ``PodSimulator.resize`` re-bases
       its remaining work onto the faster step time, and its projected
       finish in ``PodSimulator.finish_times`` improves; with ``grow``
       left off it runs out its original 8×8 slice to a later finish.
    """
    return [
        Job(job_id=0, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, profile="4s.64c", priority=1,
            steps=_steps_for("llama3-8b", "train_4k", "4s.64c",
                             long_nominal_s)),
        Job(job_id=1, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="4s.64c",
            duration_s=short_s, u_compute=0.05, priority=0),
    ]


def reconfigure_showcase(long_s: float = 50_000.0,
                         deadline_nominal_s: float = 8_000.0) -> List[Job]:
    """A deterministic **two-pod mi300** stream where only a partition-mode
    reconfigure (``ReconfigurePartition``) saves a deadline job's SLO — no
    eviction chain can, because nothing about the *fixed-mode* hardware is
    fast enough.

    Timeline on two 16×16 mi300 pods booted in ``spx-nps1``
    (fragmentation-aware placement):

    1. t=0: two long priority-1 **training** holders (8×16 each,
       ``long_s`` pinned seconds) arrive; frag-aware placement puts one
       on each pod — 128 chips free per pod, no 256-chip rectangle
       anywhere.
    2. t=10: a priority-0 **batch** decode job pinned to a full pod
       (16s.256c, ~``deadline_nominal_s`` modeled seconds of work,
       ``slo_factor=0.9``) arrives. Decode at that scale is HBM-bound,
       so its deadline (arrival + 0.9 × the NPS1 ideal) is *sub-ideal*:
       no NPS1 placement — on these pods or an empty one — can meet it,
       which makes every eviction rescue structurally futile
       (``slo_profiles`` is empty), and the holders outrank it anyway
       (shrink/preempt/migrate victims need strictly lower priority).
    3. With ``"reconfigure"`` in the ``PolicySpec`` allowlist the
       scheduler drains pod 0's holder to pod 1 (the beneficiary-less
       DCN-priced ``MigrateTenant`` move), pays the fixed mode-switch
       downtime, flips pod 0 to ``cpx-nps4`` (NPS4 memory interleaving:
       1.3× effective HBM bandwidth), and places the job under the
       target mode's PerfModel — its bandwidth-bound step time drops
       ~1.3×, beating the 0.9 deadline with the drain + downtime charged
       to its start delay. ``cpx-nps1`` (compute-only uplift) is probed
       first in mode-name order and correctly rejected: the job is not
       FLOP-bound. Without ``"reconfigure"`` the job queues until a
       holder finishes at ``long_s`` and **misses** — the same trace
       flips miss → hit on the mode switch alone.
    """
    from repro.core.hw import MI300X, get_mode
    from repro.core.perfmodel import model_for_mode
    perf = model_for_mode(MI300X, get_mode(MI300X, "spx-nps1"))
    step = perf.options(
        Job(job_id=-1, kind=BATCH, arch="llama3-8b", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="16s.256c"))[0].step_time
    return [
        Job(job_id=0, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=0.3, priority=1),
        Job(job_id=1, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=0.3, priority=1),
        Job(job_id=2, kind=BATCH, arch="llama3-8b", shape="decode_32k",
            arrival_s=10.0, profile="16s.256c", u_compute=0.3,
            steps=max(1, round(deadline_nominal_s / step)),
            slo_factor=0.9, priority=0),
    ]


def twin_showcase(long_s: float = 1_500.0,
                  steps: int = 1_000,
                  slo_factor: float = 25.0) -> List[Job]:
    """A deterministic single-pod stream where a deadline job is only
    rescuable by a **twin-offload shrink**: the pure elastic shrink
    misses the SLO and preemption is blocked by the priority discipline.

    Timeline on one 16×16 pod (completely full at t=0):

    1. t=0: three pinned **training** holders (8×16 at the bottom, 8×8
       and a 4×8) plus a low-utilisation pinned **batch** decode job on
       a 2s.32c slice (4×8) fill all 256 chips for ``long_s`` seconds.
       Training jobs refuse ``ignore_pin`` resizing, so the batch job is
       the only shrinkable victim — and shrinking it 2s.32c → 1s.16c
       mints exactly one 4×4 hole.
    2. t=10: an **unpinned** llama3-8b ``decode_32k`` serving job
       arrives with a deadline (``slo_factor`` × its ideal duration,
       which comes from the big clean profiles and is therefore
       identical whether or not twin pricing is enabled). Its KV cache
       does not fit a 16-chip slice: the plain ``1s.16c`` rung spills
       the KV tail over the host link and is ~5× too slow for the
       deadline, while every plain rung that *would* meet it needs at
       least a 4×8 rectangle — more than the shrink can mint.
    3. With ``ClusterScheduler(twin=True)`` the PerfModel also prices
       the ``1s.16c+cpu…`` twin rung — the spilled KV tail's gather
       runs host-side against DRAM instead of round-tripping the link —
       which meets the deadline on the 4×4 the shrink mints: the
       ``shrink`` action fires and the job **hits** its SLO. With twin
       pricing off there is no feasible rescue (preemption finds no
       strictly-lower-priority victim), the job waits for a holder to
       finish and **misses**. One flag, opposite verdicts.
    """
    return [
        Job(job_id=0, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=long_s, u_compute=0.3, priority=0),
        Job(job_id=1, kind=TRAINING, arch="qwen3-32b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="4s.64c",
            duration_s=long_s, u_compute=0.3, priority=0),
        Job(job_id=2, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="2s.32c",
            duration_s=long_s, u_compute=0.05, priority=0),
        Job(job_id=3, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="2s.32c",
            duration_s=long_s, u_compute=0.3, priority=0),
        Job(job_id=4, kind=SERVING, arch="llama3-8b", shape="decode_32k",
            arrival_s=10.0, steps=steps, slo_factor=slo_factor,
            priority=0),
    ]
