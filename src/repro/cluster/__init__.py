"""repro.cluster — trace-driven multi-pod scheduling on static slices.

The layer above ``serving.SliceRuntime``: a ``ClusterScheduler`` owns N
statically partitioned pods and drives a mixed job stream (serving tenants,
training runs, low-utilization batch/analytics) through admit → place →
run → complete, with MISO-style slice-profile selection, fragmentation-aware
placement, transactional ``repack()`` defragmentation priced at modeled
migration cost, and shared-power-cap admission.
"""
from repro.cluster.trace import (Job, TraceConfig, elastic_showcase,
                                 fragmentation_showcase, generate_trace,
                                 grow_showcase, preemption_showcase)
from repro.cluster.placement import (Candidate, FirstFitPolicy,
                                     FragAwarePolicy, PlacementPolicy,
                                     RescueOption, cheapest_rescue,
                                     feasible_options, get_policy)
from repro.cluster.scheduler import (ClusterScheduler, JobRecord, PodState,
                                     SuspendSnapshot)
from repro.cluster.metrics import ClusterMetrics, format_metrics, summarize

__all__ = [
    "Job", "TraceConfig", "generate_trace", "fragmentation_showcase",
    "elastic_showcase", "preemption_showcase", "grow_showcase",
    "Candidate", "PlacementPolicy", "FirstFitPolicy", "FragAwarePolicy",
    "RescueOption", "cheapest_rescue", "feasible_options", "get_policy",
    "ClusterScheduler", "JobRecord", "PodState", "SuspendSnapshot",
    "ClusterMetrics", "summarize", "format_metrics",
]
