"""repro.cluster — trace-driven multi-pod scheduling on static slices.

The layer above ``serving.SliceRuntime``: a ``ClusterScheduler`` owns N
statically partitioned pods and drives a mixed job stream (serving tenants,
training runs, low-utilization batch/analytics) through admit → place →
run → complete. Every state mutation is a first-class **Action**
(``Place`` / ``Repack`` / ``Shrink`` / ``Grow`` / ``Preempt`` /
``MigrateAcrossPods``) with a uniform ``probe → ActionOutcome`` (feasible?
priced cost? projected SLO effect?) and transactional
``apply()``/``rollback()``; a ``SchedulerPolicy``
(``GreedyCheapestRescue``, the chaining ``LookAheadPolicy``, or the
budgeted ``SearchPolicy`` of ``cluster/planner.py``) selects among the
actions a declarative ``PolicySpec`` allows. Placement scoring
stays MISO-style and fragmentation-aware; in-pod moves are priced over the
pod's host links, cross-pod migration over its DCN (``PodSpec.dcn_bw``).
"""
from repro.cluster.trace import (Job, TraceConfig, elastic_showcase,
                                 fragmentation_showcase, generate_trace,
                                 grow_showcase, load_csv,
                                 lookahead_showcase, migration_showcase,
                                 preemption_showcase, reconfigure_showcase,
                                 search_showcase, twin_showcase)
from repro.cluster.placement import (Candidate, FirstFitPolicy,
                                     FragAwarePolicy, PlacementPolicy,
                                     get_policy)
from repro.cluster.actions import (Action, ActionOutcome, Grow,
                                   GreedyCheapestRescue, LookAheadPolicy,
                                   MigrateAcrossPods, Place, PolicySpec,
                                   Preempt, ProbeCache,
                                   ReconfigurePartition, Repack,
                                   SchedulerPolicy, Shrink,
                                   get_scheduler_policy,
                                   parse_actions, select_cheapest,
                                   ACTION_KINDS, SCHEDULER_POLICY_NAMES)
from repro.cluster.planner import RebalanceController, SearchPolicy
from repro.cluster.scheduler import (ClusterScheduler, JobRecord, PodState,
                                     SuspendSnapshot)
from repro.cluster.metrics import ClusterMetrics, format_metrics, summarize
from repro.cluster.loadgen import (BurstyCurve, ConstantCurve, DiurnalCurve,
                                   LoadCurve, arrival_counts, arrival_times,
                                   get_curve, service_rate, serving_workload,
                                   CURVE_NAMES)
from repro.cluster.autoscale import (AutoscaleController, AutoscaleSpec,
                                     MigrateTenant, ShrinkTenant,
                                     TenantSignals)

__all__ = [
    # traces
    "Job", "TraceConfig", "generate_trace", "load_csv",
    "fragmentation_showcase",
    "elastic_showcase", "preemption_showcase", "grow_showcase",
    "migration_showcase", "lookahead_showcase", "search_showcase",
    "twin_showcase", "reconfigure_showcase",
    # placement (candidate enumeration)
    "Candidate", "PlacementPolicy", "FirstFitPolicy", "FragAwarePolicy",
    "get_policy",
    # the Action API + selection policies
    "Action", "ActionOutcome", "Place", "Repack", "Shrink", "Grow",
    "Preempt", "MigrateAcrossPods", "ReconfigurePartition", "PolicySpec",
    "SchedulerPolicy",
    "GreedyCheapestRescue", "LookAheadPolicy", "SearchPolicy",
    "RebalanceController", "ProbeCache", "get_scheduler_policy",
    "parse_actions", "select_cheapest", "ACTION_KINDS",
    "SCHEDULER_POLICY_NAMES",
    # scheduler + metrics
    "ClusterScheduler", "JobRecord", "PodState", "SuspendSnapshot",
    "ClusterMetrics", "summarize", "format_metrics",
    # load generation + the autoscale control loop
    "LoadCurve", "ConstantCurve", "DiurnalCurve", "BurstyCurve",
    "CURVE_NAMES", "get_curve", "arrival_counts", "arrival_times",
    "service_rate", "serving_workload",
    "AutoscaleController", "AutoscaleSpec", "TenantSignals",
    "ShrinkTenant", "MigrateTenant",
]
