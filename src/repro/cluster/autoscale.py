"""SLO-driven autoscaling of serving tenants over the priced Action API.

The missing control loop over PR 1–6's mechanisms: every control
interval the ``AutoscaleController`` folds one interval of seeded
arrivals (``loadgen``) into a per-tenant queue model, reads the signals
(virtual queue wait p50/p99, queue depth, admission rejections,
utilization), and — under hysteresis bands with per-tenant cooldowns
and a chip-hours budget — resizes tenants through the transactional
actions:

* **scale up** — ``Grow.find(..., ascending=True, max_chips=...)``
  opens a recorded transaction, the budget check runs against the
  *priced* outcome, and the controller either commits or rolls the
  grid extension back (a denied grow leaves no trace);
* **scale up, blocked locally** — ``MigrateTenant`` relocates the hot
  tenant itself to the pod with headroom (the beneficiary-less variant
  of ``MigrateAcrossPods``), so the next interval's grow has room;
* **scale down** — ``ShrinkTenant`` drops one profile rung in place
  (the beneficiary-less ``Shrink``), but only when the *projected*
  utilization on the smaller slice still clears the low watermark —
  the hysteresis gap that, together with the cooldown, makes
  grow/shrink flapping structurally impossible.

The queue model is an interval-batched Lindley recursion on the
virtual waiting time ``W``: with ``A`` arrivals over an interval of
``dt`` seconds and modeled service rate ``mu`` (``req_per_step`` per
decode step of the tenant's *current* slice — growing the slice is
what raises ``mu``), ``W' = max(0, W + A/mu − dt)``. ``W'`` is the
p99-wait signal (the worst backlogged request), the interval midpoint
``(W + W')/2`` the p50. Deterministic, O(1) per tenant-interval, and
bit-identical across replays of the same seed.

``mode="observe"`` runs the same signals without issuing any action —
the fixed-provisioning baseline in the day-in-the-life benchmark, so
both sides of the chip-hours-vs-p99 comparison report identical
latency accounting.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import numpy as np

from repro.core.slices import get_profile
from repro.cluster.actions import (Action, ActionOutcome, Grow,
                                   MigrateAcrossPods, _realloc_victim)
from repro.cluster.loadgen import LoadCurve, arrival_counts

if TYPE_CHECKING:  # pragma: no cover
    from repro.cluster.scheduler import ClusterScheduler, JobRecord, PodState

__all__ = ["AutoscaleSpec", "AutoscaleController", "TenantSignals",
           "ShrinkTenant", "MigrateTenant"]


@dataclass(frozen=True)
class AutoscaleSpec:
    """Knobs of the control loop. Watermarks are utilizations
    (arrival rate / modeled service rate); the hysteresis gap between
    ``hi`` and ``lo`` plus the per-tenant ``cooldown_s`` is the
    anti-flapping guarantee."""
    interval_s: float = 300.0       # control period
    slo_p99_s: float = 60.0         # p99 queue-wait target
    hi_watermark: float = 0.70      # scale up above this utilization
    lo_watermark: float = 0.35      # scale down only below this (projected)
    cooldown_s: float = 1500.0      # min seconds between actions per tenant
    req_per_step: float = 1.0       # requests retired per decode step
    min_chips: int = 16             # smallest profile a shrink may reach
    max_chips: int = 128            # largest profile a grow may reach
    chip_hours_budget: Optional[float] = None   # cap on serving chip-hours
    max_queue: Optional[float] = None           # admission bound (requests)
    ema_alpha: float = 0.5          # smoothing of the utilization signal
    mode: str = "hysteresis"        # "hysteresis" acts, "observe" only watches


@dataclass
class TenantSignals:
    """What the controller saw for one tenant over one interval."""
    queue_depth: float
    wait_p50_s: float
    wait_p99_s: float
    rho: float                      # smoothed arrival rate / service rate
    rejected: float                 # requests dropped at the admission bound
    rate_rps: float


@dataclass
class _TenantState:
    wait_s: float = 0.0             # Lindley virtual waiting time
    ema_rate: Optional[float] = None
    rejected: float = 0.0
    last_action_t: float = -math.inf


class ShrinkTenant(Action):
    """Drop a running serving tenant one profile rung in place — the
    beneficiary-less ``Shrink``: same in-place rectangle swap
    (``_realloc_victim``), same host-link pricing of the re-planned
    resident bytes, but the freed chips *are* the win (fewer chip-hours)
    rather than an origin for somebody else."""
    kind = "shrink"

    def __init__(self, rec: "JobRecord", pod: "PodState", small):
        super().__init__(rec)
        self.pod = pod
        self.small = small

    @classmethod
    def find(cls, sched: "ClusterScheduler", pod: "PodState",
             rec: "JobRecord", t: float,
             min_chips: int = 16) -> Optional["ShrinkTenant"]:
        """One rung down: the largest profile strictly smaller than the
        tenant's current one, floored at ``min_chips``."""
        smaller = [sc for sc in sched.perf.options(rec.job, ignore_pin=True)
                   if min_chips <= sc.profile.n_chips < rec.n_chips]
        if not smaller:
            return None
        # equal-chips tie (a profile and its twin rung): prefer the faster
        # step — the twin rung keeps utilization higher on the same chips
        small = max(smaller,
                    key=lambda sc: (sc.profile.n_chips, -sc.step_time))
        act = cls(rec, pod, small)
        act.probe(sched, t)
        return act

    def probe(self, sched, t, extra_delay=0.0) -> ActionOutcome:
        # power-of-two profile sides: a smaller profile always fits at
        # the tenant's own origin, so a self-shrink is always feasible
        mig_s = int(self.small.plan.resident_bytes) / sched._pod_host_bw
        self.outcome = ActionOutcome(True, cost_s=mig_s,
                                     start_delay_s=mig_s + extra_delay)
        return self.outcome

    def apply(self, sched, t, extra_delay=0.0, record=True) -> None:
        self._begin(sched, record)
        pod, rec, small = self.pod, self.rec, self.small
        applied = _realloc_victim(sched, pod, rec, small.profile)
        assert applied, "a smaller power-of-two profile fits in place"
        sched._shrinks += 1
        moved_bytes = int(small.plan.resident_bytes)
        rec.profile_name = small.profile.name
        rec.rung = small.rung
        rec.u_compute = sched._u_for(rec, small.terms)
        rec.step_time_s = small.step_time
        rec.resident_bytes = moved_bytes
        rec.shrunk = True
        pod.sim.resize(rec.job.job_id, small.profile.n_chips,
                       rec.u_compute, small.step_time)
        sched._charge_migration(pod, moved_bytes, [rec], t)
        sched._reissue_after_resize(pod, rec, t)


class MigrateTenant(MigrateAcrossPods):
    """Relocate the hot tenant *itself* to a pod with more headroom —
    the beneficiary-less ``MigrateAcrossPods`` (the parent's DCN-priced
    ``_relocate`` does the move; nobody takes the drained rectangle).
    The fallback when a grow finds no local rectangle extension: next
    interval, the grow retries on the roomier pod."""
    kind = "migrate"

    def __init__(self, pod: "PodState", victim: "JobRecord",
                 dest: "PodState"):
        Action.__init__(self, None)
        self.src = pod
        self.victim = victim
        self.dest = dest
        self.sc = None
        self.dest_origin: Optional[Tuple[int, int]] = None

    @classmethod
    def find(cls, sched: "ClusterScheduler", pod: "PodState",
             rec: "JobRecord", t: float) -> Optional["MigrateTenant"]:
        """Destination pods by descending free chips (index breaks ties);
        only strictly-roomier pods qualify, which rules out ping-pong
        between equally loaded pods."""
        dests = sorted((d for d in sched.pods if d is not pod),
                       key=lambda d: (-d.partitioner.free_chips(), d.idx))
        for dest in dests:
            if dest.partitioner.free_chips() <= pod.partitioner.free_chips():
                continue
            act = cls(pod, rec, dest)
            if act.probe(sched, t).feasible:
                return act
        return None

    def probe(self, sched, t, extra_delay=0.0) -> ActionOutcome:
        profile = get_profile(self.victim.profile_name)
        origins = self.dest.partitioner.origins_for(profile)
        if not origins:
            self.outcome = ActionOutcome(
                False, reason="destination pod has no aligned origin for "
                              "the tenant's profile")
            return self.outcome
        if not self._dest_power_ok(sched):
            self.outcome = ActionOutcome(
                False, reason="tenant fails the destination power gate")
            return self.outcome
        self.dest_origin = origins[0]
        cost = self._cost(sched)
        self.outcome = ActionOutcome(True, cost_s=cost.total_s,
                                     start_delay_s=cost.total_s + extra_delay)
        return self.outcome

    def apply(self, sched, t, extra_delay=0.0, record=True) -> None:
        self._begin(sched, record)
        self._relocate(sched, t)


class AutoscaleController:
    """The closed loop: per-tenant load curves in, priced resize actions
    out. Handed to ``ClusterScheduler(autoscaler=...)``, which fires
    ``control`` every ``spec.interval_s`` of virtual time and folds
    ``metrics_fields`` into the run's ``ClusterMetrics``."""

    def __init__(self, curves: Dict[int, LoadCurve],
                 spec: Optional[AutoscaleSpec] = None, *, seed: int = 0):
        self.curves = dict(curves)
        self.spec = spec if spec is not None else AutoscaleSpec()
        self.seed = seed
        # (t, job_id, kind) for every committed action — the flapping audit
        self.action_log: List[Tuple[float, int, str]] = []
        self.signal_log: List[Tuple[float, int, TenantSignals]] = []
        self._states: Dict[int, _TenantState] = {}
        self._arrivals: Optional[Dict[int, np.ndarray]] = None
        self._last_t = 0.0
        self._chip_s = 0.0              # exact serving chips × seconds
        self._wait_samples: List[float] = []
        self._hits = 0
        self._intervals = 0
        self._resizes = 0
        self._grows = 0
        self._shrinks = 0
        self._migrations = 0
        self._budget_denials = 0

    # ------------------------------------------------------------------
    # the control tick
    # ------------------------------------------------------------------
    def control(self, sched: "ClusterScheduler", t: float) -> bool:
        """One control interval at virtual time ``t``. Returns True when
        any action committed (the scheduler then re-drains its queue —
        a shrink may have freed chips a queued job wants)."""
        spec = self.spec
        recs = self._live(sched)
        dt = t - self._last_t
        if dt > 0:
            # chips held since the last tick: resizes only ever happen at
            # control ticks, so the piecewise-constant integral is exact
            self._chip_s += sum(r.n_chips for r in recs.values()) * dt
        self._ensure_arrivals(sched)
        k = int(round(t / spec.interval_s)) - 1
        committed = False
        for jid in sorted(recs):
            rec = recs[jid]
            st = self._states.setdefault(jid, _TenantState())
            arr = self._arrivals[jid]
            a = int(arr[k]) if 0 <= k < arr.shape[0] else 0
            mu = spec.req_per_step / rec.step_time_s
            w_prev = st.wait_s
            w = max(0.0, w_prev + a / mu - spec.interval_s)
            rejected = 0.0
            if spec.max_queue is not None and w * mu > spec.max_queue:
                rejected = w * mu - spec.max_queue
                w = spec.max_queue / mu
            st.wait_s = w
            st.rejected += rejected
            rate = a / spec.interval_s
            st.ema_rate = (rate if st.ema_rate is None else
                           spec.ema_alpha * rate
                           + (1.0 - spec.ema_alpha) * st.ema_rate)
            sig = TenantSignals(queue_depth=w * mu,
                                wait_p50_s=0.5 * (w_prev + w),
                                wait_p99_s=w, rho=st.ema_rate / mu,
                                rejected=rejected, rate_rps=rate)
            self.signal_log.append((t, jid, sig))
            self._intervals += 1
            self._wait_samples.append(sig.wait_p99_s)
            if sig.wait_p99_s <= spec.slo_p99_s:
                self._hits += 1
            if spec.mode != "hysteresis":
                continue
            if t - st.last_action_t < spec.cooldown_s:
                continue
            if (sig.wait_p99_s > spec.slo_p99_s or rejected > 0
                    or sig.rho > spec.hi_watermark):
                committed |= self._scale_up(sched, rec, st, t)
            elif sig.rho < spec.lo_watermark:
                committed |= self._scale_down(sched, rec, st, t)
        self._last_t = t
        return committed

    def finalize(self, sched: "ClusterScheduler", end_s: float) -> None:
        """Close the chip-seconds integral at the horizon."""
        if end_s > self._last_t:
            recs = self._live(sched)
            self._chip_s += (sum(r.n_chips for r in recs.values())
                             * (end_s - self._last_t))
            self._last_t = end_s

    def metrics_fields(self) -> Dict[str, float]:
        """The autoscale columns ``summarize`` folds into ClusterMetrics."""
        waits = np.asarray(self._wait_samples, dtype=float)
        chip_h = self._chip_s / 3600.0
        return dict(
            serving_p50_s=(float(np.percentile(waits, 50))
                           if waits.size else 0.0),
            serving_p99_s=(float(np.percentile(waits, 99))
                           if waits.size else 0.0),
            serving_slo_hit_rate=(self._hits / self._intervals
                                  if self._intervals else 0.0),
            serving_chip_hours=chip_h,
            chip_hours_per_slo_hit=(chip_h / self._hits
                                    if self._hits else 0.0),
            autoscale_resizes=self._resizes,
        )

    # ------------------------------------------------------------------
    # decisions
    # ------------------------------------------------------------------
    def _scale_up(self, sched, rec, st: _TenantState, t: float) -> bool:
        pod = sched.pods[rec.pod_idx]
        act = Grow.find(sched, pod, rec, t, record=True,
                        max_chips=self.spec.max_chips, ascending=True)
        if act is None:
            return self._migrate_toward_headroom(sched, pod, rec, st, t)
        if not self._within_budget(sched, rec, act.sc.profile.n_chips, t):
            # the priced probe already extended the grid inside its
            # transaction — a budget denial rolls the extension back
            act.rollback(sched)
            self._budget_denials += 1
            return False
        act.apply(sched, t, record=True)
        act.commit(sched)
        self._grows += 1
        self._log(t, rec, "grow", st)
        return True

    def _scale_down(self, sched, rec, st: _TenantState, t: float) -> bool:
        pod = sched.pods[rec.pod_idx]
        act = ShrinkTenant.find(sched, pod, rec, t,
                                min_chips=self.spec.min_chips)
        if act is None:
            return False
        mu_small = self.spec.req_per_step / act.small.step_time
        if (st.ema_rate is not None
                and st.ema_rate / mu_small >= self.spec.lo_watermark):
            return False    # the smaller slice would leave no headroom
        act.apply(sched, t, record=False)
        self._shrinks += 1
        self._log(t, rec, "shrink", st)
        return True

    def _migrate_toward_headroom(self, sched, pod, rec,
                                 st: _TenantState, t: float) -> bool:
        act = MigrateTenant.find(sched, pod, rec, t)
        if act is None:
            return False
        act.apply(sched, t, record=False)
        self._migrations += 1
        self._log(t, rec, "migrate", st)
        return True

    def _within_budget(self, sched, rec, new_chips: int, t: float) -> bool:
        if self.spec.chip_hours_budget is None:
            return True
        chips_after = (sum(r.n_chips for r in self._live(sched).values())
                       - rec.n_chips + new_chips)
        horizon = sched.horizon_s
        projected = (self._chip_s
                     + chips_after * max(0.0, horizon - t)) / 3600.0
        return projected <= self.spec.chip_hours_budget

    # ------------------------------------------------------------------
    # plumbing
    # ------------------------------------------------------------------
    def _log(self, t: float, rec, kind: str, st: _TenantState) -> None:
        self.action_log.append((t, rec.job.job_id, kind))
        st.last_action_t = t
        self._resizes += 1

    def _live(self, sched) -> Dict[int, "JobRecord"]:
        return {r.job.job_id: r
                for pod in sched.pods for r in pod.jobs.values()
                if r.job.job_id in self.curves and not r.finished}

    def _ensure_arrivals(self, sched) -> None:
        if self._arrivals is not None:
            return
        n = int(math.ceil(sched.horizon_s / self.spec.interval_s - 1e-9))
        self._arrivals = {
            jid: arrival_counts(curve, self.spec.interval_s, n,
                                seed=(self.seed, jid))
            for jid, curve in sorted(self.curves.items())}
