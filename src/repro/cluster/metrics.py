"""Aggregate cluster metrics: the quantities §V of the paper argues a
slice-scheduler must win on — makespan, queueing delay, SLO attainment,
chip-hour utilization, fragmentation, energy.

``ClusterScheduler`` integrates the time-weighted quantities (busy
chip-seconds, fragmentation ratio, pod power draw via ``core.power``) over
its event timeline; ``summarize`` folds them with the per-job records into
one comparable row per policy run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, List, Sequence

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.cluster.scheduler import JobRecord


@dataclass(frozen=True)
class ClusterMetrics:
    policy: str
    n_jobs: int
    placed: int
    completed: int
    left_queued: int            # never placed within the horizon
    still_running: int
    makespan_s: float           # last completion − first arrival
    mean_queue_delay_s: float
    p95_queue_delay_s: float
    slo_attainment: float       # completed-by-deadline / jobs (placed or not)
    chip_hour_utilization: float  # busy chip-s / (total chips × elapsed)
    frag_time_avg: float        # time-averaged fragmentation ratio
    energy_J: float             # modeled (synthetic power calibration, hw.py)
    energy_per_chip_hour_kJ: float
    repacks: int
    repack_failures: int
    shrinks: int                # elastic profile shrinks of running jobs
    grows: int                  # elastic extend()s of running jobs
    preemptions: int            # checkpoint evictions of running jobs
    resumes: int                # resumed-from-checkpoint placements
    wasted_checkpoint_chip_s: float  # chips × seconds spent on ckpt traffic
    migrated_bytes: int         # in-pod moves over the host links (bytes)
    migration_s: float
    migrations: int             # cross-pod relocations (MigrateAcrossPods)
    dcn_migrated_bytes: int     # resident state moved over the DCN (bytes)
    dcn_migration_s: float      # save+restore seconds paid over the DCN
    power_deferrals: int        # jobs deferred ≥ once by the power gate
    # -- partition-mode column (ReconfigurePartition commits) --
    reconfigs: int = 0          # committed pod partition-mode switches
    # -- probe-cache columns (cluster/actions.py ProbeCache) --
    rescue_probes_priced: int = 0   # structural cores actually evaluated
    probe_cache_hits: int = 0       # cores served from the ProbeCache
    # -- autoscale columns (all-zero unless an AutoscaleController ran) --
    serving_p50_s: float = 0.0          # modeled serving queue-wait p50
    serving_p99_s: float = 0.0          # modeled serving queue-wait p99
    serving_slo_hit_rate: float = 0.0   # tenant-intervals with p99 ≤ SLO
    serving_chip_hours: float = 0.0     # exact chips×time serving integral
    chip_hours_per_slo_hit: float = 0.0  # the headline efficiency number
    autoscale_resizes: int = 0          # committed grow/shrink/migrate

    def as_dict(self) -> Dict[str, object]:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


def summarize(policy: str, records: Sequence["JobRecord"], *,
              elapsed_s: float, total_chips: int, busy_chip_s: float,
              frag_time_avg: float, energy_J: float,
              repacks: int = 0, repack_failures: int = 0, shrinks: int = 0,
              grows: int = 0, preemptions: int = 0, resumes: int = 0,
              wasted_checkpoint_chip_s: float = 0.0,
              migrated_bytes: int = 0, migration_s: float = 0.0,
              migrations: int = 0, dcn_migrated_bytes: int = 0,
              dcn_migration_s: float = 0.0,
              power_deferrals: int = 0,
              reconfigs: int = 0,
              rescue_probes_priced: int = 0, probe_cache_hits: int = 0,
              serving_p50_s: float = 0.0, serving_p99_s: float = 0.0,
              serving_slo_hit_rate: float = 0.0,
              serving_chip_hours: float = 0.0,
              chip_hours_per_slo_hit: float = 0.0,
              autoscale_resizes: int = 0) -> ClusterMetrics:
    placed = [r for r in records if r.place_s is not None]
    completed = [r for r in placed if r.finished]
    delays = np.asarray([r.place_s - r.job.arrival_s for r in placed],
                        dtype=float)
    slo_ok = sum(1 for r in completed
                 if r.deadline_s is None or r.finish_s <= r.deadline_s)
    arrivals = [r.job.arrival_s for r in records]
    finishes = [r.finish_s for r in completed]
    makespan = (max(finishes) - min(arrivals)) if finishes else 0.0
    busy_frac = (busy_chip_s / (total_chips * elapsed_s)
                 if elapsed_s > 0 else 0.0)
    chip_hours = busy_chip_s / 3600.0
    return ClusterMetrics(
        policy=policy,
        n_jobs=len(records),
        placed=len(placed),
        completed=len(completed),
        left_queued=len(records) - len(placed),
        still_running=len(placed) - len(completed),
        makespan_s=makespan,
        mean_queue_delay_s=float(delays.mean()) if delays.size else 0.0,
        p95_queue_delay_s=(float(np.percentile(delays, 95))
                           if delays.size else 0.0),
        slo_attainment=slo_ok / len(records) if records else 0.0,
        chip_hour_utilization=busy_frac,
        frag_time_avg=frag_time_avg,
        energy_J=energy_J,
        energy_per_chip_hour_kJ=(energy_J / 1e3 / chip_hours
                                 if chip_hours else 0.0),
        repacks=repacks,
        repack_failures=repack_failures,
        shrinks=shrinks,
        grows=grows,
        preemptions=preemptions,
        resumes=resumes,
        wasted_checkpoint_chip_s=wasted_checkpoint_chip_s,
        migrated_bytes=migrated_bytes,
        migration_s=migration_s,
        migrations=migrations,
        dcn_migrated_bytes=dcn_migrated_bytes,
        dcn_migration_s=dcn_migration_s,
        power_deferrals=power_deferrals,
        reconfigs=reconfigs,
        rescue_probes_priced=rescue_probes_priced,
        probe_cache_hits=probe_cache_hits,
        serving_p50_s=serving_p50_s,
        serving_p99_s=serving_p99_s,
        serving_slo_hit_rate=serving_slo_hit_rate,
        serving_chip_hours=serving_chip_hours,
        chip_hours_per_slo_hit=chip_hours_per_slo_hit,
        autoscale_resizes=autoscale_resizes,
    )


# every count below renders with thousands separators: at 100k-job scale
# the bare-int forms ran six-plus digits together and the policy columns
# became unreadable (and misaligned against the already-separated floats)
_ROWS = (
    ("jobs placed/completed/queued", lambda m: (
        f"{m.placed:,}/{m.completed:,}/{m.left_queued:,}"
        + (f" (+{m.still_running:,} running at horizon)"
           if m.still_running else ""))),
    ("makespan", lambda m: f"{m.makespan_s:,.1f} s"),
    ("queue delay mean/p95", lambda m: (
        f"{m.mean_queue_delay_s:,.1f} / {m.p95_queue_delay_s:,.1f} s")),
    ("SLO attainment", lambda m: f"{m.slo_attainment:.1%}"),
    ("chip-hour utilization", lambda m: f"{m.chip_hour_utilization:.1%}"),
    ("fragmentation (time-avg)", lambda m: f"{m.frag_time_avg:.3f}"),
    ("energy (modeled)", lambda m: (
        f"{m.energy_J / 1e6:,.1f} MJ "
        f"({m.energy_per_chip_hour_kJ:,.0f} kJ/chip-hour)")),
    ("repacks (ok/failed)", lambda m: f"{m.repacks:,}/{m.repack_failures:,}"),
    ("elastic shrinks/grows", lambda m: f"{m.shrinks:,}/{m.grows:,}"),
    ("preemptions/resumes", lambda m: f"{m.preemptions:,}/{m.resumes:,}"),
    ("wasted checkpoint chip-s", lambda m: (
        f"{m.wasted_checkpoint_chip_s:,.1f}")),
    ("migration (in-pod)", lambda m: (
        f"{m.migrated_bytes / 2**30:,.1f} GiB, {m.migration_s:,.2f} s")),
    ("migration (cross-pod DCN)", lambda m: (
        f"{m.migrations:,} moves, {m.dcn_migrated_bytes / 2**30:,.1f} GiB, "
        f"{m.dcn_migration_s:,.2f} s")),
    ("power-deferred jobs", lambda m: f"{m.power_deferrals:,}"),
    ("partition reconfigures", lambda m: f"{m.reconfigs:,}"),
    ("rescue probes priced (cached)", lambda m: (
        f"{m.rescue_probes_priced:,} ({m.probe_cache_hits:,} hits)")),
    ("serving wait p50/p99", lambda m: (
        f"{m.serving_p50_s:,.1f} / {m.serving_p99_s:,.1f} s")),
    ("serving SLO hit rate", lambda m: f"{m.serving_slo_hit_rate:.1%}"),
    ("serving chip-hours (per SLO hit)", lambda m: (
        f"{m.serving_chip_hours:,.1f} ({m.chip_hours_per_slo_hit:,.3f})")),
    ("autoscale resizes", lambda m: f"{m.autoscale_resizes:,}"),
)


def format_metrics(metrics: Sequence[ClusterMetrics]) -> str:
    """Aligned comparison table, one column per policy run."""
    metrics = list(metrics)
    header = ["metric"] + [m.policy for m in metrics]
    rows: List[List[str]] = [header]
    for label, fmt in _ROWS:
        rows.append([label] + [fmt(m) for m in metrics])
    widths = [max(len(r[i]) for r in rows) for i in range(len(header))]
    lines = []
    for i, row in enumerate(rows):
        lines.append("  ".join(cell.ljust(w) for cell, w in zip(row, widths)))
        if i == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)
