"""The Action API — priced, transactional scheduler actions + policies.

Every way the cluster scheduler may mutate cluster state is a first-class
``Action`` object with one uniform life cycle:

    probe(sched, t) -> ActionOutcome     feasibility + priced cost +
                                         projected SLO effect (PerfModel)
    apply(sched, t)                      commit, recording a transaction
    rollback(sched)                      exact inverse of the last apply

``probe`` never changes observable state (grid trials are rolled back
through the partitioner's transaction primitives); ``apply`` opens a
copy-on-write undo-log ``Transaction`` first, so ``rollback`` restores
partitioner rectangles, the ``PodSimulator`` job sets, and pod power
draw bit-exactly — the property ``tests/test_actions.py`` pins. The log
saves state at first touch (O(touched pods/records), not O(cluster) —
what keeps look-ahead trials cheap on 100k-job traces); the legacy
full-snapshot path (``capture``/``restore``) is kept behind
``ClusterScheduler(snapshot_rollback=True)`` as the equivalence oracle.
That transactionality is what makes a look-ahead policy cheap:
trial-apply an action, probe what it enables, roll back if the chain
goes nowhere. Commit-only call sites pass ``record=False`` to skip
recording (see ``Action``).

The concrete actions:

* ``Place``   — admit a queued job on a scored ``Candidate`` (power-gated).
* ``Repack``  — transactional in-pod defragmentation (``repack()``), priced
  as the moved slices' resident bytes over the pod's host links.
* ``Shrink``  — resize a running batch job to a smaller profile (MISO-style
  online re-selection), priced as a host-link migration.
* ``Preempt`` — checkpoint-evict a strictly lower-priority batch job
  (``PerfModel.checkpoint_cost`` save/restore over the host links); also
  usable as a pure *enabler* (no beneficiary) by the look-ahead policy.
* ``Grow``    — extend a running job into free neighbour chips
  (``StaticPartitioner.extend``), priced like a shrink.
* ``MigrateAcrossPods`` — relocate a running lower-priority job to another
  pod over the **DCN** (``PodSpec.dcn_bw``: ``n_hosts`` NICs at
  ``ChipSpec.dcn_link_bw`` = 12.5e9 bytes/s each): the same
  ``PerfModel.checkpoint_cost`` save/restore pair as a preemption, priced
  over the DCN instead of the host links, except the victim never
  suspends — it resumes on the destination pod in the same event. This is
  the global load-balancing move in-pod rescues cannot express.

Selection is delegated to a ``SchedulerPolicy``: ``GreedyCheapestRescue``
reproduces the legacy ``cheapest_rescue`` comparator (cheapest priced
action wins; ties break least-disruptive: shrink < migrate < preempt),
``LookAheadPolicy`` may chain two actions (evict an enabler victim, then
place/rescue into what that frees — and it grows running neighbours into
rescue leftovers instead of waiting for the next completion event). Which
actions a scheduler may use at all is the declarative ``PolicySpec``
allowlist; the legacy ``elastic``/``priorities``/``grow`` booleans map
onto it via ``PolicySpec.from_flags`` (deprecation shims in
``ClusterScheduler``).

Units: times/costs in virtual seconds, volumes in bytes, slices in chips.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, fields as dc_fields, replace
from typing import (TYPE_CHECKING, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

from repro.core.perfmodel import InstanceLoad, PerfScore
from repro.core.slices import get_profile

from repro.cluster.placement import Candidate, candidate_on, modeled_duration
from repro.cluster.trace import BATCH

if TYPE_CHECKING:  # pragma: no cover
    from repro.cluster.scheduler import ClusterScheduler, JobRecord, PodState

# ---------------------------------------------------------------------------
# the declarative policy surface
# ---------------------------------------------------------------------------
RESCUE_KINDS = ("shrink", "preempt", "migrate", "reconfigure")
ACTION_KINDS = ("shrink", "preempt", "grow", "migrate", "reconfigure")
SCHEDULER_POLICY_NAMES = ("greedy", "lookahead", "search")

# deterministic tie-break among equally priced rescues: prefer the least
# disruptive — a shrink keeps the victim running in place, a migration
# keeps it running elsewhere, a preemption suspends it entirely, and a
# partition reconfigure drains a whole pod *and* pays mode-switch downtime
_DISRUPTION_RANK = {"shrink": 0, "migrate": 1, "preempt": 2,
                    "reconfigure": 3}


def parse_actions(spec: str) -> Tuple[str, ...]:
    """``"shrink,preempt"`` -> validated, canonically ordered action names.
    Empty string -> no elastic actions (placement/repack still apply)."""
    names = [n.strip() for n in spec.split(",") if n.strip()]
    unknown = [n for n in names if n not in ACTION_KINDS]
    if unknown:
        raise ValueError(f"unknown action(s) {unknown}; "
                         f"valid: {list(ACTION_KINDS)}")
    return tuple(k for k in ACTION_KINDS if k in names)


@dataclass(frozen=True)
class PolicySpec:
    """Declarative scheduler configuration: which reconfiguration actions
    are allowed (``actions`` ⊆ ``ACTION_KINDS``) and which
    ``SchedulerPolicy`` selects among them (``selector``).

    ``PolicySpec()`` is the PR 2/3 baseline (place + policy-gated repack
    only); ``PolicySpec.from_flags(elastic=..., priorities=..., grow=...)``
    maps the deprecated booleans onto the allowlist."""
    selector: str = "greedy"
    actions: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.selector not in SCHEDULER_POLICY_NAMES:
            raise ValueError(f"unknown selector {self.selector!r}; valid: "
                             f"{list(SCHEDULER_POLICY_NAMES)}")
        unknown = [a for a in self.actions if a not in ACTION_KINDS]
        if unknown:
            raise ValueError(f"unknown action(s) {unknown}; "
                             f"valid: {list(ACTION_KINDS)}")
        # canonical order + dedup so specs compare by meaning
        object.__setattr__(
            self, "actions",
            tuple(k for k in ACTION_KINDS if k in self.actions))

    @classmethod
    def from_flags(cls, *, elastic: bool = False, priorities: bool = False,
                   grow: bool = False) -> "PolicySpec":
        """The legacy boolean surface: ``elastic`` -> shrink,
        ``priorities`` -> preempt, ``grow`` -> grow."""
        actions = []
        if elastic:
            actions.append("shrink")
        if priorities:
            actions.append("preempt")
        if grow:
            actions.append("grow")
        return cls(selector="greedy", actions=tuple(actions))

    def enabled(self, kind: str) -> bool:
        return kind in self.actions


def deprecated_flags_spec(elastic, priorities, grow) -> Optional[PolicySpec]:
    """Shim for ``ClusterScheduler(elastic=…, priorities=…, grow=…)``:
    warn once per call site and fold the booleans into a ``PolicySpec``.
    Returns ``None`` when no flag was passed (all still ``None``)."""
    if elastic is None and priorities is None and grow is None:
        return None
    warnings.warn(
        "ClusterScheduler(elastic=, priorities=, grow=) is deprecated; "
        "pass spec=PolicySpec(actions=(...)) instead "
        "(elastic->'shrink', priorities->'preempt', grow->'grow')",
        DeprecationWarning, stacklevel=3)
    return PolicySpec.from_flags(elastic=bool(elastic),
                                 priorities=bool(priorities),
                                 grow=bool(grow))


# ---------------------------------------------------------------------------
# outcomes + transactions
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ActionOutcome:
    """What one probed action would do, before anyone pays for it.

    ``cost_s`` is the priced data movement in seconds (host links for
    in-pod moves, DCN for cross-pod), ``start_delay_s`` the wall delay the
    beneficiary would pay before starting, ``projected_finish_s`` its
    modeled finish (via the shared PerfModel), and ``meets_slo`` whether
    that finish makes the deadline (``None`` when there is no beneficiary
    or no deadline). ``reason`` says why an infeasible probe failed."""
    feasible: bool
    cost_s: float = 0.0
    start_delay_s: float = 0.0
    projected_finish_s: Optional[float] = None
    meets_slo: Optional[bool] = None
    reason: str = ""


_COUNTERS = ("_repacks", "_repack_failures", "_shrinks", "_grows",
             "_preemptions", "_resumes", "_wasted_checkpoint_chip_s",
             "_migrated_bytes", "_migration_s", "_power_deferrals",
             "_migrations", "_dcn_migrated_bytes", "_dcn_migration_s",
             "_reconfigs")


def capture(sched: "ClusterScheduler",
            extra: Sequence["JobRecord"] = ()) -> dict:
    """Snapshot everything an action may mutate: per-pod partitioner state
    (grid, allocation table — object identities preserved so live
    ``SliceRuntime`` tenants keep their ``SliceAllocation``), simulator
    job sets, the scheduler queue, counters, and every reachable
    ``JobRecord``'s fields (``version`` excepted — versions only ever
    advance, so stale finish events stay stale across a rollback).
    ``extra`` adds records not yet reachable from a pod or the queue —
    the beneficiary an action is about to place."""
    from repro.cluster.scheduler import JobRecord
    pods = []
    recset: Dict[int, "JobRecord"] = {}
    for rec in extra:
        if rec is not None:
            recset[id(rec)] = rec
    for pod in sched.pods:
        pods.append(_save_pod(pod))
        for rec in pod.jobs.values():
            recset[id(rec)] = rec
    for rec in sched._queue:
        recset[id(rec)] = rec
    rec_fields = [f.name for f in dc_fields(JobRecord) if f.name != "version"]
    return {
        "pods": pods,
        "queue": list(sched._queue),
        "counters": {n: getattr(sched, n) for n in _COUNTERS},
        "records": [(rec, {k: getattr(rec, k) for k in rec_fields})
                    for rec in recset.values()],
        "rec_fields": rec_fields,
    }


def restore(sched: "ClusterScheduler", snap: dict) -> None:
    """Exact inverse of every mutation since the matching ``capture``.

    Record versions are *bumped*, not restored (monotone versions are what
    keeps ghost finish events pushed during the rolled-back span stale
    forever), and live placements get their finish event re-issued at the
    restored time."""
    for pod, ps in zip(sched.pods, snap["pods"]):
        _restore_pod(pod, ps)
    sched._queue[:] = snap["queue"]
    sched._queued_ids = {id(r) for r in sched._queue}
    for name, value in snap["counters"].items():
        setattr(sched, name, value)
    for rec, saved in snap["records"]:
        for k, v in saved.items():
            setattr(rec, k, v)
        sched._revive_finish(rec)


def _save_pod(pod: "PodState") -> dict:
    """Full copy-on-write snapshot of one pod: partitioner state (grid,
    allocation table — object identities preserved so live tenants keep
    their ``SliceAllocation``), simulator job set, and record membership
    dicts."""
    part = pod.partitioner
    return {
        "grid": part._grid.copy(),
        "next_id": part._next_id,
        "allocs": {sid: (a, a.profile, a.origin, a.devices)
                   for sid, a in part.allocations.items()},
        "sim_now": pod.sim.now,
        "sim_jobs": {k: replace(j) for k, j in pod.sim.jobs.items()},
        "jobs": dict(pod.jobs),
        "slice_jobs": dict(pod.slice_jobs),
        "mode": pod.mode,
        "profiles": part.profiles,
    }


def _restore_pod(pod: "PodState", ps: dict) -> None:
    part = pod.partitioner
    pod.gen += 1   # rollback rewrites pod state wholesale: new generation
    pod.mode = ps["mode"]
    if part.profiles != ps["profiles"]:
        part.set_profiles(ps["profiles"])   # re-derives the ladder + dirties
    part._grid = ps["grid"].copy()
    part.mark_dirty()
    part._next_id = ps["next_id"]
    allocs = {}
    for sid, (obj, profile, origin, devices) in ps["allocs"].items():
        obj.profile, obj.origin, obj.devices = profile, origin, devices
        allocs[sid] = obj
    part.allocations = allocs
    pod.sim.now = ps["sim_now"]
    pod.sim.jobs = {k: replace(j) for k, j in ps["sim_jobs"].items()}
    pod.sim.invalidate()
    pod.jobs = dict(ps["jobs"])
    pod.slice_jobs = dict(ps["slice_jobs"])


_REC_FIELDS: Optional[Tuple[str, ...]] = None


def _rec_fields() -> Tuple[str, ...]:
    global _REC_FIELDS
    if _REC_FIELDS is None:
        from repro.cluster.scheduler import JobRecord
        _REC_FIELDS = tuple(f.name for f in dc_fields(JobRecord)
                            if f.name != "version")
    return _REC_FIELDS


class Transaction:
    """Copy-on-write undo log: the default rollback mechanism.

    Instead of snapshotting the whole cluster up front (``capture``), a
    transaction saves state lazily at first touch while the recorded span
    runs: the first mutation of a pod saves that pod in full (plus every
    record currently resident on it — a resync may move any of their
    finish projections), the first mutation of an off-pod record saves its
    fields, queue membership changes are journaled as ops and replayed in
    reverse, and the (tiny) counter tuple is saved eagerly at begin. Cost
    is O(pods and records actually touched), not O(cluster) — the win
    that lets look-ahead trials run on 100k-job traces.

    Invariants mirrored from ``capture``/``restore``:

    * Record ``version`` is never saved: versions only advance, so ghost
      finish events pushed during the rolled-back span stay stale forever.
      ``rollback`` re-bumps (and re-issues finish events for) *touched*
      records only — untouched records keep their original live events.
    * Transactions nest LIFO on ``sched._txns``; mutations always journal
      into the innermost open transaction (``txn_touch``). A nested
      transaction that *commits* (keeps its mutations — a failed
      ``Repack.find`` keeping its tidy compaction, a look-ahead chain
      landing) is absorbed into its parent so an outer rollback still
      sees pre-span state: first-touch entries the parent lacks moved up
      unchanged (nothing mutated them between the two begins, or the
      parent would already hold an entry), queue ops appended in order.
    """

    def __init__(self, sched: "ClusterScheduler"):
        self.sched = sched
        self.counters = {n: getattr(sched, n) for n in _COUNTERS}
        self.pods: Dict[int, tuple] = {}      # id(pod) -> (pod, saved)
        self.records: Dict[int, tuple] = {}   # id(rec) -> (rec, fields)
        self.queue_ops: List[tuple] = []      # ("add"|"del", rec, pos)

    def touch_pod(self, pod: "PodState") -> None:
        if id(pod) in self.pods:
            return
        self.pods[id(pod)] = (pod, _save_pod(pod))
        for rec in pod.jobs.values():
            self.touch_record(rec)

    def touch_record(self, rec: Optional["JobRecord"]) -> None:
        if rec is None or id(rec) in self.records:
            return
        self.records[id(rec)] = (
            rec, {k: getattr(rec, k) for k in _rec_fields()})

    def note_queue(self, op: str, rec: "JobRecord",
                   pos: Optional[int] = None) -> None:
        self.queue_ops.append((op, rec, pos))

    def absorb(self, child: "Transaction") -> None:
        """Fold a committed nested transaction's journal into this one."""
        for key, entry in child.pods.items():
            self.pods.setdefault(key, entry)
        for key, entry in child.records.items():
            self.records.setdefault(key, entry)
        self.queue_ops.extend(child.queue_ops)
        # counters: this transaction's eager save predates the child's

    def rollback(self) -> None:
        sched = self.sched
        for pod, ps in self.pods.values():
            _restore_pod(pod, ps)
        queue = sched._queue
        for op, rec, pos in reversed(self.queue_ops):
            if op == "add":       # invert an append: drop the last match
                for i in range(len(queue) - 1, -1, -1):
                    if queue[i] is rec:
                        del queue[i]
                        break
                sched._queued_ids.discard(id(rec))
            else:                 # invert a removal: reinsert in place
                queue.insert(pos, rec)
                sched._queued_ids.add(id(rec))
        for name, value in self.counters.items():
            setattr(sched, name, value)
        for rec, saved in self.records.values():
            for k, v in saved.items():
                setattr(rec, k, v)
            sched._revive_finish(rec)


def begin_txn(sched: "ClusterScheduler", *extra: Optional["JobRecord"]):
    """Open a recorded span: an undo-log ``Transaction`` pushed onto
    ``sched._txns`` (default), or a legacy full ``capture`` snapshot when
    the scheduler was built with ``snapshot_rollback=True`` (kept for the
    equivalence property test). ``extra`` pre-touches records not yet
    reachable from a pod or the queue — the beneficiary an action is
    about to place."""
    if sched.snapshot_rollback:
        return capture(sched, tuple(r for r in extra if r is not None))
    txn = Transaction(sched)
    for rec in extra:
        txn.touch_record(rec)
    sched._txns.append(txn)
    return txn


def rollback_txn(sched: "ClusterScheduler", txn) -> None:
    """Undo everything since the matching ``begin_txn``. Undo-log spans
    must close innermost-first (LIFO)."""
    if sched.snapshot_rollback:
        restore(sched, txn)
        return
    assert sched._txns and sched._txns[-1] is txn, \
        "transactions must roll back innermost-first"
    sched._txns.pop()
    txn.rollback()


def commit_txn(sched: "ClusterScheduler", txn) -> None:
    """Close a recorded span *keeping* its mutations. A nested span's
    journal is absorbed by the parent so an outer rollback still restores
    pre-span state. Snapshot mode just drops the capture."""
    if sched.snapshot_rollback:
        return
    assert sched._txns and sched._txns[-1] is txn, \
        "transactions must commit innermost-first"
    sched._txns.pop()
    if sched._txns:
        sched._txns[-1].absorb(txn)


def txn_touch(sched: "ClusterScheduler", pod: Optional["PodState"] = None,
              *recs: Optional["JobRecord"]) -> None:
    """Journal ``pod`` (and any extra records) into the innermost open
    undo transaction before mutating them. No-op when nothing is
    recording (the scheduler's hot path) and in snapshot mode (where
    ``capture`` saved everything up front, so ``sched._txns`` stays
    empty)."""
    txns = sched._txns
    if not txns:
        return
    txn = txns[-1]
    if pod is not None:
        txn.touch_pod(pod)
    for rec in recs:
        txn.touch_record(rec)


# ---------------------------------------------------------------------------
# helpers shared by the rescue actions
# ---------------------------------------------------------------------------
def slo_profiles(sched, rec: "JobRecord", t: float) -> Iterator[PerfScore]:
    """PerfScores (smallest profile first) whose unthrottled modeled
    duration still meets ``rec``'s deadline when started at ``t`` — the
    only placements a rescue action is allowed to buy. Each probe must
    still re-check with its own start delay (``meets_after``).

    Rescue probes iterate this for the same record at many candidate
    times, so the (score, duration) rows come from the PerfModel's
    ``slo_table`` LRU — the filter here is one add + compare per row."""
    if rec.deadline_s is None:
        return
    for sc, dur in sched.perf.slo_table(rec.job):
        if t + dur <= rec.deadline_s:
            yield sc


def meets_after(rec: "JobRecord", t: float, sc: PerfScore,
                delay_s: float) -> bool:
    """Does ``rec`` still meet its deadline when its start is pushed back
    ``delay_s`` seconds by the rescue's own migration/checkpoint traffic?
    Without this, a rescue could disturb a victim and *still* deliver an
    SLO miss."""
    return t + delay_s + modeled_duration(rec.job, sc) <= rec.deadline_s


def shrink_victims(pod: "PodState", rec: "JobRecord") -> List["JobRecord"]:
    """Running non-executed batch jobs, cheapest first: least resident
    state (the migration cost proxy), then job id for determinism."""
    return sorted((r for r in pod.jobs.values()
                   if r.job.kind == BATCH and not r.executed
                   and not r.finished),
                  key=lambda r: (r.resident_bytes, r.job.job_id))


def preempt_victims(pod: "PodState", rec: "JobRecord") -> List["JobRecord"]:
    """Evictable jobs: running non-executed *batch* jobs of strictly lower
    priority. Scanned lowest priority class first, then least resident
    state (the checkpoint-volume cost), then job id — so the first
    feasible victim is also the cheapest eligible one."""
    return sorted((r for r in pod.jobs.values()
                   if r.job.kind == BATCH and not r.executed
                   and not r.finished
                   and r.job.priority < rec.job.priority),
                  key=lambda r: (r.job.priority, r.resident_bytes,
                                 r.job.job_id))


def migrate_victims(pod: "PodState", rec: "JobRecord") -> List["JobRecord"]:
    """Relocatable jobs: running non-executed jobs of strictly lower
    priority, *any* kind — migration never suspends the victim (it keeps
    running on the destination pod after the priced save/restore), so
    training reservations are eligible where eviction would be unsafe.
    Cheapest first: priority class, then resident state (the DCN volume),
    then job id."""
    return sorted((r for r in pod.jobs.values()
                   if not r.executed and not r.finished
                   and r.job.priority < rec.job.priority),
                  key=lambda r: (r.job.priority, r.resident_bytes,
                                 r.job.job_id))


def _realloc_victim(sched: "ClusterScheduler", pod: "PodState",
                    victim: "JobRecord", profile) -> bool:
    """Transactionally swap the victim's rectangle for ``profile`` at its
    current origin (power-of-two profile sides make the origin aligned for
    every smaller profile). On failure the allocation recorded in
    ``victim.profile_name`` — which stays at the committed profile until
    the shrink commits — is restored, so this one helper serves both the
    shrink trial and its rollback. Even self-restoring trials advance
    slice ids and ``_next_id`` — journaled when a transaction is open, so
    an enclosing rollback restores allocation-table order exactly."""
    txn_touch(sched, pod)
    part = pod.partitioner
    part.release(victim.slice_id)
    try:
        alloc = part.allocate(profile, tag=victim.job.tag,
                              origin=victim.origin)
        ok = True
    except RuntimeError:
        alloc = part.allocate(get_profile(victim.profile_name),
                              tag=victim.job.tag, origin=victim.origin)
        ok = False
    pod.slice_jobs.pop(victim.slice_id)
    victim.slice_id = alloc.slice_id
    pod.slice_jobs[alloc.slice_id] = victim
    return ok


# ---------------------------------------------------------------------------
# the probe cache
# ---------------------------------------------------------------------------
class ProbeCache:
    """Memo table for the *structural cores* of rescue probes.

    A rescue probe splits into a time-dependent SLO check (one add and
    compare — always recomputed) and a structural core: grid trials,
    ``origins_for`` queries and the power-gate throttle solve, the parts
    that dominate probe cost. The core reads only pod state — the free
    mask, the resident records' load parameters and the power mix — never
    ``t``, so its outcome is a pure function of the key:

        (kind, pod index, ``PodState.generation`` (a composite of the
         pod-level counter, the partitioner's grid generation and the
         simulator's mix generation), victim job id, the profile names
         involved, the beneficiary job's pricing signature, and
         ``PerfModel.profile_key``)

    Invalidation is structural, not explicit: any ``apply()`` moves the
    touched pod's partitioner/simulator generations, and an undo-log
    ``rollback()`` bumps ``PodState.gen`` (plus ``mark_dirty`` /
    ``invalidate``) — so entries for touched pods silently stop matching
    while untouched pods' entries keep hitting across events and trial-
    tree branches. Self-restoring probe trials re-stamp the partitioner
    generation (``restore_generation``), so sibling probes during one
    rescue scan share a generation and a later identical scan hits.

    Bounded: at ``max_entries`` the table is cleared wholesale (same
    policy as the PerfModel memos) — correctness never depends on
    retention, only speed."""

    __slots__ = ("max_entries", "_table")

    def __init__(self, max_entries: int = 1 << 16):
        self.max_entries = max_entries
        self._table: Dict[tuple, tuple] = {}

    def __len__(self) -> int:
        return len(self._table)

    def get(self, key: tuple) -> Optional[tuple]:
        return self._table.get(key)

    def put(self, key: tuple, value: tuple) -> None:
        if len(self._table) >= self.max_entries:
            self._table.clear()
        self._table[key] = value

    def clear(self) -> None:
        self._table.clear()


def _job_sig(rec: "JobRecord") -> tuple:
    """The beneficiary fields a structural core can read: (arch, shape,
    pinned utilization). Together with the candidate profile name these
    determine the PerfScore terms the power gate prices — job ids are
    deliberately absent so distinct queued jobs with equal pricing share
    cache entries."""
    j = rec.job
    return (j.arch, j.shape, j.u_compute)


def _cached_core(sched: "ClusterScheduler", key: Optional[tuple],
                 core) -> tuple:
    """Evaluate a probe's structural core through the scheduler's
    ``ProbeCache``. Every consultation is counted: a fresh evaluation
    increments ``_probes_priced`` (the work actually done), a hit
    increments ``_probe_hits`` (the work avoided) — the metrics columns
    the ≥3x probe-drop gate reads. With the cache disabled (or no key)
    the core always runs."""
    cache = sched.probe_cache
    if cache is None or key is None:
        sched._probes_priced += 1
        return core(sched)
    val = cache.get(key)
    if val is not None:
        sched._probe_hits += 1
        return val
    sched._probes_priced += 1
    val = core(sched)
    cache.put(key, val)
    return val


def _churn_victim(sched: "ClusterScheduler", pod: "PodState",
                  victim: "JobRecord") -> None:
    """Replay the allocation-table side effect of a skipped probe trial.

    A fresh trial releases and re-allocates the victim's rectangle, which
    moves its entry to the end of the allocation table and advances its
    slice id. ``repack()`` iterates that table (stable sort on profile
    size), so the *order* perturbation is decision-relevant — a cache hit
    that skipped it would drift the pinned timelines. This replays just
    the cheap release/allocate-at-origin pair (no ``origins_for`` query,
    no power solve) and re-stamps the grid generation, leaving the table
    exactly as a fresh probe would."""
    txn_touch(sched, pod)
    part = pod.partitioner
    g = part.generation
    part.release(victim.slice_id)
    alloc = part.allocate(get_profile(victim.profile_name),
                          tag=victim.job.tag, origin=victim.origin)
    pod.slice_jobs.pop(victim.slice_id)
    victim.slice_id = alloc.slice_id
    pod.slice_jobs[alloc.slice_id] = victim
    part.restore_generation(g)


# ---------------------------------------------------------------------------
# the Action base
# ---------------------------------------------------------------------------
class Action:
    """One priced, transactional mutation of cluster state.

    Subclasses bind their parameters (beneficiary record, victim, pod,
    profile score) at construction — usually via the class's ``find``
    scanner — and implement ``probe``/``apply``. ``apply`` records a
    transaction by default; ``rollback`` restores the captured state
    exactly. Commit-only call sites (the scheduler's event loop, a
    policy committing its final choice) pass ``record=False`` to skip
    the snapshot — capturing on every admission costs ~25% of a heavy
    trace's wall time and only look-ahead trials ever roll back.
    ``extra_delay`` threads a chained predecessor's drain time (seconds)
    into both the SLO check and the committed start delay, which is how
    ``LookAheadPolicy`` composes actions."""
    kind = "action"

    def __init__(self, rec: Optional["JobRecord"]):
        self.rec = rec
        self.outcome: Optional[ActionOutcome] = None
        self._txn: Optional[dict] = None

    @property
    def rank(self) -> int:
        return _DISRUPTION_RANK.get(self.kind, 99)

    @property
    def victim_id(self) -> int:
        return -1

    def probe(self, sched: "ClusterScheduler", t: float,
              extra_delay: float = 0.0) -> ActionOutcome:
        raise NotImplementedError

    def apply(self, sched: "ClusterScheduler", t: float,
              extra_delay: float = 0.0, record: bool = True) -> None:
        raise NotImplementedError

    def rollback(self, sched: "ClusterScheduler") -> None:
        assert self._txn is not None, "rollback without a recorded apply"
        rollback_txn(sched, self._txn)
        self._txn = None

    def commit(self, sched: "ClusterScheduler") -> None:
        """Keep the applied mutations but close the recorded span — its
        undo journal is absorbed by the enclosing transaction, if any
        (a look-ahead chain that landed must still be undoable by an
        outer trial)."""
        if self._txn is not None:
            commit_txn(sched, self._txn)
            self._txn = None

    def _begin(self, sched: "ClusterScheduler", record: bool) -> None:
        if record:
            self._txn = begin_txn(sched, self.rec)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        who = self.rec.job.job_id if self.rec is not None else None
        return (f"<{type(self).__name__} rec={who} victim={self.victim_id} "
                f"outcome={self.outcome}>")


class Place(Action):
    """Admit ``rec`` on a scored placement ``Candidate`` (power-gated)."""
    kind = "place"

    def __init__(self, rec: "JobRecord", cand: Candidate):
        super().__init__(rec)
        self.cand = cand

    def probe(self, sched, t, extra_delay=0.0) -> ActionOutcome:
        if not sched._power_ok(self.cand, self.rec):
            self.outcome = ActionOutcome(
                False, reason="power gate: predicted throttle below "
                              f"min_throttle={sched.min_throttle}")
            return self.outcome
        finish = t + extra_delay + self.cand.duration_s
        meets = (None if self.rec.deadline_s is None
                 else finish <= self.rec.deadline_s)
        self.outcome = ActionOutcome(True, cost_s=0.0,
                                     start_delay_s=extra_delay,
                                     projected_finish_s=finish,
                                     meets_slo=meets)
        return self.outcome

    def apply(self, sched, t, extra_delay=0.0, record=True) -> None:
        self._begin(sched, record)
        sched._place(self.rec, self.cand, t, start_delay=extra_delay)


class Repack(Action):
    """In-pod defragmentation: transactional ``repack()`` plus placement
    of the stranded beneficiary, priced as the moved slices' resident
    bytes over the pod's host links (arXiv 2512.16099 stranding fix).

    ``find`` mirrors the legacy scan exactly, including its documented
    quirk: a compaction that fails to mint the needed origin is *kept*
    (the grid stays valid and tidier) and charged nothing. The action's
    transaction therefore spans ``find``+``apply`` — ``rollback`` returns
    to the state before the scan began."""
    kind = "repack"

    def __init__(self, rec: "JobRecord"):
        super().__init__(rec)
        self.pod: Optional["PodState"] = None
        self.moved: Dict[int, tuple] = {}
        self.cand: Optional[Candidate] = None

    @classmethod
    def find(cls, sched: "ClusterScheduler", rec: "JobRecord", t: float,
             record: bool = True) -> Optional["Repack"]:
        act = cls(rec)
        act._txn = begin_txn(sched, rec) if record else None
        for sc in sched.perf.options(rec.job):
            for pod in sched.pods:
                part = pod.partitioner
                if (part.free_chips() < sc.profile.n_chips
                        or part.origins_for(sc.profile)):
                    continue  # either truly full, or no stranding to fix
                # power gate BEFORE paying for migration: a repack whose
                # beneficiary then fails admission would stretch the moved
                # jobs for nothing
                if not sched._power_ok_profile(pod, rec, sc.profile,
                                               sc.terms):
                    continue
                txn_touch(sched, pod)   # repack rewrites the whole grid
                try:
                    moved = part.repack()
                except RuntimeError:
                    sched._repack_failures += 1
                    continue
                for sid, origin in moved.items():
                    # keep records truthful: a later shrink/preempt
                    # re-allocates at the record's origin, so a stale one
                    # would rebuild the victim on the wrong rectangle
                    if sid in pod.slice_jobs:
                        pod.slice_jobs[sid].origin = origin
                cand = candidate_on(pod, rec.job, sc, t, rec.deadline_s)
                if cand is None:
                    # compaction could not mint an aligned origin after
                    # all; the grid stays valid (and tidier) — charge
                    # nothing, keep looking
                    continue
                moved_bytes = sum(pod.slice_jobs[sid].resident_bytes
                                  for sid in moved if sid in pod.slice_jobs)
                t_mig = moved_bytes / sched._pod_host_bw
                act.pod, act.moved, act.cand = pod, moved, cand
                finish = t + t_mig + cand.duration_s
                act.outcome = ActionOutcome(
                    True, cost_s=t_mig, start_delay_s=t_mig,
                    projected_finish_s=finish,
                    meets_slo=(None if rec.deadline_s is None
                               else finish <= rec.deadline_s))
                return act
        if act._txn is not None:   # failed scans keep their tidy
            commit_txn(sched, act._txn)   # compactions — journal upward
            act._txn = None
        return None

    def probe(self, sched, t, extra_delay=0.0) -> ActionOutcome:
        txn = begin_txn(sched)
        found = Repack.find(sched, self.rec, t, record=False)
        rollback_txn(sched, txn)
        if found is None:
            self.outcome = ActionOutcome(False,
                                         reason="no repack mints an origin")
        else:
            self.outcome = found.outcome
        return self.outcome

    def apply(self, sched, t, extra_delay=0.0, record=True) -> None:
        assert self.cand is not None, "apply() requires a successful find()"
        # the transaction spans find()+apply(): find(record=True) already
        # captured (before the compaction) — apply must not re-capture,
        # and a find(record=False) binding cannot become rollbackable here
        assert not record or self._txn is not None, \
            "Repack transactions open in find(); bind with find(record=True)"
        sched._repacks += 1
        t_mig = sched._migration_cost(self.pod, self.moved, t)
        sched._place(self.rec, self.cand, t,
                     start_delay=t_mig + extra_delay)


class Shrink(Action):
    """Resize a running batch victim to a smaller profile so the blocked
    deadline job ``rec`` places now — MISO-style online re-selection,
    priced as the victim's post-shrink resident bytes over the pod's host
    links. A shrink can help two ways: mint an aligned origin on a full
    pod, or (when the power gate blocked admission) drop the victim's
    dynamic draw below the shared cap."""
    kind = "shrink"

    def __init__(self, rec: "JobRecord", pod: "PodState",
                 victim: "JobRecord", small: PerfScore, sc: PerfScore):
        super().__init__(rec)
        self.pod = pod
        self.victim = victim
        self.small = small
        self.sc = sc

    @property
    def victim_id(self) -> int:
        return self.victim.job.job_id

    @classmethod
    def find(cls, sched: "ClusterScheduler", rec: "JobRecord", t: float,
             extra_delay: float = 0.0) -> Optional["Shrink"]:
        """First feasible shrink, scanned victims-cheapest-first within
        each (SLO profile, pod) — the legacy probe order."""
        for sc in slo_profiles(sched, rec, t):
            for pod in sched.pods:
                for victim in shrink_victims(pod, rec):
                    for small in sched.perf.options(victim.job,
                                                    ignore_pin=True):
                        if small.profile.n_chips >= victim.n_chips:
                            continue
                        act = cls(rec, pod, victim, small, sc)
                        if act.probe(sched, t, extra_delay).feasible:
                            return act
        return None

    def probe(self, sched, t, extra_delay=0.0) -> ActionOutcome:
        """Trial-only: would shrinking ``victim`` to ``small`` free an
        origin for ``sc.profile`` under the power gate, with the migration
        delay still inside ``rec``'s deadline? The grid is restored before
        returning, found or not. The structural core (the two realloc
        trials, the origin query and the power solve) is memoized per pod
        generation in the scheduler's ``ProbeCache``; the SLO arithmetic
        is recomputed fresh every call."""
        pod, victim, small, sc = self.pod, self.victim, self.small, self.sc
        mig_s = int(small.plan.resident_bytes) / sched._pod_host_bw
        if not meets_after(self.rec, t, sc, mig_s + extra_delay):
            self.outcome = ActionOutcome(
                False, reason="the shrink migration would blow the SLO")
            return self.outcome
        key = None
        if sched.probe_cache is not None:
            # rung (not profile.name): a twin and a plain score share the
            # rectangle but not the power/step outcome — they must not
            # collide in the cache
            key = ("shrink", pod.idx, pod.generation, victim.job.job_id,
                   small.rung, sc.rung, _job_sig(self.rec),
                   sched.perf.profile_key)
            if sched.probe_cache.get(key) is not None:
                _churn_victim(sched, pod, victim)
        ok, reason = _cached_core(sched, key, self._core)
        if not ok:
            self.outcome = ActionOutcome(False, reason=reason)
            return self.outcome
        finish = t + mig_s + extra_delay + modeled_duration(self.rec.job, sc)
        self.outcome = ActionOutcome(
            True, cost_s=mig_s, start_delay_s=mig_s + extra_delay,
            projected_finish_s=finish,
            meets_slo=finish <= self.rec.deadline_s)
        return self.outcome

    def _core(self, sched) -> tuple:
        """Structural core: does ``small`` fit at the victim's origin, and
        does the shrunk grid mint an aligned origin for ``sc`` under the
        power gate? Pure pod-state function (no ``t``). The two realloc
        trials cancel on the free mask, so the starting grid generation is
        re-stamped — sibling probes in the same rescue scan share it."""
        pod, victim, small, sc = self.pod, self.victim, self.small, self.sc
        part = pod.partitioner
        g = part.generation
        if not _realloc_victim(sched, pod, victim, small.profile):
            part.restore_generation(g)
            return (False, "smaller profile does not fit at the "
                           "victim's origin")
        ok = (bool(part.origins_for(sc.profile))
              and self._power_ok(sched))
        restored = _realloc_victim(sched, pod, victim,
                                   get_profile(victim.profile_name))
        assert restored, "shrink rollback must always fit"
        part.restore_generation(g)
        if not ok:
            return (False, "shrink mints no origin / fails power gate")
        return (True, None)

    def _power_ok(self, sched) -> bool:
        loads = []
        for r in self.pod.jobs.values():
            if r is self.victim:
                loads.append(InstanceLoad(
                    self.small.profile.n_chips,
                    sched._u_for(self.victim, self.small.terms),
                    self.small.step_time, 1))
            else:
                loads.append(r.load())
        loads.append(InstanceLoad(self.sc.profile.n_chips,
                                  sched._u_for(self.rec, self.sc.terms),
                                  self.sc.step_time, 1))
        return sched.perf.throttle(loads, sched.pod_spec) \
            >= sched.min_throttle

    def apply(self, sched, t, extra_delay=0.0, record=True) -> None:
        self._begin(sched, record)
        pod, victim, small, sc = self.pod, self.victim, self.small, self.sc
        applied = _realloc_victim(sched, pod, victim, small.profile)
        assert applied, "probed shrink must re-apply"
        sched._shrinks += 1
        moved_bytes = int(small.plan.resident_bytes)
        victim.profile_name = small.profile.name
        victim.rung = small.rung
        victim.u_compute = sched._u_for(victim, small.terms)
        victim.step_time_s = small.step_time
        victim.resident_bytes = moved_bytes
        victim.shrunk = True
        pod.sim.resize(victim.job.job_id, small.profile.n_chips,
                       victim.u_compute, small.step_time)
        t_mig = sched._charge_migration(pod, moved_bytes, [victim], t)
        sched._reissue_after_resize(pod, victim, t)
        cand = candidate_on(pod, self.rec.job, sc, t, self.rec.deadline_s)
        assert cand is not None, "origins_for was just checked"
        sched._place(self.rec, cand, t, start_delay=t_mig + extra_delay)


class Preempt(Action):
    """Checkpoint-evict a strictly lower-priority running batch job and
    (when a beneficiary is bound) place ``rec`` in its rectangle.

    Priced via ``PerfModel.checkpoint_cost``: the save volume (the
    victim's resident bytes — what ``train/checkpoint.py`` host-gathers)
    crosses the pod's host links before the rectangle is usable, so the
    beneficiary starts after ``save_s``; the victim's progress survives in
    a ``SuspendSnapshot`` and the job re-queues for a later resume, paying
    ``restore_s`` then. With ``rec=None`` the action is a pure *enabler*
    (look-ahead chaining): the eviction happens, nobody is placed, and the
    save drain is handed to the chained action as its ``extra_delay``."""
    kind = "preempt"

    def __init__(self, rec: Optional["JobRecord"], pod: "PodState",
                 victim: "JobRecord", sc: Optional[PerfScore]):
        super().__init__(rec)
        self.pod = pod
        self.victim = victim
        self.sc = sc

    @property
    def victim_id(self) -> int:
        return self.victim.job.job_id

    @classmethod
    def find(cls, sched: "ClusterScheduler", rec: "JobRecord", t: float,
             extra_delay: float = 0.0) -> Optional["Preempt"]:
        """First feasible checkpoint-eviction with a bound beneficiary,
        victims scanned cheapest-first (priority class, resident bytes) —
        the legacy probe order."""
        for sc in slo_profiles(sched, rec, t):
            for pod in sched.pods:
                for victim in preempt_victims(pod, rec):
                    act = cls(rec, pod, victim, sc)
                    if act.probe(sched, t, extra_delay).feasible:
                        return act
        return None

    @classmethod
    def enablers(cls, sched: "ClusterScheduler", rec: "JobRecord", t: float
                 ) -> Iterator["Preempt"]:
        """Beneficiary-less evictions the look-ahead may trial-apply,
        cheapest victims first per pod."""
        for pod in sched.pods:
            for victim in preempt_victims(pod, rec):
                yield cls(None, pod, victim, None)

    def _cost(self, sched):
        return sched.perf.checkpoint_cost(self.victim.resident_bytes,
                                          sched._pod_host_bw)

    def probe(self, sched, t, extra_delay=0.0) -> ActionOutcome:
        """Trial-only: the victim's rectangle is released and re-allocated
        in place — grid state is unchanged on return (only its internal
        slice id advances). The structural core (release/origin/power
        trial) is memoized per pod generation; the SLO arithmetic and the
        checkpoint price are recomputed fresh every call."""
        pod, victim, sc = self.pod, self.victim, self.sc
        cost = self._cost(sched)
        if self.rec is None:   # pure enabler: eligibility is feasibility
            self.outcome = ActionOutcome(True, cost_s=cost.total_s,
                                         start_delay_s=cost.save_s)
            return self.outcome
        if not meets_after(self.rec, t, sc, cost.save_s + extra_delay):
            self.outcome = ActionOutcome(
                False, reason="the checkpoint save drain would blow the SLO")
            return self.outcome
        key = None
        if sched.probe_cache is not None:
            key = ("preempt", pod.idx, pod.generation, victim.job.job_id,
                   sc.rung, _job_sig(self.rec),
                   sched.perf.profile_key)
            if sched.probe_cache.get(key) is not None:
                _churn_victim(sched, pod, victim)
        ok, reason = _cached_core(sched, key, self._core)
        if not ok:
            self.outcome = ActionOutcome(False, reason=reason)
            return self.outcome
        finish = (t + cost.save_s + extra_delay
                  + modeled_duration(self.rec.job, sc))
        self.outcome = ActionOutcome(
            True, cost_s=cost.total_s,
            start_delay_s=cost.save_s + extra_delay,
            projected_finish_s=finish,
            meets_slo=finish <= self.rec.deadline_s)
        return self.outcome

    def _core(self, sched) -> tuple:
        """Structural core: with the victim's rectangle released, does the
        pod mint an aligned origin for ``sc`` and pass the power gate?
        Pure pod-state function (no ``t``); the release/re-allocate pair
        cancels on the free mask, so the grid generation is re-stamped."""
        pod, victim, sc = self.pod, self.victim, self.sc
        txn_touch(sched, pod)
        part = pod.partitioner
        g = part.generation
        profile = get_profile(victim.profile_name)
        origin = victim.origin
        part.release(victim.slice_id)
        ok = (bool(part.origins_for(sc.profile))
              and self._power_ok(sched))
        alloc = part.allocate(profile, tag=victim.job.tag, origin=origin)
        pod.slice_jobs.pop(victim.slice_id)
        victim.slice_id = alloc.slice_id
        pod.slice_jobs[alloc.slice_id] = victim
        part.restore_generation(g)
        if not ok:
            return (False, "eviction mints no origin / fails power gate")
        return (True, None)

    def _power_ok(self, sched) -> bool:
        loads = [r.load() for r in self.pod.jobs.values()
                 if r is not self.victim]
        loads.append(InstanceLoad(self.sc.profile.n_chips,
                                  sched._u_for(self.rec, self.sc.terms),
                                  self.sc.step_time, 1))
        return sched.perf.throttle(loads, sched.pod_spec) \
            >= sched.min_throttle

    def apply(self, sched, t, extra_delay=0.0, record=True) -> None:
        self._begin(sched, record)
        self._evict(sched, t)
        if self.rec is not None:
            cand = candidate_on(self.pod, self.rec.job, self.sc, t,
                                self.rec.deadline_s)
            assert cand is not None, "eviction was probed to mint an origin"
            sched._place(self.rec, cand, t,
                         start_delay=self._cost(sched).save_s + extra_delay)

    def _evict(self, sched, t: float) -> None:
        from repro.cluster.scheduler import SuspendSnapshot
        pod, victim = self.pod, self.victim
        txn_touch(sched, pod)
        sched._preemptions += 1
        cost = self._cost(sched)
        sched._wasted_checkpoint_chip_s += victim.n_chips * cost.save_s
        sim = pod.sim.remove(victim.job.job_id)
        victim.suspended = SuspendSnapshot(
            work_done=sim.work_done, work_total=sim.work_total,
            fixed_remaining=sim.fixed_s, pinned=sim.pinned,
            step_time=sim.step_time, bytes=cost.bytes,
            delay_remaining=sim.delay_s)
        victim.preemptions += 1
        victim.suspend_s = t
        victim.checkpoint_bytes += cost.bytes
        victim.checkpoint_delay_s += cost.save_s
        pod.jobs.pop(victim.job.job_id)
        pod.slice_jobs.pop(victim.slice_id)
        pod.partitioner.release(victim.slice_id)
        victim.pod_idx = None
        victim.slice_id = None
        victim.finish_s = None
        victim.version += 1   # orphan the victim's pending finish event
        sched._enqueue(victim)


class MigrateAcrossPods(Action):
    """Relocate a running lower-priority victim to *another pod* so the
    blocked deadline job ``rec`` takes its rectangle — the cross-pod
    balancing move (ROADMAP item one) in-pod rescues cannot express.

    The move is the same save/restore pair as a checkpoint preemption
    (``PerfModel.checkpoint_cost``), priced over the pod's **DCN**
    bandwidth (``PodSpec.dcn_bw``, bytes/s — the per-host 100 GbE-class
    NICs, the bottleneck of a pod-to-pod transfer) instead of the host
    links. Unlike a preemption the victim never suspends: it is re-admitted
    on the destination pod in the same event, its progress intact, delayed
    by ``save_s + restore_s`` (plus any unburned migration debt). The
    beneficiary's rectangle is usable after ``save_s`` (the state must
    drain off the source slice first). Any job kind of strictly lower
    priority is eligible — relocation preserves the victim's reservation,
    so training holders may move where eviction would be unsafe."""
    kind = "migrate"

    def __init__(self, rec: "JobRecord", src: "PodState",
                 victim: "JobRecord", dest: "PodState", sc: PerfScore):
        super().__init__(rec)
        self.src = src
        self.victim = victim
        self.dest = dest
        self.sc = sc
        self.dest_origin: Optional[Tuple[int, int]] = None

    @property
    def victim_id(self) -> int:
        return self.victim.job.job_id

    @classmethod
    def find(cls, sched: "ClusterScheduler", rec: "JobRecord", t: float,
             extra_delay: float = 0.0) -> Optional["MigrateAcrossPods"]:
        """First feasible cross-pod relocation: source pods in index
        order, victims cheapest-first, destinations in index order."""
        if len(sched.pods) < 2:
            return None
        for sc in slo_profiles(sched, rec, t):
            for src in sched.pods:
                for victim in migrate_victims(src, rec):
                    for dest in sched.pods:
                        if dest is src:
                            continue
                        act = cls(rec, src, victim, dest, sc)
                        if act.probe(sched, t, extra_delay).feasible:
                            return act
        return None

    def _cost(self, sched):
        return sched.perf.checkpoint_cost(self.victim.resident_bytes,
                                          sched._dcn_bw)

    def probe(self, sched, t, extra_delay=0.0) -> ActionOutcome:
        """Trial-only; grid state of both pods is unchanged on return.
        The destination check (origin + power gate, read-only) and the
        source trial (release/origin/power) are memoized as *separate*
        structural cores: the destination core is keyed on the victim's
        profile and load alone so it is shared across beneficiary
        profiles, and the source core is destination-independent so one
        victim probed against many destinations prices it once."""
        src, dest, victim, sc = self.src, self.dest, self.victim, self.sc
        cost = self._cost(sched)
        if not meets_after(self.rec, t, sc, cost.save_s + extra_delay):
            self.outcome = ActionOutcome(
                False, reason="the DCN save drain would blow the SLO")
            return self.outcome
        dkey = None
        if sched.probe_cache is not None:
            dkey = ("mig-dest", dest.idx, dest.generation,
                    victim.profile_name, victim.load(),
                    sched.perf.profile_key)
        dest_origin, reason = _cached_core(sched, dkey, self._dest_core)
        if dest_origin is None:
            self.outcome = ActionOutcome(False, reason=reason)
            return self.outcome
        skey = None
        if sched.probe_cache is not None:
            skey = ("mig-src", src.idx, src.generation, victim.job.job_id,
                    sc.rung, _job_sig(self.rec),
                    sched.perf.profile_key)
            if sched.probe_cache.get(skey) is not None:
                _churn_victim(sched, src, victim)
        ok, reason = _cached_core(sched, skey, self._src_core)
        if not ok:
            self.outcome = ActionOutcome(False, reason=reason)
            return self.outcome
        self.dest_origin = dest_origin
        finish = (t + cost.save_s + extra_delay
                  + modeled_duration(self.rec.job, sc))
        self.outcome = ActionOutcome(
            True, cost_s=cost.total_s,
            start_delay_s=cost.save_s + extra_delay,
            projected_finish_s=finish,
            meets_slo=finish <= self.rec.deadline_s)
        return self.outcome

    def _dest_core(self, sched) -> tuple:
        """Read-only destination check: an aligned origin for the victim's
        profile plus the destination power gate. Returns (origin, None) or
        (None, reason)."""
        dest, victim = self.dest, self.victim
        profile = get_profile(victim.profile_name)
        dest_origins = dest.partitioner.origins_for(profile)
        if not dest_origins:
            return (None, "destination pod has no aligned origin for "
                          "the victim's profile")
        if not self._dest_power_ok(sched):
            return (None, "victim fails the destination power gate")
        return (dest_origins[0], None)

    def _src_core(self, sched) -> tuple:
        """Source-side structural core: with the victim's rectangle
        released, does the source mint an origin for ``sc`` under the
        power gate? Same self-restoring release/re-allocate trial as the
        preemption core."""
        src, victim, sc = self.src, self.victim, self.sc
        txn_touch(sched, src)
        part = src.partitioner
        g = part.generation
        profile = get_profile(victim.profile_name)
        origin = victim.origin
        part.release(victim.slice_id)
        ok = (bool(part.origins_for(sc.profile))
              and self._src_power_ok(sched))
        alloc = part.allocate(profile, tag=victim.job.tag, origin=origin)
        src.slice_jobs.pop(victim.slice_id)
        victim.slice_id = alloc.slice_id
        src.slice_jobs[alloc.slice_id] = victim
        part.restore_generation(g)
        if not ok:
            return (False, "relocation mints no origin / fails the "
                           "source power gate")
        return (True, None)

    def _dest_power_ok(self, sched) -> bool:
        if not self.dest.jobs:
            return True
        return self.dest.sim.throttle(self.victim.load()) \
            >= sched.min_throttle

    def _src_power_ok(self, sched) -> bool:
        loads = [r.load() for r in self.src.jobs.values()
                 if r is not self.victim]
        loads.append(InstanceLoad(self.sc.profile.n_chips,
                                  sched._u_for(self.rec, self.sc.terms),
                                  self.sc.step_time, 1))
        return sched.perf.throttle(loads, sched.pod_spec) \
            >= sched.min_throttle

    def apply(self, sched, t, extra_delay=0.0, record=True) -> None:
        self._begin(sched, record)
        cost = self._relocate(sched, t)
        # the beneficiary takes the drained source rectangle
        cand = candidate_on(self.src, self.rec.job, self.sc, t,
                            self.rec.deadline_s)
        assert cand is not None, "relocation was probed to mint an origin"
        sched._place(self.rec, cand, t,
                     start_delay=cost.save_s + extra_delay)

    def _relocate(self, sched, t):
        """Move ``victim`` from ``src`` to ``dest`` (progress intact,
        DCN-priced) and return the checkpoint cost. Shared by the rescue
        ``apply`` above and the autoscaler's beneficiary-less
        ``MigrateTenant`` — the tenant moving *is* the point there."""
        src, dest, victim = self.src, self.dest, self.victim
        assert self.dest_origin is not None, \
            "apply() requires a successful probe()"
        txn_touch(sched, src)
        txn_touch(sched, dest)
        cost = self._cost(sched)
        sched._migrations += 1
        sched._dcn_migrated_bytes += cost.bytes
        sched._dcn_migration_s += cost.total_s
        # chips idle under checkpoint traffic on both ends of the move
        sched._wasted_checkpoint_chip_s += victim.n_chips * cost.total_s
        profile = get_profile(victim.profile_name)
        sim = src.sim.remove(victim.job.job_id)
        src.jobs.pop(victim.job.job_id)
        src.slice_jobs.pop(victim.slice_id)
        src.partitioner.release(victim.slice_id)
        # re-admit on the destination with progress intact; the relocation
        # pipeline (save + restore over the DCN) and any unburned earlier
        # migration debt delay its restart
        admit_kw = {}
        duration = None
        if sim.pinned:
            duration = sim.fixed_s          # wall-clock contract
        elif sim.fixed_s is not None:
            admit_kw["fixed_remaining"] = sim.fixed_s
        else:
            admit_kw["work_done"] = sim.work_done
        finish = dest.sim.admit(
            victim.job.job_id, sim.n_chips, sim.u_compute, sim.step_time,
            sim.steps, t, duration_s=duration,
            start_delay=cost.total_s + sim.delay_s, **admit_kw)
        alloc = dest.partitioner.allocate(profile, tag=victim.job.tag,
                                          origin=self.dest_origin)
        victim.pod_idx = dest.idx
        victim.slice_id = alloc.slice_id
        victim.origin = self.dest_origin
        victim.finish_s = finish
        victim.migrations += 1
        victim.migrate_s = t
        victim.dcn_bytes += cost.bytes
        victim.dcn_delay_s += cost.total_s
        dest.jobs[victim.job.job_id] = victim
        dest.slice_jobs[alloc.slice_id] = victim
        victim.version += 1
        sched._push(finish, "finish", (victim, victim.version))
        if not sched.frozen_durations:
            sched._resync(dest, t)   # the newcomer slows dest co-tenants
        return cost


class ReconfigurePartition(Action):
    """Switch a pod to another hardware partition mode so the blocked
    deadline job ``rec`` fits where no fixed-mode rescue can help — e.g.
    a bandwidth-starved job that misses its SLO under NPS1 but meets it
    under NPS4's interleaving uplift (``core.hw.PartitionMode``).

    Feasibility requires the pod *drainable*: every resident tenant must
    relocate to another pod (the beneficiary-less ``MigrateTenant`` move,
    DCN-priced), because a mode switch resets the pod's memory/compute
    partitioning. The priced cost is the tenants' drain traffic plus the
    mode's fixed ``switch_downtime_s``; the beneficiary re-admits on the
    reconfigured pod under the *target mode's* PerfModel
    (``sched.mode_model``), whose slice ladder may differ (CPX exposes
    per-XCD slices, SPX only whole-socket ones). ``probe`` trial-applies
    the whole drain inside a transaction and rolls it back bit-exactly;
    ``apply`` replays the recorded drain plan, flips ``pod.mode``,
    re-derives the partitioner's profile ladder, and places ``rec``.

    On a single-mode chip (v5e's ``fixed``) ``find`` has nothing to scan,
    so legacy configurations never change behaviour even when the kind is
    enabled."""
    kind = "reconfigure"

    def __init__(self, rec: Optional["JobRecord"], pod: "PodState",
                 mode_name: str):
        super().__init__(rec)
        self.pod = pod
        self.mode_name = mode_name
        self.sc: Optional[PerfScore] = None
        self.plan: List[Tuple[int, int]] = []   # (victim job id, dest idx)
        self.drain_save_s = 0.0
        self.drain_total_s = 0.0

    @classmethod
    def find(cls, sched: "ClusterScheduler", rec: "JobRecord", t: float,
             extra_delay: float = 0.0) -> Optional["ReconfigurePartition"]:
        """First feasible (pod, target mode) pair — pods in index order,
        modes in sorted-name order, the current mode skipped."""
        for pod in sched.pods:
            for name in sorted(sched._modes):
                if name == pod.mode:
                    continue
                act = cls(rec, pod, name)
                if act.probe(sched, t, extra_delay=extra_delay).feasible:
                    return act
        return None

    def probe(self, sched, t, extra_delay=0.0) -> ActionOutcome:
        from repro.cluster.autoscale import MigrateTenant
        rec, pod = self.rec, self.pod
        mode = sched._modes[self.mode_name]
        if rec is None or rec.deadline_s is None:
            self.outcome = ActionOutcome(
                False, reason="reconfigure only rescues deadline jobs")
            return self.outcome
        if any(r.executed or r.finished for r in pod.jobs.values()):
            self.outcome = ActionOutcome(
                False, reason="pod tenants include a non-relocatable job")
            return self.outcome
        # trial-drain every tenant inside a recorded span, priced as the
        # DCN moves it would really take; rolled back before returning
        txn = begin_txn(sched, rec)
        tenants = sorted(pod.jobs.values(),
                         key=lambda r: (r.resident_bytes, r.job.job_id))
        drain_save = drain_total = 0.0
        plan: List[Tuple[int, int]] = []
        drained = True
        for victim in tenants:
            moved = False
            dests = sorted((d for d in sched.pods if d is not pod),
                           key=lambda d: (-d.partitioner.free_chips(),
                                          d.idx))
            for dest in dests:
                mv = MigrateTenant(pod, victim, dest)
                if not mv.probe(sched, t).feasible:
                    continue
                cost = mv._cost(sched)
                mv.apply(sched, t, record=False)   # journals into txn
                drain_save += cost.save_s
                drain_total += cost.total_s
                plan.append((victim.job.job_id, dest.idx))
                moved = True
                break
            if not moved:
                drained = False
                break
        sc_found = None
        if drained:
            # the beneficiary admits under the *target* mode's model:
            # smallest profile whose modeled duration — after the drain's
            # save traffic and the fixed switch downtime — meets the SLO
            delay = extra_delay + drain_save + mode.switch_downtime_s
            mm = sched.mode_model(self.mode_name)
            for sc, dur in mm.slo_table(rec.job):
                if t + delay + dur > rec.deadline_s:
                    continue
                if sc.profile.n_chips > sched.pod_spec.n_chips:
                    continue
                load = InstanceLoad(sc.profile.n_chips,
                                    sched._u_for(rec, sc.terms),
                                    sc.step_time, 1)
                if mm.throttle([load], sched.pod_spec) < sched.min_throttle:
                    continue
                sc_found = sc
                break
        rollback_txn(sched, txn)
        if not drained:
            self.outcome = ActionOutcome(
                False, reason="pod is not drainable: a tenant found no "
                              "destination rectangle")
            return self.outcome
        if sc_found is None:
            self.outcome = ActionOutcome(
                False, reason=f"no profile meets the SLO under mode "
                              f"{self.mode_name!r} after drain + downtime")
            return self.outcome
        self.sc = sc_found
        self.plan = plan
        self.drain_save_s = drain_save
        self.drain_total_s = drain_total
        delay = extra_delay + drain_save + mode.switch_downtime_s
        finish = t + delay + modeled_duration(rec.job, sc_found)
        self.outcome = ActionOutcome(
            True, cost_s=drain_total + mode.switch_downtime_s,
            start_delay_s=delay, projected_finish_s=finish,
            meets_slo=finish <= rec.deadline_s)
        return self.outcome

    def apply(self, sched, t, extra_delay=0.0, record=True) -> None:
        from repro.core.hw import ladder_for
        from repro.cluster.autoscale import MigrateTenant
        assert self.sc is not None, "apply() requires a successful probe()"
        self._begin(sched, record)
        pod = self.pod
        mode = sched._modes[self.mode_name]
        txn_touch(sched, pod)
        # replay the probed drain plan (re-probing each move binds its
        # destination origin on the current state)
        for vid, didx in self.plan:
            victim = pod.jobs[vid]
            mv = MigrateTenant(pod, victim, sched.pods[didx])
            out = mv.probe(sched, t)
            assert out.feasible, "probed drain plan must replay"
            mv.apply(sched, t, record=False)
        sched._reconfigs += 1
        pod.mode = self.mode_name
        pod.partitioner.set_profiles(ladder_for(mode))
        pod.gen += 1   # mode flip invalidates every cached structural core
        delay = extra_delay + self.drain_save_s + mode.switch_downtime_s
        cand = candidate_on(pod, self.rec.job, self.sc, t,
                            self.rec.deadline_s)
        assert cand is not None, "drained pod must admit the beneficiary"
        sched._place(self.rec, cand, t, start_delay=delay)


class Grow(Action):
    """Extend the running job ``rec`` into free neighbour chips via the
    partitioner's transactional ``extend()`` — the symmetric move to a
    shrink, priced identically (the re-planned resident bytes cross the
    pod's host links) and power-gated like an admission.

    Like ``Repack``, ``find`` commits the grid extension as it scans (the
    primitive is transactional on its own), so the action's transaction
    spans ``find``+``apply``."""
    kind = "grow"

    def __init__(self, rec: "JobRecord", pod: "PodState"):
        super().__init__(rec)
        self.pod = pod
        self.sc: Optional[PerfScore] = None

    @classmethod
    def find(cls, sched: "ClusterScheduler", pod: "PodState",
             rec: "JobRecord", t: float,
             record: bool = True, max_chips: Optional[int] = None,
             ascending: bool = False) -> Optional["Grow"]:
        """Largest power-feasible profile whose rectangle extension fits
        the free neighbourhood and whose step time beats the current one.
        ``max_chips`` caps the candidate ladder and ``ascending=True``
        flips the scan to the *smallest* qualifying profile — the gentle
        rung-by-rung step-up the autoscaler wants, versus the scheduler's
        default grab-everything-free sweep."""
        act = cls(rec, pod)
        act._txn = begin_txn(sched, rec) if record else None
        bigger = sorted((sc for sc in sched.perf.options(rec.job,
                                                         ignore_pin=True)
                         if sc.profile.n_chips > rec.n_chips
                         and sc.step_time < rec.step_time_s
                         and (max_chips is None
                              or sc.profile.n_chips <= max_chips)),
                        key=lambda sc: (sc.profile.n_chips if ascending
                                        else -sc.profile.n_chips))
        free = pod.partitioner.free_chips()
        for sc in bigger:
            if sc.profile.n_chips - rec.n_chips > free:
                continue   # not even the chip count fits, let alone power
            if not act._power_ok(sched, sc):
                continue
            txn_touch(sched, pod)
            try:
                pod.partitioner.extend(rec.slice_id, sc.profile)
            except (RuntimeError, ValueError):
                continue   # extend is transactional: nothing changed
            act.sc = sc
            t_mig = int(sc.plan.resident_bytes) / sched._pod_host_bw
            act.outcome = ActionOutcome(True, cost_s=t_mig,
                                        start_delay_s=t_mig)
            return act
        if act._txn is not None:
            commit_txn(sched, act._txn)
            act._txn = None
        return None

    def probe(self, sched, t, extra_delay=0.0) -> ActionOutcome:
        txn = begin_txn(sched)
        found = Grow.find(sched, self.pod, self.rec, t, record=False)
        rollback_txn(sched, txn)
        if found is None:
            self.outcome = ActionOutcome(
                False, reason="no feasible rectangle extension")
        else:
            self.outcome = found.outcome
        return self.outcome

    def _power_ok(self, sched, sc: PerfScore) -> bool:
        loads = [InstanceLoad(sc.profile.n_chips,
                              sched._u_for(self.rec, sc.terms),
                              sc.step_time, 1)
                 if r is self.rec else r.load()
                 for r in self.pod.jobs.values()]
        return sched.perf.throttle(loads, sched.pod_spec) \
            >= sched.min_throttle

    def apply(self, sched, t, extra_delay=0.0, record=True) -> None:
        assert self.sc is not None, "apply() requires a successful find()"
        # like Repack: the transaction spans find()+apply() (the grid was
        # already extended in find) — see the assertion rationale there
        assert not record or self._txn is not None, \
            "Grow transactions open in find(); bind with find(record=True)"
        pod, rec, sc = self.pod, self.rec, self.sc
        sched._grows += 1
        moved_bytes = int(sc.plan.resident_bytes)
        rec.profile_name = sc.profile.name
        rec.rung = sc.rung
        rec.origin = pod.partitioner.allocations[rec.slice_id].origin
        rec.u_compute = sched._u_for(rec, sc.terms)
        rec.step_time_s = sc.step_time
        rec.resident_bytes = moved_bytes
        rec.grown = True
        pod.sim.resize(rec.job.job_id, sc.profile.n_chips,
                       rec.u_compute, sc.step_time)
        sched._charge_migration(pod, moved_bytes, [rec], t)
        sched._reissue_after_resize(pod, rec, t)


# the find() scanners the policies enumerate, in deterministic kind order
_FINDERS = {
    "shrink": Shrink.find,
    "preempt": Preempt.find,
    "migrate": MigrateAcrossPods.find,
    "reconfigure": ReconfigurePartition.find,
}


def select_cheapest(options: Sequence[Action]) -> Optional[Action]:
    """The probe → price → select comparator: among feasible, SLO-
    preserving rescue actions, pick the smallest modeled cost in seconds;
    ties break toward the least disruptive kind (shrink < migrate <
    preempt), then the lowest victim job id. An empty option set returns
    ``None`` — the job queues (the cheapest action is to wait)."""
    options = [o for o in options
               if o is not None and o.outcome is not None
               and o.outcome.feasible]
    if not options:
        return None
    return min(options, key=lambda o: (o.outcome.cost_s, o.rank,
                                       o.victim_id))


# ---------------------------------------------------------------------------
# scheduler policies (the selection layer)
# ---------------------------------------------------------------------------
class SchedulerPolicy:
    """Protocol: given a blocked deadline job, pick and *commit* a rescue
    plan. ``rescue`` returns the list of committed actions (in order), or
    ``None`` after leaving state untouched — committed trials must be
    rolled back before returning ``None``. A chaining policy sets
    ``chains_grow`` so the scheduler runs a grow sweep right after a
    committed plan (instead of only after completion events)."""
    name = "base"
    chains_grow = False

    def rescue(self, sched: "ClusterScheduler", rec: "JobRecord",
               t: float) -> Optional[List[Action]]:
        raise NotImplementedError


class GreedyCheapestRescue(SchedulerPolicy):
    """The legacy ``cheapest_rescue`` behaviour: probe every enabled
    rescue kind, price the first feasible option of each, commit the
    cheapest single action."""
    name = "greedy"

    def rescue(self, sched, rec, t) -> Optional[List[Action]]:
        options = [_FINDERS[kind](sched, rec, t)
                   for kind in RESCUE_KINDS
                   if sched.spec.enabled(kind)]
        choice = select_cheapest(options)
        if choice is None:
            return None
        choice.apply(sched, t, record=False)   # final choice: no rollback
        return [choice]


class LookAheadPolicy(GreedyCheapestRescue):
    """Greedy plus a two-action look-ahead: when no single action rescues
    the blocked job, trial-apply a beneficiary-less eviction (``Preempt``
    enabler, cheapest victims first), re-probe the whole single-action
    space on the resulting state — a direct ``Place`` into what the
    eviction freed, or any enabled rescue — and commit the pair if the
    chain lands inside the SLO; otherwise roll the trial back exactly.
    The enabler's checkpoint drain is threaded into the chained action's
    start delay, so a chain can never promise an SLO its own traffic
    breaks. Requires ``"preempt"`` in the action allowlist (the enabler
    is an eviction)."""
    name = "lookahead"
    chains_grow = True

    def rescue(self, sched, rec, t) -> Optional[List[Action]]:
        single = super().rescue(sched, rec, t)
        if single is not None:
            return single
        if rec.deadline_s is None or not sched.spec.enabled("preempt"):
            return None
        if not any(True for _ in slo_profiles(sched, rec, t)):
            return None   # no profile meets the deadline even undelayed
        for enabler in Preempt.enablers(sched, rec, t):
            out = enabler.probe(sched, t)
            if not any(meets_after(rec, t, sc, out.start_delay_s)
                       for sc in slo_profiles(sched, rec, t)):
                continue   # this victim's drain alone blows the deadline
            enabler.apply(sched, t)   # trial: records, may roll back
            closer = self._closer(sched, rec, t, out.start_delay_s)
            if closer is not None:
                # the chain lands: close the enabler's recorded span
                # (journaling into any outer trial) before committing
                # the closer on top of it
                enabler.commit(sched)
                closer.apply(sched, t, extra_delay=out.start_delay_s,
                             record=False)
                return [enabler, closer]
            enabler.rollback(sched)
        return None

    def _closer(self, sched, rec, t, extra_delay) -> Optional[Action]:
        """Best follow-up on the trial state: a direct placement into what
        the enabler freed, else the cheapest enabled rescue."""
        cands = sched.candidates_for(rec.job, t, rec.deadline_s)
        for cand in cands:
            act = Place(rec, cand)
            out = act.probe(sched, t, extra_delay=extra_delay)
            if out.feasible and out.meets_slo:
                return act
        options = [_FINDERS[kind](sched, rec, t, extra_delay=extra_delay)
                   for kind in RESCUE_KINDS
                   if sched.spec.enabled(kind)]
        return select_cheapest(options)


_SCHEDULER_POLICIES = {
    "greedy": GreedyCheapestRescue,
    "lookahead": LookAheadPolicy,
}


def get_scheduler_policy(name: str) -> SchedulerPolicy:
    if name == "search" and "search" not in _SCHEDULER_POLICIES:
        # lazy: planner.py imports this module, so registering at import
        # time would be a cycle — the first "search" request resolves it
        from repro.cluster.planner import SearchPolicy
        _SCHEDULER_POLICIES["search"] = SearchPolicy
    try:
        return _SCHEDULER_POLICIES[name]()
    except KeyError:
        raise KeyError(f"unknown scheduler policy {name!r}; have "
                       f"{sorted(_SCHEDULER_POLICIES)}") from None
