"""Bounded best-first planning over the priced action space.

``SearchPolicy`` generalizes the two fixed selection strategies in
``cluster/actions.py`` — ``GreedyCheapestRescue`` (depth 1: commit the
cheapest single rescue) and ``LookAheadPolicy`` (depth 2, first
improvement: one eviction enabler, then the first chain that lands
inside the SLO) — into a budgeted search for the *cheapest*
SLO-preserving chain of up to ``max_depth`` actions. The transactional
``apply``/``rollback`` surface of the Action API is the trial tree:
every enabler is applied inside a recorded undo-log span, deeper
enablers nest LIFO, and every branch is rolled back bit-exactly before
the next sibling is tried, so the search never leaks state. Structural
probe work inside the tree is memoized by the scheduler's ``ProbeCache``
(untouched pods keep their generations across branches), which is what
makes the extra probing affordable at trace scale.

The search prunes three ways, all deterministic:

* **Budget** — ``budget_probes`` caps the structural probe
  consultations (priced + cache hits, the scheduler's
  ``_probes_priced``/``_probe_hits`` deltas) a single rescue may spend
  beyond the root single-action scan. Exhausting the budget stops
  expansion, never unwinds a found incumbent.
* **Admissible lower bound** — a chain of evictions still needs a
  closer, and the cheapest conceivable closer is a free ``Place`` into a
  freed rectangle, so ``g`` (the chain's accumulated action cost) is an
  admissible completion bound: any branch with ``g >= incumbent`` is
  cut. Priced closers only tighten the incumbent when recorded.
* **Dominance** — among sibling enablers on the *same pod*, one that
  costs no less, drains no less and frees no more chips than an
  already-kept sibling is strictly dominated and dropped: every chain
  through it is available no-worse through the dominator.

``RebalanceController`` is the proactive complement: instead of waiting
for a blocked deadline job, it spends a per-tick probe budget at CONTROL
events relocating cheap tenants off the power-starved pod whenever the
pods' power-headroom spread drifts past a threshold — the same
DCN-priced ``MigrateTenant`` moves the reactive autoscaler uses.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, List, Optional, Tuple

from repro.configs import get_config, get_shape

from repro.cluster.actions import (Action, GreedyCheapestRescue, Place,
                                   Preempt, RESCUE_KINDS, _FINDERS,
                                   meets_after, select_cheapest,
                                   slo_profiles)

if TYPE_CHECKING:  # pragma: no cover
    from repro.cluster.scheduler import ClusterScheduler, JobRecord, PodState

__all__ = ["SearchPolicy", "RebalanceController"]


class SearchPolicy(GreedyCheapestRescue):
    """Budgeted branch-and-bound over eviction chains, cheapest first.

    Chains are ``[enabler_1, ..., enabler_k, closer]`` with
    ``k + 1 <= max_depth``: enablers are beneficiary-less ``Preempt``
    evictions (their probes are pure arithmetic — the priced structural
    work happens when a branch is closed), the closer is a direct
    ``Place`` into the freed space or any enabled single rescue. Chain
    cost is the sum of member action costs; the save drains of the
    enablers serialize over the pod's host links, so a chain's closer is
    probed with the *accumulated* drain as its start delay — a chain can
    never promise an SLO its own traffic breaks. The cheapest complete
    chain wins; ties never arise because the expansion order is total
    (enabler cost, then victim id) and only strict improvements replace
    the incumbent. ``max_depth=2`` explores exactly the look-ahead
    policy's chain shape but keeps searching for the cheapest chain
    where ``LookAheadPolicy`` commits the first improvement; depth 1
    (the root scan) is the greedy policy."""
    name = "search"
    chains_grow = True

    def __init__(self, budget_probes: int = 96, max_depth: int = 3):
        self.budget_probes = budget_probes
        self.max_depth = max_depth

    # -- probe accounting ------------------------------------------------
    def _spent(self, sched: "ClusterScheduler") -> int:
        return sched._probes_priced + sched._probe_hits - self._base

    def rescue(self, sched: "ClusterScheduler", rec: "JobRecord",
               t: float) -> Optional[List[Action]]:
        # Depth 1, always in budget: the greedy single-action scan seeds
        # the incumbent, so search never does worse than greedy.
        options = [_FINDERS[kind](sched, rec, t)
                   for kind in RESCUE_KINDS
                   if sched.spec.enabled(kind)]
        choice = select_cheapest(options)
        self._best_cost = (choice.outcome.cost_s if choice is not None
                           else float("inf"))
        self._best: Optional[Tuple[List[Preempt], Action]] = \
            (([], choice) if choice is not None else None)
        deeper = (self.max_depth >= 2
                  and rec.deadline_s is not None
                  and sched.spec.enabled("preempt")
                  and any(True for _ in slo_profiles(sched, rec, t)))
        if deeper:
            # batch-reprice the candidate space once: every resident
            # victim's (arch, shape) row lands in the PerfModel score
            # memo in one sweep instead of cold misses inside the tree
            pairs = {(r.job.arch, r.job.shape)
                     for pod in sched.pods for r in pod.jobs.values()}
            pairs.add((rec.job.arch, rec.job.shape))
            sched.perf.score_many({get_config(a) for a, _ in pairs},
                                  {get_shape(s) for _, s in pairs})
            self._base = sched._probes_priced + sched._probe_hits
            self._expand(sched, rec, t, chain=[], drain=0.0, g=0.0)
        if self._best is None:
            return None
        enablers, closer = self._best
        if not enablers:            # the greedy single was already cheapest
            closer.apply(sched, t, record=False)
            return [closer]
        # every trial span was rolled back above, so state is bit-exactly
        # pre-rescue: re-applying the recorded chain reproduces the probed
        # trial states (and the closer's bound candidate) deterministically
        delay = 0.0
        for en in enablers:
            delay += en._cost(sched).save_s
            en.apply(sched, t, record=False)
        closer.apply(sched, t, extra_delay=delay, record=False)
        return [*enablers, closer]

    def _expand(self, sched: "ClusterScheduler", rec: "JobRecord", t: float,
                chain: List[Preempt], drain: float, g: float) -> None:
        """Try one more enabler on the current trial state, cheapest
        first, closing and recursing under budget/bound/dominance."""
        kept: List[Tuple[float, float, int, "PodState"]] = []
        enablers = sorted(
            ((en.probe(sched, t), en) for en in
             Preempt.enablers(sched, rec, t)),
            key=lambda p: (p[0].cost_s, p[1].victim_id))
        for out, en in enablers:
            if self._spent(sched) >= self.budget_probes:
                return
            new_g = g + out.cost_s
            if new_g >= self._best_cost:
                # admissible bound: the cheapest remaining single action
                # is a free Place, so no completion can beat the incumbent
                return   # enablers are cost-sorted: siblings only worsen
            freed = en.victim.n_chips
            if any(c <= out.cost_s and d <= out.start_delay_s and f >= freed
                   and pod is en.pod for c, d, f, pod in kept):
                continue   # strictly dominated by a kept same-pod sibling
            new_drain = drain + out.start_delay_s
            if not any(meets_after(rec, t, sc, new_drain)
                       for sc in slo_profiles(sched, rec, t)):
                continue   # the chain's own save traffic blows the SLO
            kept.append((out.cost_s, out.start_delay_s, freed, en.pod))
            en.apply(sched, t)   # recorded trial span
            closer = self._closer(sched, rec, t, new_drain)
            if closer is not None \
                    and new_g + closer.outcome.cost_s < self._best_cost:
                self._best_cost = new_g + closer.outcome.cost_s
                self._best = (chain + [en], closer)
            if len(chain) + 2 < self.max_depth \
                    and self._spent(sched) < self.budget_probes:
                self._expand(sched, rec, t, chain + [en], new_drain, new_g)
            en.rollback(sched)

    def _closer(self, sched: "ClusterScheduler", rec: "JobRecord", t: float,
                drain: float) -> Optional[Action]:
        """Cheapest completion on the trial state: a free direct placement
        into what the evictions freed, else the cheapest enabled rescue —
        the same completion rule as ``LookAheadPolicy._closer``."""
        cands = sched.candidates_for(rec.job, t, rec.deadline_s)
        for cand in cands:
            act = Place(rec, cand)
            out = act.probe(sched, t, extra_delay=drain)
            if out.feasible and out.meets_slo:
                return act
        options = [_FINDERS[kind](sched, rec, t, extra_delay=drain)
                   for kind in RESCUE_KINDS
                   if sched.spec.enabled(kind)]
        return select_cheapest(options)


class RebalanceController:
    """Proactive cross-pod balancing at CONTROL events.

    Reactive rescues only fire when a deadline job is already blocked.
    This controller watches the pods' *power headroom* — the gap between
    the pod power cap and the uncapped modeled draw — and acts on the
    hazard state where the max-min spread exceeds ``spread_watts``
    *and* the coolest pod is also the packed one: every free rectangle
    then sits on the power-tight pod, where the next hot deadline
    arrival will be power-blocked. It spends up to ``budget_probes``
    ``MigrateTenant`` probes per tick moving the cool pod's cheapest
    (least resident state) tenant to a chip-roomier pod — a cool tenant
    adds little draw, so the destination gate passes where a hot
    placement would not — simultaneously narrowing the draw spread and
    freeing a rectangle where the arrival wants it.

    Duck-typed like ``AutoscaleController`` (``spec.interval_s``,
    ``control``, ``finalize``, ``metrics_fields``) so it plugs into
    ``ClusterScheduler(autoscaler=...)`` unchanged; it keeps no
    per-tenant model state, only a cooldown stamp."""

    class _Spec:
        def __init__(self, interval_s: float):
            self.interval_s = interval_s

    def __init__(self, interval_s: float = 300.0, *,
                 spread_watts: float = 500.0, budget_probes: int = 8,
                 cooldown_s: float = 600.0):
        self.spec = self._Spec(interval_s)
        self.spread_watts = spread_watts
        self.budget_probes = budget_probes
        self.cooldown_s = cooldown_s
        self._last_move_s = -float("inf")
        self.moves = 0
        self.probes = 0

    def _headroom(self, sched: "ClusterScheduler",
                  pod: "PodState") -> float:
        return (sched.pod_spec.power_cap_watts
                - pod.sim.draw(capped=False))

    def control(self, sched: "ClusterScheduler", t: float) -> bool:
        from repro.cluster.autoscale import MigrateTenant
        if len(sched.pods) < 2 or t - self._last_move_s < self.cooldown_s:
            return False
        by_headroom = sorted(sched.pods,
                             key=lambda p: (self._headroom(sched, p), p.idx))
        tight, cool = by_headroom[0], by_headroom[-1]
        spread = (self._headroom(sched, cool)
                  - self._headroom(sched, tight))
        if spread <= self.spread_watts:
            return False
        # the hazard state: the *cool* pod is also the packed one, so the
        # only free rectangles sit on the power-tight pod — the next hot
        # deadline arrival will be power-blocked there. Relieve it by
        # moving the cool pod's cheapest tenant to a chip-roomier pod
        # (cool tenants add little draw, so the gate passes where a hot
        # placement would not).
        if cool.partitioner.free_chips() >= max(
                p.partitioner.free_chips()
                for p in sched.pods if p is not cool):
            return False   # the cool pod is not the packing bottleneck
        victims = sorted((r for r in cool.jobs.values()
                          if not r.executed and not r.finished),
                         key=lambda r: (r.resident_bytes, r.job.job_id))
        dests = sorted((d for d in sched.pods if d is not cool),
                       key=lambda d: (-d.partitioner.free_chips(), d.idx))
        budget = self.budget_probes
        for victim in victims:
            for dest in dests:
                if budget <= 0:
                    return False
                if dest.partitioner.free_chips() \
                        <= cool.partitioner.free_chips():
                    continue
                act = MigrateTenant(cool, victim, dest)
                self.probes += 1
                budget -= 1
                if not act.probe(sched, t).feasible:
                    continue
                act.apply(sched, t, record=False)
                self.moves += 1
                self._last_move_s = t
                # one move per tick: re-measure the spread next interval
                return True
        return False

    def finalize(self, sched: "ClusterScheduler", end_s: float) -> None:
        pass

    def metrics_fields(self) -> dict:
        return {"autoscale_resizes": self.moves}
