"""Slice-profile selection and pod/origin scoring for cluster placement.

Two-level decision, per queued job:

1. **Which profile?** MISO-style (arXiv 2207.11428): every feasible
   ``SliceProfile`` × offload plan is scored by the shared
   ``core.perfmodel.PerfModel`` (fine-grained CPU offloading widens the
   feasible set exactly as the paper intends) and ranked by perf-per-chip,
   preferring profiles whose modeled duration meets the job's SLO deadline.
2. **Which pod / origin?** Fragmentation-aware (arXiv 2512.16099): among
   the free aligned origins for the chosen profile, pick the one whose
   placement preserves the largest still-placeable profile, so large
   future jobs are not stranded behind scattered small rectangles.

``FirstFitPolicy`` is the naive baseline: smallest feasible profile, first
pod with room, first free origin (row-major) — the policy whose stranding
``benchmarks/bench_cluster.py`` quantifies.

This module only *enumerates and scores* placements. When no candidate
exists for a deadline job, selection escalates to the Action API
(``cluster/actions.py``): a ``SchedulerPolicy`` probes the allowed
rescue actions (shrink / preempt / cross-pod migrate), prices them, and
commits the cheapest SLO-preserving plan.

Units used throughout this module (and the scheduler): durations and
costs are **nominal seconds** of virtual time (wall-clock seconds once
throttle stretch is applied by ``PodSimulator``), data volumes are
**bytes**, capacities are **chips** (one chip = one grid cell of the
pod; profiles come in power-of-two rectangles of them).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

from repro.configs import get_config, get_shape
from repro.core.hw import ChipSpec, V5E
from repro.core.offload import OffloadPlan
from repro.core.perfmodel import PerfModel, PerfScore, get_model
from repro.core.roofline import RooflineTerms
from repro.core.slices import SliceProfile
from repro.core.workload import WorkloadEstimate

from repro.cluster.trace import Job

if TYPE_CHECKING:  # pragma: no cover
    from repro.cluster.scheduler import PodState


@dataclass(frozen=True)
class Candidate:
    """One scored placement option for a job."""
    pod_idx: int
    profile: SliceProfile
    origin: Tuple[int, int]
    plan: OffloadPlan
    terms: RooflineTerms
    duration_s: float        # modeled (unthrottled) or pinned duration
    perf_per_chip: float     # (1/step_time)/n_chips — the MISO score
    largest_after: int       # chips of largest placeable profile after place
    meets_deadline: bool
    rung: str = ""           # PerfScore.rung; "+cpuX.XX" suffix marks a twin


def estimate_for(job: Job) -> WorkloadEstimate:
    """Full-size analytic model for a trace job (pod-scale numbers even when
    execution runs reduced configs)."""
    return WorkloadEstimate(get_config(job.arch), get_shape(job.shape))


def feasible_options(job: Job, chip: ChipSpec = V5E
                     ) -> Tuple[Tuple[SliceProfile, OffloadPlan, RooflineTerms], ...]:
    """(profile, plan, terms) for every profile the job fits on — possibly
    only via offloading — smallest profile first. A pinned ``job.profile``
    restricts the set to that profile. Thin compatibility view over the
    shared ``PerfModel`` memo (``get_model(chip).options``)."""
    return tuple((sc.profile, sc.plan, sc.terms)
                 for sc in get_model(chip).options(job))


def modeled_duration(job: Job, score: PerfScore) -> float:
    """Unthrottled duration of ``job`` on ``score.profile`` in nominal
    seconds (``steps × step_time``); a pinned ``job.duration_s`` is a
    wall-clock contract and is returned as-is."""
    return (job.duration_s if job.duration_s is not None
            else job.steps * score.step_time)


def ideal_duration(job: Job, chip: ChipSpec = V5E,
                   perf: Optional[PerfModel] = None) -> Optional[float]:
    """Duration on the job's fastest feasible profile, unthrottled — the
    SLO reference point (deadline = arrival + slo_factor × ideal)."""
    if job.duration_s is not None:
        return job.duration_s
    perf = perf if perf is not None else get_model(chip)
    opts = perf.options(job)
    if not opts:
        return None
    return min(job.steps * sc.step_time for sc in opts)


class PlacementPolicy:
    name = "base"
    repack_enabled = False

    def candidates(self, job: Job, pods: Sequence["PodState"],
                   chip: ChipSpec, now: float,
                   deadline_s: Optional[float],
                   perf: Optional[PerfModel] = None) -> List[Candidate]:
        raise NotImplementedError


class FirstFitPolicy(PlacementPolicy):
    """Smallest feasible profile, first pod, first origin — no look-ahead."""
    name = "first_fit"

    def candidates(self, job, pods, chip, now, deadline_s, perf=None):
        perf = perf if perf is not None else get_model(chip)
        cands = []
        for sc in perf.options(job):
            dur = modeled_duration(job, sc)
            need = sc.profile.n_chips
            for pod in pods:
                if pod.partitioner.free_chips() < need:
                    continue   # no origin can be free — skip the index
                origins = pod.partitioner.origins_for(sc.profile)
                if not origins:
                    continue
                cands.append(Candidate(
                    pod_idx=pod.idx, profile=sc.profile, origin=origins[0],
                    plan=sc.plan, terms=sc.terms, duration_s=dur,
                    perf_per_chip=sc.perf_per_chip,
                    largest_after=0,
                    meets_deadline=_meets(now, dur, deadline_s),
                    rung=sc.rung))
        return cands


class FragAwarePolicy(PlacementPolicy):
    """MISO profile scoring + stranding-minimizing pod/origin choice."""

    def __init__(self, repack: bool = False):
        self.repack_enabled = repack
        self.name = "frag_repack" if repack else "frag"

    def candidates(self, job, pods, chip, now, deadline_s, perf=None):
        perf = perf if perf is not None else get_model(chip)
        cands = []
        for sc in perf.options(job):
            dur = modeled_duration(job, sc)
            need = sc.profile.n_chips
            for pod in pods:
                if pod.partitioner.free_chips() < need:
                    continue   # no origin can be free — skip the index
                best = _best_origin(pod.partitioner, sc.profile)
                if best is None:
                    continue
                origin, largest_after = best
                cands.append(Candidate(
                    pod_idx=pod.idx, profile=sc.profile, origin=origin,
                    plan=sc.plan, terms=sc.terms, duration_s=dur,
                    perf_per_chip=sc.perf_per_chip,
                    largest_after=largest_after,
                    meets_deadline=_meets(now, dur, deadline_s),
                    rung=sc.rung))
        cands.sort(key=lambda c: (
            not c.meets_deadline,        # SLO-feasible placements first
            -c.perf_per_chip,            # then best perf per chip (MISO)
            -c.largest_after,            # then least stranding
            c.pod_idx, c.origin))
        return cands


def _meets(now: float, duration: float, deadline_s: Optional[float]) -> bool:
    return deadline_s is None or (now + duration) <= deadline_s


def candidate_on(pod: "PodState", job: Job, score: PerfScore, now: float,
                 deadline_s: Optional[float]) -> Optional[Candidate]:
    """Best-origin candidate for a *specific* (pod, profile) — used by the
    Action API's commit paths (repack / shrink / preempt / migrate), which
    already know which pod they reshaped."""
    best = _best_origin(pod.partitioner, score.profile)
    if best is None:
        return None
    origin, largest_after = best
    dur = modeled_duration(job, score)
    return Candidate(pod_idx=pod.idx, profile=score.profile, origin=origin,
                     plan=score.plan, terms=score.terms, duration_s=dur,
                     perf_per_chip=score.perf_per_chip,
                     largest_after=largest_after,
                     meets_deadline=_meets(now, dur, deadline_s),
                     rung=score.rung)


def _best_origin(partitioner, profile: SliceProfile
                 ) -> Optional[Tuple[Tuple[int, int], int]]:
    """(origin, largest_placeable_chips_after) maximizing the look-ahead;
    row-major order breaks ties deterministically. Answered (and memoized
    per grid generation) by the partitioner's free-rectangle index."""
    return partitioner.best_origin_for(profile)


_POLICIES = {
    "first_fit": FirstFitPolicy,
    "frag": lambda: FragAwarePolicy(repack=False),
    "frag_repack": lambda: FragAwarePolicy(repack=True),
}

POLICY_NAMES = tuple(_POLICIES)


def get_policy(name: str) -> PlacementPolicy:
    try:
        return _POLICIES[name]()
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; have {sorted(_POLICIES)}"
                       ) from None
