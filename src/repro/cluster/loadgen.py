"""Seeded request-arrival generators for serving tenants — the
"millions of users" input side of the autoscale control loop.

A ``LoadCurve`` maps virtual time to an instantaneous request rate
(requests/second). Two concrete shapes cover the classic serving
regimes:

* ``DiurnalCurve`` — a raised-cosine day/night swing with a per-tenant
  phase offset, the slow predictable tide every fleet sees.
* ``BurstyCurve`` — a seeded Poisson process of spike onsets, each
  decaying exponentially: flash crowds layered over a quiet floor.

Curves compose (``a + b``, ``0.5 * a``) so a tenant can be "diurnal
plus flash crowds" without a new class. One curve drives **both**
consumption paths from the same trace:

* the *analytic* path — ``arrival_counts`` buckets a seeded Poisson
  draw per control interval, which ``AutoscaleController`` feeds into
  its queue model against ``PodSimulator``-scheduled records;
* the *live* path — ``arrival_times`` draws individual arrival
  instants (Lewis thinning) you can replay into a ``TenantEngine``.

``serving_workload`` builds the matching long-lived serving ``Job``s:
pinned wall-clock duration (a tenant lives all day — the autoscaler
varies its *chips*, never its lifetime) plus one phase-staggered curve
per tenant. Rates are calibrated in units of the modeled service rate
of a reference slice profile (``service_rate``), so "peak = 2.2"
means *2.2× what the smallest slice can serve* regardless of arch.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.cluster.trace import (KIND_PRIORITY, KIND_SHAPE, SERVING, Job)

__all__ = [
    "LoadCurve", "ConstantCurve", "DiurnalCurve", "BurstyCurve",
    "CURVE_NAMES", "arrival_counts", "arrival_times", "service_rate",
    "serving_workload",
]

CURVE_NAMES = ("diurnal", "bursty")


class LoadCurve:
    """Instantaneous request rate over virtual time; composable."""

    def rate(self, t: float) -> float:
        raise NotImplementedError

    def __add__(self, other: "LoadCurve") -> "LoadCurve":
        return _SumCurve(self, other)

    def __mul__(self, k: float) -> "LoadCurve":
        return _ScaledCurve(self, float(k))

    __rmul__ = __mul__


@dataclass(frozen=True)
class ConstantCurve(LoadCurve):
    rps: float

    def rate(self, t: float) -> float:
        return self.rps


@dataclass(frozen=True)
class _SumCurve(LoadCurve):
    a: LoadCurve
    b: LoadCurve

    def rate(self, t: float) -> float:
        return self.a.rate(t) + self.b.rate(t)


@dataclass(frozen=True)
class _ScaledCurve(LoadCurve):
    inner: LoadCurve
    k: float

    def rate(self, t: float) -> float:
        return self.k * self.inner.rate(t)


@dataclass(frozen=True)
class DiurnalCurve(LoadCurve):
    """Raised-cosine day/night swing: trough ``base_rps`` at
    ``t = phase_s`` (mod period), peak ``peak_rps`` half a period later."""
    base_rps: float
    peak_rps: float
    period_s: float = 86400.0
    phase_s: float = 0.0

    def rate(self, t: float) -> float:
        theta = 2.0 * math.pi * (t - self.phase_s) / self.period_s
        return (self.base_rps
                + (self.peak_rps - self.base_rps) * 0.5 * (1.0 - math.cos(theta)))


class BurstyCurve(LoadCurve):
    """Flash crowds over a quiet floor: burst onsets are a seeded Poisson
    process (mean gap ``mean_gap_s``); each burst adds ``burst_rps`` that
    decays as ``exp(-(t - onset) / decay_s)``. Onsets are drawn once at
    construction, so ``rate`` is a pure deterministic function of ``t``."""

    def __init__(self, base_rps: float, burst_rps: float, *,
                 mean_gap_s: float, decay_s: float, seed=0,
                 horizon_s: float = 86400.0):
        self.base_rps = base_rps
        self.burst_rps = burst_rps
        self.decay_s = decay_s
        self.horizon_s = horizon_s
        rng = np.random.default_rng(seed)
        onsets: List[float] = []
        t = float(rng.exponential(mean_gap_s))
        while t < horizon_s:
            onsets.append(t)
            t += float(rng.exponential(mean_gap_s))
        self.onsets = np.asarray(onsets, dtype=float)

    def rate(self, t: float) -> float:
        active = self.onsets[self.onsets <= t]
        if active.size == 0:
            return self.base_rps
        # bursts older than ~9 decay constants contribute < 1.3e-4 of
        # their peak; keeping them costs nothing and stays exact
        return self.base_rps + self.burst_rps * float(
            np.exp(-(t - active) / self.decay_s).sum())


def get_curve(name: str, **kw) -> LoadCurve:
    """CLI registry: construct a named curve shape."""
    if name == "diurnal":
        return DiurnalCurve(**kw)
    if name == "bursty":
        return BurstyCurve(**kw)
    raise ValueError(f"unknown load curve {name!r}; valid: {CURVE_NAMES}")


# ---------------------------------------------------------------------------
# sampling: one curve, two consumption paths
# ---------------------------------------------------------------------------
def arrival_counts(curve: LoadCurve, interval_s: float, n_intervals: int,
                   seed=0) -> np.ndarray:
    """Seeded Poisson request counts per control interval (the analytic
    path). Interval ``k`` covers ``(k·dt, (k+1)·dt]`` with mean
    ``rate(midpoint) · dt`` — the midpoint rule is exact for the linear
    part of any smooth curve over one interval."""
    rng = np.random.default_rng(seed)
    lam = np.asarray([curve.rate((k + 0.5) * interval_s) * interval_s
                      for k in range(n_intervals)], dtype=float)
    return rng.poisson(np.maximum(lam, 0.0))


def arrival_times(curve: LoadCurve, horizon_s: float, seed=0,
                  max_rate: float = None) -> np.ndarray:
    """Individual seeded arrival instants via Lewis thinning (the live
    path — replay these into a ``TenantEngine``). ``max_rate`` bounds the
    proposal process; by default it is scanned from the curve."""
    if max_rate is None:
        grid = np.linspace(0.0, horizon_s, 512)
        max_rate = max(curve.rate(float(g)) for g in grid) * 1.1
    if max_rate <= 0.0:
        return np.empty(0, dtype=float)
    rng = np.random.default_rng(seed)
    out: List[float] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / max_rate))
        if t >= horizon_s:
            break
        if rng.uniform() * max_rate <= curve.rate(t):
            out.append(t)
    return np.asarray(out, dtype=float)


# ---------------------------------------------------------------------------
# the matching cluster workload
# ---------------------------------------------------------------------------
def service_rate(arch: str, profile: str, *, req_per_step: float = 1.0,
                 shape: str = KIND_SHAPE[SERVING]) -> float:
    """Modeled requests/second a slice ``profile`` sustains for ``arch``:
    ``req_per_step`` requests complete per decode step of the shared
    ``PerfModel``'s step time. The calibration unit for load curves."""
    from repro.configs import get_config, get_shape
    from repro.core.perfmodel import get_model
    from repro.core.slices import get_profile
    sc = get_model().score(get_config(arch), get_shape(shape),
                           get_profile(profile))
    return req_per_step / sc.step_time


def serving_workload(n_tenants: int = 2, curve: str = "diurnal", *,
                     horizon_s: float = 86400.0, seed: int = 0,
                     arch: str = "gpt2-124m",
                     start_profile: str = "1s.16c",
                     calibration_profile: str = "1s.16c",
                     base_frac: float = 0.2, peak_frac: float = 2.2,
                     period_s: float = None, phase_frac: float = 0.125,
                     slo_factor: float = 8.0,
                     req_per_step: float = 1.0,
                     ) -> Tuple[List[Job], Dict[int, LoadCurve]]:
    """Long-lived serving tenants plus their per-tenant load curves.

    Each tenant is one serving ``Job`` with a pinned wall-clock lifetime
    of ``horizon_s`` (the autoscaler changes its chips, never its
    lifetime) starting at ``start_profile``. Rates are fractions of the
    modeled service rate of ``calibration_profile`` — deliberately
    *independent* of ``start_profile``, so a fixed-provisioning run (big
    starting slice) and an autoscaled run (small starting slice) face
    the **same** traffic.

    Diurnal tenants are phase-staggered by ``phase_frac`` of the period;
    bursty tenants draw independent seeded burst onsets.
    """
    mu0 = service_rate(arch, calibration_profile, req_per_step=req_per_step)
    period = period_s if period_s is not None else horizon_s
    jobs: List[Job] = []
    curves: Dict[int, LoadCurve] = {}
    for i in range(n_tenants):
        if curve == "diurnal":
            c: LoadCurve = DiurnalCurve(base_frac * mu0, peak_frac * mu0,
                                        period_s=period,
                                        phase_s=i * phase_frac * period)
        elif curve == "bursty":
            c = BurstyCurve(base_frac * mu0, 1.2 * mu0,
                            mean_gap_s=period / 6.0, decay_s=period / 24.0,
                            seed=(seed, i), horizon_s=horizon_s)
        else:
            raise ValueError(
                f"unknown load curve {curve!r}; valid: {CURVE_NAMES}")
        jobs.append(Job(job_id=i, kind=SERVING, arch=arch,
                        shape=KIND_SHAPE[SERVING], arrival_s=0.0, steps=1,
                        slo_factor=slo_factor, profile=start_profile,
                        duration_s=horizon_s,
                        priority=KIND_PRIORITY[SERVING]))
        curves[i] = c
    return jobs, curves
