"""ClusterScheduler — event-loop scheduling of a job trace onto N pods.

Each pod is a ``StaticPartitioner`` grid plus a ``core.perfmodel.
PodSimulator`` (and optionally a live ``SliceRuntime`` so serving jobs
execute on the real engine). The loop is discrete-event in virtual seconds:
arrivals and completions are the events, placements happen greedily at each
event via a ``PlacementPolicy``, and the scheduler integrates energy / busy
chips / fragmentation over the timeline between events.

All performance and power questions go through the shared ``PerfModel`` /
``PodSimulator`` pair — no roofline or power-model glue lives here. Beyond
plain packing, the two interference surfaces static partitioning does NOT
remove (paper §V) are modeled:

* **Power** — a candidate placement is rejected when the pod simulator's
  predicted throttle with the new instance falls below ``min_throttle``
  (the §V-B shared-cap effect); the job waits instead of dragging every
  co-tenant below the cap. Jobs that *are* admitted re-solve the whole
  pod: every admission, completion, repack delay, or elastic resize
  re-projects the remaining finish time of every running job under the new
  mix — a later compute-heavy arrival retroactively stretches an in-flight
  job, exactly the §V-B interference account.
* **Fragmentation** — when a queued job fits a pod's total free chips but
  no aligned rectangle (arXiv 2512.16099 stranding), a repack-enabled
  policy triggers the partitioner's transactional ``repack()`` and pays a
  modeled migration cost: the moved slices' resident state crosses the
  pod's host links (``core.hw`` PCIe-class bandwidth), delaying the new
  job's start and stretching the moved jobs' completions.

**Elastic shrink** (``elastic=True``): when a queued deadline job would
otherwise miss its SLO, the scheduler may shrink a running low-priority
batch job to a smaller feasible profile — priced exactly like a repack
migration (the victim's resident state crosses the host links, its progress
is re-based onto the smaller slice's step time) — freeing an aligned
rectangle for the deadline job.

``frozen_durations=True`` is the compatibility mode: durations are fixed at
admission time with the legacy float arithmetic and never re-solved,
reproducing the PR 2 scheduler's numbers bit-for-bit. Crafted jobs with
pinned ``duration_s`` skip throttle modeling in both modes so tests stay
exactly deterministic.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.hw import PodSpec, V5E_POD
from repro.core.partitioner import StaticPartitioner
from repro.core.perfmodel import (InstanceLoad, PerfModel, PerfScore,
                                  PodSimulator, get_model)
from repro.core.slices import get_profile

from repro.cluster.metrics import ClusterMetrics, summarize
from repro.cluster.placement import (Candidate, PlacementPolicy,
                                     candidate_on, get_policy, ideal_duration,
                                     modeled_duration)
from repro.cluster.trace import BATCH, SERVING, Job

ARRIVE = "arrive"
FINISH = "finish"


@dataclass
class JobRecord:
    """Mutable scheduling state of one trace job."""
    job: Job
    deadline_s: Optional[float] = None
    pod_idx: Optional[int] = None
    slice_id: Optional[int] = None
    profile_name: Optional[str] = None
    origin: Optional[Tuple[int, int]] = None
    place_s: Optional[float] = None
    finish_s: Optional[float] = None
    duration_s: Optional[float] = None
    u_compute: float = 0.0
    step_time_s: float = 0.0
    resident_bytes: int = 0
    finished: bool = False
    executed: bool = False        # ran on a live SliceRuntime tenant
    shrunk: bool = False          # resized to a smaller profile mid-flight
    tokens_out: int = 0
    power_deferred: int = 0
    version: int = 0              # bumps invalidate stale finish events

    @property
    def placed(self) -> bool:
        return self.place_s is not None

    @property
    def n_chips(self) -> int:
        return get_profile(self.profile_name).n_chips if self.profile_name else 0

    def load(self) -> InstanceLoad:
        return InstanceLoad(self.n_chips, self.u_compute, self.step_time_s, 1)


@dataclass
class PodState:
    idx: int
    partitioner: StaticPartitioner
    sim: PodSimulator
    runtime: Optional[object] = None   # serving.SliceRuntime when executing
    jobs: Dict[int, JobRecord] = field(default_factory=dict)       # by job_id
    slice_jobs: Dict[int, JobRecord] = field(default_factory=dict)  # by slice


class ClusterScheduler:
    def __init__(self, n_pods: int = 2,
                 policy: Union[str, PlacementPolicy] = "frag_repack",
                 pod: PodSpec = V5E_POD, *,
                 min_throttle: float = 0.8,
                 horizon_s: Optional[float] = None,
                 frozen_durations: bool = False,
                 elastic: bool = False,
                 perf: Optional[PerfModel] = None,
                 execute_serving: bool = False,
                 mesh=None,
                 serving_slots: int = 2,
                 serving_max_seq: int = 32,
                 serving_max_new: int = 4):
        self.pod_spec = pod
        self.chip = pod.chip
        self.policy = get_policy(policy) if isinstance(policy, str) else policy
        self.min_throttle = min_throttle
        self.horizon_s = horizon_s
        self.frozen_durations = frozen_durations
        self.elastic = elastic
        self.perf = perf if perf is not None else get_model(pod.chip)
        self.execute_serving = execute_serving
        self.serving_slots = serving_slots
        self.serving_max_seq = serving_max_seq
        self.serving_max_new = serving_max_new
        self.pods = [PodState(i, StaticPartitioner(pod),
                              PodSimulator(pod, frozen=frozen_durations))
                     for i in range(n_pods)]
        if execute_serving:
            from repro.serving import SliceRuntime
            if mesh is None:
                from repro.launch.mesh import make_host_mesh
                mesh = make_host_mesh(1, 1)
            for p in self.pods:
                p.runtime = SliceRuntime(pod=pod, mesh=mesh,
                                         partitioner=p.partitioner)
        # migration path: every moved byte crosses the pod's host links once
        n_hosts = max(1, pod.n_chips // self.chip.chips_per_host)
        self._pod_host_bw = n_hosts * self.chip.host_link_bw
        # timeline integrals
        self._now = 0.0
        self._busy_chip_s = 0.0
        self._frag_s = 0.0
        self._energy_J = 0.0
        # counters
        self._repacks = 0
        self._repack_failures = 0
        self._shrinks = 0
        self._migrated_bytes = 0
        self._migration_s = 0.0
        self._power_deferrals = 0
        self._heap: List[tuple] = []
        self._seq = 0
        self.records: Optional[List[JobRecord]] = None

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[Job]) -> Tuple[List[JobRecord], ClusterMetrics]:
        assert self.records is None, "ClusterScheduler instances are single-use"
        records = []
        for job in sorted(jobs, key=lambda j: (j.arrival_s, j.job_id)):
            ideal = ideal_duration(job, self.chip, self.perf)
            rec = JobRecord(job, deadline_s=(
                job.arrival_s + job.slo_factor * ideal
                if ideal is not None else None))
            records.append(rec)
            self._push(job.arrival_s, ARRIVE, rec)
        self.records = records

        queue: List[JobRecord] = []
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if self.horizon_s is not None and t > self.horizon_s:
                break
            self._advance(t)
            if kind == ARRIVE:
                if not self._try_place(payload, t):
                    queue.append(payload)
            else:
                rec, version = payload
                if version != rec.version or rec.finished:
                    continue  # stale event (a re-solve moved the finish)
                self._complete(rec, t)
                self._drain(queue, t)

        end_s = self.horizon_s if self.horizon_s is not None else self._now
        if end_s > self._now:
            self._advance(end_s)
        metrics = summarize(
            self.policy.name, records,
            elapsed_s=end_s,
            total_chips=len(self.pods) * self.pod_spec.n_chips,
            busy_chip_s=self._busy_chip_s,
            frag_time_avg=(self._frag_s / (len(self.pods) * end_s)
                           if end_s > 0 else 0.0),
            energy_J=self._energy_J,
            repacks=self._repacks,
            repack_failures=self._repack_failures,
            shrinks=self._shrinks,
            migrated_bytes=self._migrated_bytes,
            migration_s=self._migration_s,
            power_deferrals=self._power_deferrals,
        )
        return records, metrics

    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    def _advance(self, t: float) -> None:
        dt = t - self._now
        if dt <= 0:
            return
        for pod in self.pods:
            self._energy_J += pod.sim.draw(capped=True) * dt
            self._busy_chip_s += pod.partitioner.used_chips() * dt
            self._frag_s += pod.partitioner.fragmentation_ratio() * dt
            pod.sim.advance(t)
        self._now = t

    def _drain(self, queue: List[JobRecord], t: float) -> None:
        progressed = True
        while progressed:
            progressed = False
            for rec in list(queue):
                if self._try_place(rec, t):
                    queue.remove(rec)
                    progressed = True

    def _is_fixed(self, rec: JobRecord) -> bool:
        """Fixed-duration jobs (pinned or frozen mode) are event-driven and
        never re-projected; only explicit delays move their finish."""
        return self.frozen_durations or rec.job.duration_s is not None

    def _resync(self, pod: PodState, t: float) -> None:
        """Re-project every progress job on the pod after a mix change and
        re-issue the finish events that moved (stale versions are skipped
        by the event loop). No-op in frozen mode."""
        for jid, fin in pod.sim.finish_times(t).items():
            rec = pod.jobs.get(jid)
            if rec is None or rec.finished or fin == rec.finish_s:
                continue
            rec.finish_s = fin
            rec.version += 1
            self._push(fin, FINISH, (rec, rec.version))

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _try_place(self, rec: JobRecord, t: float) -> bool:
        cands = self.policy.candidates(rec.job, self.pods, self.chip, t,
                                       rec.deadline_s, perf=self.perf)
        power_blocked = False
        for cand in cands:
            if self._power_ok(cand, rec):
                self._place(rec, cand, t)
                return True
            power_blocked = True
        if power_blocked:
            # shrinking a victim lowers its dynamic draw with its chip
            # count, so the elastic path can lift the shared cap too
            if self.elastic and self._shrink_and_place(rec, t):
                return True
            if rec.power_deferred == 0:
                self._power_deferrals += 1  # count jobs, not retry attempts
            rec.power_deferred += 1
            return False
        if self.policy.repack_enabled:
            if self._repack_and_place(rec, t):
                return True
        if self.elastic and self._shrink_and_place(rec, t):
            return True
        return False

    def _power_ok(self, cand: Candidate, rec: JobRecord) -> bool:
        return self._power_ok_profile(self.pods[cand.pod_idx], rec,
                                      cand.profile, cand.terms)

    def _power_ok_profile(self, pod: PodState, rec: JobRecord,
                          profile, terms) -> bool:
        if not pod.jobs:
            return True  # a job alone on a pod is always admitted
        new = InstanceLoad(profile.n_chips, self._u_for(rec, terms),
                          terms.step_time, 1)
        return pod.sim.throttle(new) >= self.min_throttle

    def _u_for(self, rec: JobRecord, terms) -> float:
        if rec.job.u_compute is not None:
            return rec.job.u_compute
        step = terms.step_time
        return terms.t_compute / step if step else 0.0

    def _place(self, rec: JobRecord, cand: Candidate, t: float,
               start_delay: float = 0.0) -> None:
        pod = self.pods[cand.pod_idx]
        job = rec.job
        u = self._u_for(rec, cand.terms)
        finish = pod.sim.admit(
            job.job_id, cand.profile.n_chips, u, cand.terms.step_time,
            job.steps, t, duration_s=job.duration_s, start_delay=start_delay)
        rec.pod_idx = pod.idx
        rec.profile_name = cand.profile.name
        rec.origin = cand.origin
        rec.place_s = t
        rec.duration_s = finish - t - start_delay
        rec.finish_s = finish
        rec.u_compute = u
        rec.step_time_s = cand.terms.step_time
        rec.resident_bytes = int(cand.plan.resident_bytes)
        if (job.kind == SERVING and self.execute_serving
                and pod.runtime is not None):
            rec.slice_id = self._start_tenant(rec, pod, cand)
            rec.executed = True
        else:
            alloc = pod.partitioner.allocate(cand.profile, tag=job.tag,
                                             origin=cand.origin)
            rec.slice_id = alloc.slice_id
        pod.jobs[job.job_id] = rec
        pod.slice_jobs[rec.slice_id] = rec
        rec.version += 1
        self._push(rec.finish_s, FINISH, (rec, rec.version))
        if not self.frozen_durations:
            self._resync(pod, t)   # the new tenant slows every co-tenant

    def _complete(self, rec: JobRecord, t: float) -> None:
        pod = self.pods[rec.pod_idx]
        rec.finished = True
        rec.finish_s = t
        pod.jobs.pop(rec.job.job_id)
        pod.slice_jobs.pop(rec.slice_id)
        pod.sim.remove(rec.job.job_id)
        if rec.executed:
            pod.runtime.remove_tenant(rec.job.tag)
        else:
            pod.partitioner.release(rec.slice_id)
        if not self.frozen_durations:
            self._resync(pod, t)   # survivors speed back up

    # ------------------------------------------------------------------
    # repack path (arXiv 2512.16099 stranding fix, priced)
    # ------------------------------------------------------------------
    def _repack_and_place(self, rec: JobRecord, t: float) -> bool:
        for sc in self.perf.options(rec.job):
            for pod in self.pods:
                part = pod.partitioner
                if (part.free_chips() < sc.profile.n_chips
                        or part.origins_for(sc.profile)):
                    continue  # either truly full, or no stranding to fix
                # power gate BEFORE paying for migration: a repack whose
                # beneficiary then fails admission would stretch the moved
                # jobs for nothing
                if not self._power_ok_profile(pod, rec, sc.profile, sc.terms):
                    continue
                try:
                    moved = part.repack()
                except RuntimeError:
                    self._repack_failures += 1
                    continue
                cand = candidate_on(pod, rec.job, sc, t, rec.deadline_s)
                if cand is None:
                    # compaction could not mint an aligned origin after
                    # all; the grid stays valid (and tidier) — charge
                    # nothing, keep looking
                    continue
                self._repacks += 1
                t_mig = self._migration_cost(pod, moved, t)
                self._place(rec, cand, t, start_delay=t_mig)
                return True
        return False

    def _migration_cost(self, pod: PodState, moved: Dict[int, tuple],
                        t: float) -> float:
        """Seconds to migrate the moved slices' resident state across the
        pod's host links; stretches the moved running jobs by the same
        amount (their completion events are re-issued)."""
        moved_bytes = sum(pod.slice_jobs[sid].resident_bytes
                          for sid in moved if sid in pod.slice_jobs)
        victims = [pod.slice_jobs[sid] for sid in moved
                   if sid in pod.slice_jobs
                   and not pod.slice_jobs[sid].finished]
        return self._charge_migration(pod, moved_bytes, victims, t)

    def _charge_migration(self, pod: PodState, moved_bytes: int,
                          victims: Sequence[JobRecord], t: float) -> float:
        """Price ``moved_bytes`` over the pod's host links and stretch the
        given running records by the resulting delay — the single pricing
        path for both repack and elastic-shrink migrations."""
        t_mig = moved_bytes / self._pod_host_bw
        self._migrated_bytes += moved_bytes
        self._migration_s += t_mig
        if t_mig > 0:
            for r in victims:
                pod.sim.delay(r.job.job_id, t_mig)
                if self._is_fixed(r):
                    r.finish_s += t_mig
                    r.version += 1
                    self._push(r.finish_s, FINISH, (r, r.version))
            if not self.frozen_durations:
                self._resync(pod, t)
        return t_mig

    # ------------------------------------------------------------------
    # elastic shrink (online profile re-selection, MISO-style)
    # ------------------------------------------------------------------
    def _shrink_and_place(self, rec: JobRecord, t: float) -> bool:
        """Shrink one running low-priority batch job to a smaller feasible
        profile so a queued deadline job places *now* instead of missing
        its SLO. Priced as a repack-style migration: the victim's resident
        state crosses the pod's host links, its progress is re-based onto
        the smaller slice, and the new job's start is delayed."""
        job = rec.job
        if rec.deadline_s is None:
            return False
        for sc in self.perf.options(job):
            dur = modeled_duration(job, sc)
            if t + dur > rec.deadline_s:
                continue   # placing now would miss anyway; shrink can't help
            for pod in self.pods:
                # a shrink can help two ways: mint an aligned origin on a
                # full pod, or (when an origin already exists and the power
                # gate blocked admission) drop the victim's dynamic draw
                # below the shared cap — _try_shrink_on re-checks both
                if self._try_shrink_on(pod, rec, sc, t):
                    return True
        return False

    def _try_shrink_on(self, pod: PodState, rec: JobRecord, sc: PerfScore,
                       t: float) -> bool:
        victims = sorted((r for r in pod.jobs.values()
                          if r.job.kind == BATCH and not r.executed
                          and not r.finished),
                         key=lambda r: r.job.job_id)
        for victim in victims:
            for small in self.perf.options(victim.job, ignore_pin=True):
                if small.profile.n_chips >= victim.n_chips:
                    continue
                if not self._realloc_victim(pod, victim, small.profile):
                    continue
                if (not pod.partitioner.origins_for(sc.profile)
                        or not self._shrink_power_ok(pod, victim, small,
                                                     rec, sc)):
                    restored = self._realloc_victim(
                        pod, victim, get_profile(victim.profile_name))
                    assert restored, "shrink rollback must always fit"
                    continue
                self._commit_shrink(pod, victim, small, rec, sc, t)
                return True
        return False

    def _realloc_victim(self, pod: PodState, victim: JobRecord,
                        profile) -> bool:
        """Transactionally swap the victim's rectangle for ``profile`` at
        its current origin (power-of-two profile sides make the origin
        aligned for every smaller profile). On failure the allocation
        recorded in ``victim.profile_name`` — which stays at the committed
        profile until ``_commit_shrink`` — is restored, so this one helper
        serves both the shrink attempt and its rollback."""
        part = pod.partitioner
        part.release(victim.slice_id)
        try:
            alloc = part.allocate(profile, tag=victim.job.tag,
                                  origin=victim.origin)
            ok = True
        except RuntimeError:
            alloc = part.allocate(get_profile(victim.profile_name),
                                  tag=victim.job.tag, origin=victim.origin)
            ok = False
        pod.slice_jobs.pop(victim.slice_id)
        victim.slice_id = alloc.slice_id
        pod.slice_jobs[alloc.slice_id] = victim
        return ok

    def _shrink_power_ok(self, pod: PodState, victim: JobRecord,
                         small: PerfScore, rec: JobRecord,
                         sc: PerfScore) -> bool:
        loads = []
        for r in pod.jobs.values():
            if r is victim:
                loads.append(InstanceLoad(small.profile.n_chips,
                                          self._u_for(victim, small.terms),
                                          small.step_time, 1))
            else:
                loads.append(r.load())
        loads.append(InstanceLoad(sc.profile.n_chips,
                                  self._u_for(rec, sc.terms),
                                  sc.step_time, 1))
        return self.perf.throttle(loads, self.pod_spec) >= self.min_throttle

    def _commit_shrink(self, pod: PodState, victim: JobRecord,
                       small: PerfScore, rec: JobRecord, sc: PerfScore,
                       t: float) -> None:
        self._shrinks += 1
        moved_bytes = int(small.plan.resident_bytes)
        victim.profile_name = small.profile.name
        victim.u_compute = self._u_for(victim, small.terms)
        victim.step_time_s = small.step_time
        victim.resident_bytes = moved_bytes
        victim.shrunk = True
        pod.sim.resize(victim.job.job_id, small.profile.n_chips,
                       victim.u_compute, small.step_time)
        t_mig = self._charge_migration(pod, moved_bytes, [victim], t)
        if self.frozen_durations and victim.job.duration_s is None:
            # frozen durations never self-re-project, but a resize re-bases
            # the remaining frozen wall time — re-issue the finish event
            fin = pod.sim.projected_finish(victim.job.job_id, t)
            if fin != victim.finish_s:
                victim.finish_s = fin
                victim.version += 1
                self._push(fin, FINISH, (victim, victim.version))
        cand = candidate_on(pod, rec.job, sc, t, rec.deadline_s)
        assert cand is not None, "origins_for was just checked"
        self._place(rec, cand, t, start_delay=t_mig)

    # ------------------------------------------------------------------
    # live serving execution
    # ------------------------------------------------------------------
    def _start_tenant(self, rec: JobRecord, pod: PodState,
                      cand: Candidate) -> int:
        """Admit the serving job as a real SliceRuntime tenant (reduced-scale
        config on the host backend, same profile and origin the scheduler
        chose) and drain its requests through the live engine."""
        from repro.configs import get_config
        from repro.serving import Request, TenantSpec
        job = rec.job
        cfg = get_config(job.arch).reduced().with_(remat="none")
        tenant = pod.runtime.add_tenant(TenantSpec(
            name=job.tag, cfg=cfg, profile=cand.profile,
            origin=cand.origin, slots=self.serving_slots,
            max_seq=self.serving_max_seq, seed=job.job_id))
        if job.requests:
            rng = np.random.default_rng(1000 + job.job_id)
            reqs = [Request(i, rng.integers(
                        0, cfg.vocab_size,
                        size=int(rng.integers(4, 9))).astype(np.int32),
                        self.serving_max_new)
                    for i in range(job.requests)]
            pod.runtime.submit(job.tag, reqs)
            while not tenant.engine.idle:
                tenant.engine.tick()
            rec.tokens_out = tenant.engine.stats.tokens_out
        return tenant.alloc.slice_id
