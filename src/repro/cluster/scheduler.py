"""ClusterScheduler — event-loop scheduling of a job trace onto N pods.

Each pod is a ``StaticPartitioner`` grid plus a ``core.perfmodel.
PodSimulator`` (and optionally a live ``SliceRuntime`` so serving jobs
execute on the real engine). The loop is discrete-event in virtual seconds:
arrivals and completions are the events, placements happen greedily at each
event via a ``PlacementPolicy``, and the scheduler integrates energy / busy
chips / fragmentation over the timeline between events.

All performance and power questions go through the shared ``PerfModel`` /
``PodSimulator`` pair — no roofline or power-model glue lives here. Beyond
plain packing, the two interference surfaces static partitioning does NOT
remove (paper §V) are modeled:

* **Power** — a candidate placement is rejected when the pod simulator's
  predicted throttle with the new instance falls below ``min_throttle``
  (the §V-B shared-cap effect); the job waits instead of dragging every
  co-tenant below the cap. Jobs that *are* admitted re-solve the whole
  pod: every admission, completion, repack delay, or elastic resize
  re-projects the remaining finish time of every running job under the new
  mix — a later compute-heavy arrival retroactively stretches an in-flight
  job, exactly the §V-B interference account.
* **Fragmentation** — when a queued job fits a pod's total free chips but
  no aligned rectangle (arXiv 2512.16099 stranding), a repack-enabled
  policy triggers the partitioner's transactional ``repack()`` and pays a
  modeled migration cost: the moved slices' resident state crosses the
  pod's host links (``core.hw`` PCIe-class bandwidth), delaying the new
  job's start and stretching the moved jobs' completions.

**Elastic shrink** (``elastic=True``): when a queued deadline job would
otherwise miss its SLO, the scheduler may shrink a running low-priority
batch job to a smaller feasible profile — priced exactly like a repack
migration (the victim's resident state crosses the host links, its progress
is re-based onto the smaller slice's step time) — freeing an aligned
rectangle for the deadline job.

**Priority preemption** (``priorities=True``): when neither a free origin
nor a shrink can place a deadline job, the scheduler may checkpoint-evict
a strictly lower-priority running *batch* job (MISO, arXiv 2207.11428:
dynamic re-slicing around priorities). The suspend is priced as the
``train/checkpoint.py`` save volume — the victim's resident bytes host-
gathered over the pod's host links (``PerfModel.checkpoint_cost``; no
power/roofline glue lives here) — and delays the beneficiary's start; the
victim's progress is snapshotted (``work_done`` in nominal seconds), the
job re-queues, and a later placement resumes it from the checkpoint,
paying the restore volume. Shrink and preempt compete through
``placement.cheapest_rescue`` — the preempt-vs-shrink-vs-queue comparator
picks the cheapest SLO-preserving action.

**Elastic grow** (``grow=True``): the symmetric move to shrink — after a
completion frees chips (and the queue has drained), a running progress job
may absorb free neighbouring chips via the partitioner's transactional
``extend()`` primitive, priced as the same host-link migration as a
shrink; ``PodSimulator.resize`` re-bases its remaining work onto the
faster step time and re-solves the pod throttle, so the grown job's
projected finish improves in ``finish_times``. Grows are power-gated like
admissions.

``frozen_durations=True`` is the compatibility mode: durations are fixed at
admission time with the legacy float arithmetic and never re-solved,
reproducing the PR 2 scheduler's numbers bit-for-bit. Crafted jobs with
pinned ``duration_s`` skip throttle modeling in both modes so tests stay
exactly deterministic.

Units, everywhere in this module: virtual time and durations in seconds
(nominal = unthrottled work seconds; wall = after throttle stretch and
delays), state volumes in bytes, slice sizes in chips.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.hw import PodSpec, V5E_POD
from repro.core.partitioner import StaticPartitioner
from repro.core.perfmodel import (InstanceLoad, PerfModel, PerfScore,
                                  PodSimulator, get_model)
from repro.core.slices import get_profile

from repro.cluster.metrics import ClusterMetrics, summarize
from repro.cluster.placement import (Candidate, PlacementPolicy,
                                     RescueOption, candidate_on,
                                     cheapest_rescue, get_policy,
                                     ideal_duration, modeled_duration)
from repro.cluster.trace import BATCH, SERVING, Job

ARRIVE = "arrive"
FINISH = "finish"


@dataclass(frozen=True)
class SuspendSnapshot:
    """Progress frozen at checkpoint-eviction time, restored at resume.

    ``work_done``/``work_total`` are nominal (unthrottled) seconds for
    progress jobs; ``fixed_remaining`` is remaining wall seconds for
    pinned/frozen jobs (``pinned`` tells which); ``step_time`` is the
    evicted slice's nominal seconds per step (re-bases a frozen remainder
    onto a different resume profile); ``bytes`` is the checkpoint volume
    written at save time — the restore pays the same bytes back;
    ``delay_remaining`` is unburned wall delay (seconds) from an earlier
    charged migration, still owed after the resume."""
    work_done: float
    work_total: float
    fixed_remaining: Optional[float]
    pinned: bool
    step_time: float
    bytes: int
    delay_remaining: float = 0.0


@dataclass
class JobRecord:
    """Mutable scheduling state of one trace job.

    Units: ``*_s`` fields are virtual seconds, ``resident_bytes`` /
    ``checkpoint_bytes`` are bytes, profiles imply chips. ``place_s`` is
    the *first* placement (queue delay = ``place_s − arrival_s``; a
    checkpoint resume keeps it), ``duration_s`` is the most recent
    admission's modeled remaining duration."""
    job: Job
    deadline_s: Optional[float] = None
    pod_idx: Optional[int] = None
    slice_id: Optional[int] = None
    profile_name: Optional[str] = None
    origin: Optional[Tuple[int, int]] = None
    place_s: Optional[float] = None
    finish_s: Optional[float] = None
    duration_s: Optional[float] = None
    u_compute: float = 0.0
    step_time_s: float = 0.0
    resident_bytes: int = 0
    finished: bool = False
    executed: bool = False        # ran on a live SliceRuntime tenant
    shrunk: bool = False          # resized to a smaller profile mid-flight
    grown: bool = False           # absorbed freed chips via extend()
    tokens_out: int = 0
    power_deferred: int = 0
    version: int = 0              # bumps invalidate stale finish events
    # checkpoint preemption bookkeeping
    preemptions: int = 0          # times checkpoint-evicted
    resumes: int = 0              # times resumed from a checkpoint
    suspend_s: Optional[float] = None   # last eviction time
    resume_s: Optional[float] = None    # last resume time
    checkpoint_bytes: int = 0     # total save+restore volume paid (bytes)
    checkpoint_delay_s: float = 0.0     # total save+restore seconds paid
    suspended: Optional[SuspendSnapshot] = None  # set while evicted

    @property
    def placed(self) -> bool:
        return self.place_s is not None

    @property
    def n_chips(self) -> int:
        return get_profile(self.profile_name).n_chips if self.profile_name else 0

    def load(self) -> InstanceLoad:
        return InstanceLoad(self.n_chips, self.u_compute, self.step_time_s, 1)


@dataclass
class PodState:
    idx: int
    partitioner: StaticPartitioner
    sim: PodSimulator
    runtime: Optional[object] = None   # serving.SliceRuntime when executing
    jobs: Dict[int, JobRecord] = field(default_factory=dict)       # by job_id
    slice_jobs: Dict[int, JobRecord] = field(default_factory=dict)  # by slice


class ClusterScheduler:
    """Discrete-event scheduler for a job trace over ``n_pods`` pods.

    Feature flags (all default off → PR 2/3-compatible behaviour):
    ``elastic`` enables shrink rescues, ``priorities`` enables checkpoint
    preemption, ``grow`` enables rectangle extension of running jobs,
    ``frozen_durations`` pins the legacy fixed-at-admission arithmetic.

    Units: event times and all ``*_s`` quantities are virtual seconds,
    migrated/checkpointed volumes are bytes priced over the pod's
    aggregate host-link bandwidth (bytes/s), slice sizes are chips.
    Instances are single-use: one ``run()`` per scheduler."""

    def __init__(self, n_pods: int = 2,
                 policy: Union[str, PlacementPolicy] = "frag_repack",
                 pod: PodSpec = V5E_POD, *,
                 min_throttle: float = 0.8,
                 horizon_s: Optional[float] = None,
                 frozen_durations: bool = False,
                 elastic: bool = False,
                 priorities: bool = False,
                 grow: bool = False,
                 perf: Optional[PerfModel] = None,
                 execute_serving: bool = False,
                 mesh=None,
                 serving_slots: int = 2,
                 serving_max_seq: int = 32,
                 serving_max_new: int = 4):
        self.pod_spec = pod
        self.chip = pod.chip
        self.policy = get_policy(policy) if isinstance(policy, str) else policy
        self.min_throttle = min_throttle
        self.horizon_s = horizon_s
        self.frozen_durations = frozen_durations
        self.elastic = elastic
        self.priorities = priorities
        self.grow = grow
        self.perf = perf if perf is not None else get_model(pod.chip)
        self.execute_serving = execute_serving
        self.serving_slots = serving_slots
        self.serving_max_seq = serving_max_seq
        self.serving_max_new = serving_max_new
        self.pods = [PodState(i, StaticPartitioner(pod),
                              PodSimulator(pod, frozen=frozen_durations))
                     for i in range(n_pods)]
        if execute_serving:
            from repro.serving import SliceRuntime
            if mesh is None:
                from repro.launch.mesh import make_host_mesh
                mesh = make_host_mesh(1, 1)
            for p in self.pods:
                p.runtime = SliceRuntime(pod=pod, mesh=mesh,
                                         partitioner=p.partitioner)
        # migration path: every moved byte crosses the pod's host links once
        n_hosts = max(1, pod.n_chips // self.chip.chips_per_host)
        self._pod_host_bw = n_hosts * self.chip.host_link_bw
        # timeline integrals
        self._now = 0.0
        self._busy_chip_s = 0.0
        self._frag_s = 0.0
        self._energy_J = 0.0
        # counters
        self._repacks = 0
        self._repack_failures = 0
        self._shrinks = 0
        self._grows = 0
        self._preemptions = 0
        self._resumes = 0
        self._wasted_checkpoint_chip_s = 0.0
        self._migrated_bytes = 0
        self._migration_s = 0.0
        self._power_deferrals = 0
        self._heap: List[tuple] = []
        self._seq = 0
        self._queue: List[JobRecord] = []
        self.records: Optional[List[JobRecord]] = None

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[Job]) -> Tuple[List[JobRecord], ClusterMetrics]:
        """Schedule ``jobs`` to completion (or ``horizon_s`` virtual
        seconds) and return (per-job records, aggregate metrics). Each
        record's deadline is ``arrival + slo_factor × ideal`` seconds,
        where ideal is the job's fastest unthrottled feasible duration."""
        assert self.records is None, "ClusterScheduler instances are single-use"
        records = []
        for job in sorted(jobs, key=lambda j: (j.arrival_s, j.job_id)):
            ideal = ideal_duration(job, self.chip, self.perf)
            rec = JobRecord(job, deadline_s=(
                job.arrival_s + job.slo_factor * ideal
                if ideal is not None else None))
            records.append(rec)
            self._push(job.arrival_s, ARRIVE, rec)
        self.records = records

        queue = self._queue
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if self.horizon_s is not None and t > self.horizon_s:
                break
            self._advance(t)
            if kind == ARRIVE:
                if not self._try_place(payload, t):
                    queue.append(payload)
            else:
                rec, version = payload
                if version != rec.version or rec.finished:
                    continue  # stale event (a re-solve moved the finish)
                pod = self.pods[rec.pod_idx]
                self._complete(rec, t)
                self._drain(queue, t)
                if self.grow:
                    # queued jobs had first claim on the freed chips; a
                    # running neighbour may absorb what is still free
                    self._grow_into_free(pod, t)

        end_s = self.horizon_s if self.horizon_s is not None else self._now
        if end_s > self._now:
            self._advance(end_s)
        metrics = summarize(
            self.policy.name, records,
            elapsed_s=end_s,
            total_chips=len(self.pods) * self.pod_spec.n_chips,
            busy_chip_s=self._busy_chip_s,
            frag_time_avg=(self._frag_s / (len(self.pods) * end_s)
                           if end_s > 0 else 0.0),
            energy_J=self._energy_J,
            repacks=self._repacks,
            repack_failures=self._repack_failures,
            shrinks=self._shrinks,
            grows=self._grows,
            preemptions=self._preemptions,
            resumes=self._resumes,
            wasted_checkpoint_chip_s=self._wasted_checkpoint_chip_s,
            migrated_bytes=self._migrated_bytes,
            migration_s=self._migration_s,
            power_deferrals=self._power_deferrals,
        )
        return records, metrics

    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    def _advance(self, t: float) -> None:
        dt = t - self._now
        if dt <= 0:
            return
        for pod in self.pods:
            self._energy_J += pod.sim.draw(capped=True) * dt
            self._busy_chip_s += pod.partitioner.used_chips() * dt
            self._frag_s += pod.partitioner.fragmentation_ratio() * dt
            pod.sim.advance(t)
        self._now = t

    def _drain(self, queue: List[JobRecord], t: float) -> None:
        """Place every queued job that now fits; sweeps repeat until a
        full pass places nothing. A placement may mutate the queue
        underneath the sweep snapshot (a rescue suspends a victim into
        it, or resumes one out of it), so membership is re-checked by
        identity before each attempt — placing a record twice would
        double-admit it."""
        progressed = True
        while progressed:
            progressed = False
            for rec in list(queue):
                if not any(q is rec for q in queue):
                    continue   # resumed by a nested rescue this sweep
                if self._try_place(rec, t):
                    self._unqueue(rec)
                    progressed = True

    def _unqueue(self, rec: JobRecord) -> None:
        """Remove ``rec`` from the queue by identity (JobRecord equality
        is field-wise, which could alias distinct records)."""
        for i, q in enumerate(self._queue):
            if q is rec:
                del self._queue[i]
                return

    def _is_fixed(self, rec: JobRecord) -> bool:
        """Fixed-duration jobs (pinned or frozen mode) are event-driven and
        never re-projected; only explicit delays move their finish."""
        return self.frozen_durations or rec.job.duration_s is not None

    def _resync(self, pod: PodState, t: float) -> None:
        """Re-project every progress job on the pod after a mix change and
        re-issue the finish events that moved (stale versions are skipped
        by the event loop). No-op in frozen mode."""
        for jid, fin in pod.sim.finish_times(t).items():
            rec = pod.jobs.get(jid)
            if rec is None or rec.finished or fin == rec.finish_s:
                continue
            rec.finish_s = fin
            rec.version += 1
            self._push(fin, FINISH, (rec, rec.version))

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _try_place(self, rec: JobRecord, t: float) -> bool:
        """Place ``rec`` now if any path allows it: a free aligned origin,
        a repack, or a rescue action (shrink / preempt) chosen by the
        ``cheapest_rescue`` comparator. Returns False → the job queues."""
        cands = self.policy.candidates(rec.job, self.pods, self.chip, t,
                                       rec.deadline_s, perf=self.perf)
        power_blocked = False
        for cand in cands:
            if self._power_ok(cand, rec):
                self._place(rec, cand, t)
                return True
            power_blocked = True
        if power_blocked:
            # shrinking (or evicting) a victim lowers its dynamic draw
            # with its chip count, so a rescue can lift the shared cap too
            if self._rescue_and_place(rec, t):
                return True
            if rec.power_deferred == 0:
                self._power_deferrals += 1  # count jobs, not retry attempts
            rec.power_deferred += 1
            return False
        if self.policy.repack_enabled:
            if self._repack_and_place(rec, t):
                return True
        return self._rescue_and_place(rec, t)

    def _power_ok(self, cand: Candidate, rec: JobRecord) -> bool:
        return self._power_ok_profile(self.pods[cand.pod_idx], rec,
                                      cand.profile, cand.terms)

    def _power_ok_profile(self, pod: PodState, rec: JobRecord,
                          profile, terms) -> bool:
        if not pod.jobs:
            return True  # a job alone on a pod is always admitted
        new = InstanceLoad(profile.n_chips, self._u_for(rec, terms),
                          terms.step_time, 1)
        return pod.sim.throttle(new) >= self.min_throttle

    def _u_for(self, rec: JobRecord, terms) -> float:
        if rec.job.u_compute is not None:
            return rec.job.u_compute
        step = terms.step_time
        return terms.t_compute / step if step else 0.0

    def _place(self, rec: JobRecord, cand: Candidate, t: float,
               start_delay: float = 0.0) -> None:
        """Admit ``rec`` on ``cand``'s pod/profile/origin at time ``t``
        (virtual seconds), optionally after ``start_delay`` wall seconds
        of migration or checkpoint traffic. A suspended record (evicted
        earlier) is *resumed*: its snapshotted progress carries over and
        the checkpoint restore volume is paid before work continues."""
        pod = self.pods[cand.pod_idx]
        job = rec.job
        u = self._u_for(rec, cand.terms)
        duration = job.duration_s
        admit_kw = {}
        if rec.suspended is not None:
            snap = rec.suspended
            restore_s = self.perf.checkpoint_cost(
                snap.bytes, self._pod_host_bw).restore_s
            # restore traffic, plus any migration delay still owed from
            # before the eviction — suspension never forgives a debt
            start_delay += restore_s + snap.delay_remaining
            self._resumes += 1
            self._wasted_checkpoint_chip_s += (cand.profile.n_chips
                                               * restore_s)
            rec.resumes += 1
            rec.resume_s = t
            rec.checkpoint_bytes += snap.bytes
            rec.checkpoint_delay_s += restore_s
            if snap.fixed_remaining is not None and snap.pinned:
                duration = snap.fixed_remaining   # wall-clock contract
            elif snap.fixed_remaining is not None:
                # frozen remainder re-based onto the resume profile
                admit_kw["fixed_remaining"] = (
                    snap.fixed_remaining
                    * cand.terms.step_time / snap.step_time)
            else:
                frac = (snap.work_done / snap.work_total
                        if snap.work_total else 0.0)
                admit_kw["work_done"] = frac * (job.steps
                                                * cand.terms.step_time)
            rec.suspended = None
        finish = pod.sim.admit(
            job.job_id, cand.profile.n_chips, u, cand.terms.step_time,
            job.steps, t, duration_s=duration, start_delay=start_delay,
            **admit_kw)
        rec.pod_idx = pod.idx
        rec.profile_name = cand.profile.name
        rec.origin = cand.origin
        if rec.place_s is None:
            rec.place_s = t   # queue delay measures the FIRST placement
        rec.duration_s = finish - t - start_delay
        rec.finish_s = finish
        rec.u_compute = u
        rec.step_time_s = cand.terms.step_time
        rec.resident_bytes = int(cand.plan.resident_bytes)
        if (job.kind == SERVING and self.execute_serving
                and pod.runtime is not None):
            rec.slice_id = self._start_tenant(rec, pod, cand)
            rec.executed = True
        else:
            alloc = pod.partitioner.allocate(cand.profile, tag=job.tag,
                                             origin=cand.origin)
            rec.slice_id = alloc.slice_id
        pod.jobs[job.job_id] = rec
        pod.slice_jobs[rec.slice_id] = rec
        rec.version += 1
        self._push(rec.finish_s, FINISH, (rec, rec.version))
        if not self.frozen_durations:
            self._resync(pod, t)   # the new tenant slows every co-tenant

    def _complete(self, rec: JobRecord, t: float) -> None:
        pod = self.pods[rec.pod_idx]
        rec.finished = True
        rec.finish_s = t
        pod.jobs.pop(rec.job.job_id)
        pod.slice_jobs.pop(rec.slice_id)
        pod.sim.remove(rec.job.job_id)
        if rec.executed:
            pod.runtime.remove_tenant(rec.job.tag)
        else:
            pod.partitioner.release(rec.slice_id)
        if not self.frozen_durations:
            self._resync(pod, t)   # survivors speed back up

    # ------------------------------------------------------------------
    # repack path (arXiv 2512.16099 stranding fix, priced)
    # ------------------------------------------------------------------
    def _repack_and_place(self, rec: JobRecord, t: float) -> bool:
        for sc in self.perf.options(rec.job):
            for pod in self.pods:
                part = pod.partitioner
                if (part.free_chips() < sc.profile.n_chips
                        or part.origins_for(sc.profile)):
                    continue  # either truly full, or no stranding to fix
                # power gate BEFORE paying for migration: a repack whose
                # beneficiary then fails admission would stretch the moved
                # jobs for nothing
                if not self._power_ok_profile(pod, rec, sc.profile, sc.terms):
                    continue
                try:
                    moved = part.repack()
                except RuntimeError:
                    self._repack_failures += 1
                    continue
                for sid, origin in moved.items():
                    # keep records truthful: a later shrink/preempt
                    # re-allocates at the record's origin, so a stale one
                    # would rebuild the victim on the wrong rectangle
                    if sid in pod.slice_jobs:
                        pod.slice_jobs[sid].origin = origin
                cand = candidate_on(pod, rec.job, sc, t, rec.deadline_s)
                if cand is None:
                    # compaction could not mint an aligned origin after
                    # all; the grid stays valid (and tidier) — charge
                    # nothing, keep looking
                    continue
                self._repacks += 1
                t_mig = self._migration_cost(pod, moved, t)
                self._place(rec, cand, t, start_delay=t_mig)
                return True
        return False

    def _migration_cost(self, pod: PodState, moved: Dict[int, tuple],
                        t: float) -> float:
        """Seconds to migrate the moved slices' resident state across the
        pod's host links; stretches the moved running jobs by the same
        amount (their completion events are re-issued)."""
        moved_bytes = sum(pod.slice_jobs[sid].resident_bytes
                          for sid in moved if sid in pod.slice_jobs)
        victims = [pod.slice_jobs[sid] for sid in moved
                   if sid in pod.slice_jobs
                   and not pod.slice_jobs[sid].finished]
        return self._charge_migration(pod, moved_bytes, victims, t)

    def _charge_migration(self, pod: PodState, moved_bytes: int,
                          victims: Sequence[JobRecord], t: float) -> float:
        """Price ``moved_bytes`` over the pod's host links and stretch the
        given running records by the resulting delay — the single pricing
        path for both repack and elastic-shrink migrations."""
        t_mig = moved_bytes / self._pod_host_bw
        self._migrated_bytes += moved_bytes
        self._migration_s += t_mig
        if t_mig > 0:
            for r in victims:
                pod.sim.delay(r.job.job_id, t_mig)
                if self._is_fixed(r):
                    r.finish_s += t_mig
                    r.version += 1
                    self._push(r.finish_s, FINISH, (r, r.version))
            if not self.frozen_durations:
                self._resync(pod, t)
        return t_mig

    # ------------------------------------------------------------------
    # rescue actions: shrink (MISO online re-selection) vs checkpoint
    # preemption, arbitrated by placement.cheapest_rescue
    # ------------------------------------------------------------------
    def _rescue_and_place(self, rec: JobRecord, t: float) -> bool:
        """Probe every enabled rescue action for the blocked deadline job
        ``rec``, hand the priced options to the preempt-vs-shrink-vs-queue
        comparator, and commit the winner. Probes only inspect (all grid
        trials roll back); the chosen option's ``commit`` closure applies
        it. Returns False → queue (no SLO-preserving action exists)."""
        options: List[RescueOption] = []
        if self.elastic:
            opt = self._probe_shrink(rec, t)
            if opt is not None:
                options.append(opt)
        if self.priorities:
            opt = self._probe_preempt(rec, t)
            if opt is not None:
                options.append(opt)
        choice = cheapest_rescue(options)
        if choice is None:
            return False
        choice.commit()
        if choice.kind == "preempt":
            # the evicted victim may fit *right now* — a smaller profile,
            # another pod — instead of idling until the next completion
            # event drains the queue
            for r in [q for q in self._queue if q.suspended is not None]:
                if self._try_place(r, t):
                    self._unqueue(r)
        return True

    def _slo_profiles(self, rec: JobRecord, t: float):
        """PerfScores (smallest profile first) whose unthrottled modeled
        duration still meets ``rec``'s deadline when started at ``t`` —
        the only placements a rescue action is allowed to buy. Each probe
        must still re-check with its own start delay (``_meets_after``)."""
        if rec.deadline_s is None:
            return
        for sc in self.perf.options(rec.job):
            if t + modeled_duration(rec.job, sc) <= rec.deadline_s:
                yield sc

    def _meets_after(self, rec: JobRecord, t: float, sc: PerfScore,
                     delay_s: float) -> bool:
        """Does ``rec`` still meet its deadline when its start is pushed
        back ``delay_s`` seconds by the rescue's own migration/checkpoint
        traffic? Without this, a rescue could suspend or shrink a victim
        and *still* deliver an SLO miss."""
        return (t + delay_s + modeled_duration(rec.job, sc)
                <= rec.deadline_s)

    # -- elastic shrink -------------------------------------------------
    def _probe_shrink(self, rec: JobRecord, t: float
                      ) -> Optional[RescueOption]:
        """First feasible shrink (victim to a smaller profile so ``rec``
        places now), priced as the victim's post-shrink resident bytes
        over the pod's host links. A shrink can help two ways: mint an
        aligned origin on a full pod, or (when the power gate blocked
        admission) drop the victim's dynamic draw below the shared cap."""
        for sc in self._slo_profiles(rec, t):
            for pod in self.pods:
                found = self._probe_shrink_on(pod, rec, sc, t)
                if found is None:
                    continue
                victim, small = found
                cost_s = int(small.plan.resident_bytes) / self._pod_host_bw
                return RescueOption(
                    kind="shrink", cost_s=cost_s,
                    victim_id=victim.job.job_id,
                    commit=lambda pod=pod, victim=victim, small=small,
                    sc=sc: self._do_shrink(pod, victim, small, rec, sc, t))
        return None

    def _probe_shrink_on(self, pod: PodState, rec: JobRecord, sc: PerfScore,
                         t: float) -> Optional[Tuple[JobRecord, PerfScore]]:
        """Trial-only: find (victim, smaller profile) on ``pod`` that
        frees an origin for ``sc.profile`` under the power gate, whose
        migration delay still lets ``rec`` meet its deadline (checked per
        candidate — one over-heavy victim must not mask a feasible one).
        The grid is restored before returning, found or not."""
        for victim in self._shrink_victims(pod, rec):
            for small in self.perf.options(victim.job, ignore_pin=True):
                if small.profile.n_chips >= victim.n_chips:
                    continue
                mig_s = int(small.plan.resident_bytes) / self._pod_host_bw
                if not self._meets_after(rec, t, sc, mig_s):
                    continue   # this migration would itself blow the SLO
                if not self._realloc_victim(pod, victim, small.profile):
                    continue
                ok = (bool(pod.partitioner.origins_for(sc.profile))
                      and self._shrink_power_ok(pod, victim, small, rec, sc))
                restored = self._realloc_victim(
                    pod, victim, get_profile(victim.profile_name))
                assert restored, "shrink rollback must always fit"
                if ok:
                    return victim, small
        return None

    def _shrink_victims(self, pod: PodState, rec: JobRecord
                        ) -> List[JobRecord]:
        """Running non-executed batch jobs, cheapest first: least resident
        state (the migration cost proxy), then job id for determinism."""
        return sorted((r for r in pod.jobs.values()
                       if r.job.kind == BATCH and not r.executed
                       and not r.finished),
                      key=lambda r: (r.resident_bytes, r.job.job_id))

    def _do_shrink(self, pod: PodState, victim: JobRecord, small: PerfScore,
                   rec: JobRecord, sc: PerfScore, t: float) -> None:
        applied = self._realloc_victim(pod, victim, small.profile)
        assert applied, "probed shrink must re-apply"
        self._commit_shrink(pod, victim, small, rec, sc, t)

    def _realloc_victim(self, pod: PodState, victim: JobRecord,
                        profile) -> bool:
        """Transactionally swap the victim's rectangle for ``profile`` at
        its current origin (power-of-two profile sides make the origin
        aligned for every smaller profile). On failure the allocation
        recorded in ``victim.profile_name`` — which stays at the committed
        profile until ``_commit_shrink`` — is restored, so this one helper
        serves both the shrink attempt and its rollback."""
        part = pod.partitioner
        part.release(victim.slice_id)
        try:
            alloc = part.allocate(profile, tag=victim.job.tag,
                                  origin=victim.origin)
            ok = True
        except RuntimeError:
            alloc = part.allocate(get_profile(victim.profile_name),
                                  tag=victim.job.tag, origin=victim.origin)
            ok = False
        pod.slice_jobs.pop(victim.slice_id)
        victim.slice_id = alloc.slice_id
        pod.slice_jobs[alloc.slice_id] = victim
        return ok

    def _shrink_power_ok(self, pod: PodState, victim: JobRecord,
                         small: PerfScore, rec: JobRecord,
                         sc: PerfScore) -> bool:
        loads = []
        for r in pod.jobs.values():
            if r is victim:
                loads.append(InstanceLoad(small.profile.n_chips,
                                          self._u_for(victim, small.terms),
                                          small.step_time, 1))
            else:
                loads.append(r.load())
        loads.append(InstanceLoad(sc.profile.n_chips,
                                  self._u_for(rec, sc.terms),
                                  sc.step_time, 1))
        return self.perf.throttle(loads, self.pod_spec) >= self.min_throttle

    def _commit_shrink(self, pod: PodState, victim: JobRecord,
                       small: PerfScore, rec: JobRecord, sc: PerfScore,
                       t: float) -> None:
        self._shrinks += 1
        moved_bytes = int(small.plan.resident_bytes)
        victim.profile_name = small.profile.name
        victim.u_compute = self._u_for(victim, small.terms)
        victim.step_time_s = small.step_time
        victim.resident_bytes = moved_bytes
        victim.shrunk = True
        pod.sim.resize(victim.job.job_id, small.profile.n_chips,
                       victim.u_compute, small.step_time)
        t_mig = self._charge_migration(pod, moved_bytes, [victim], t)
        self._reissue_after_resize(pod, victim, t)
        cand = candidate_on(pod, rec.job, sc, t, rec.deadline_s)
        assert cand is not None, "origins_for was just checked"
        self._place(rec, cand, t, start_delay=t_mig)

    def _reissue_after_resize(self, pod: PodState, rec: JobRecord,
                              t: float) -> None:
        """Frozen durations never self-re-project, but a resize re-bases
        the remaining frozen wall time — re-issue the finish event."""
        if not (self.frozen_durations and rec.job.duration_s is None):
            return
        fin = pod.sim.projected_finish(rec.job.job_id, t)
        if fin != rec.finish_s:
            rec.finish_s = fin
            rec.version += 1
            self._push(fin, FINISH, (rec, rec.version))

    # ------------------------------------------------------------------
    # checkpoint preemption (priority eviction, priced via checkpoint.py
    # save/restore volumes through PerfModel.checkpoint_cost)
    # ------------------------------------------------------------------
    def _probe_preempt(self, rec: JobRecord, t: float
                       ) -> Optional[RescueOption]:
        """First feasible checkpoint-eviction: a strictly lower-priority
        running batch job whose rectangle (once freed) admits ``rec``
        under the power gate. Priced as save + restore checkpoint volume
        (the victim's resident bytes, twice) over the pod's host links."""
        for sc in self._slo_profiles(rec, t):
            for pod in self.pods:
                victim = self._probe_preempt_on(pod, rec, sc, t)
                if victim is None:
                    continue
                cost = self.perf.checkpoint_cost(victim.resident_bytes,
                                                 self._pod_host_bw)
                return RescueOption(
                    kind="preempt", cost_s=cost.total_s,
                    victim_id=victim.job.job_id,
                    commit=lambda pod=pod, victim=victim, sc=sc:
                    self._do_preempt(pod, victim, rec, sc, t))
        return None

    def _preempt_victims(self, pod: PodState, rec: JobRecord
                         ) -> List[JobRecord]:
        """Evictable jobs: running non-executed *batch* jobs of strictly
        lower priority. Scanned lowest priority class first, then least
        resident state (the checkpoint-volume cost), then job id — so the
        first feasible victim is also the cheapest eligible one."""
        return sorted((r for r in pod.jobs.values()
                       if r.job.kind == BATCH and not r.executed
                       and not r.finished
                       and r.job.priority < rec.job.priority),
                      key=lambda r: (r.job.priority, r.resident_bytes,
                                     r.job.job_id))

    def _probe_preempt_on(self, pod: PodState, rec: JobRecord,
                          sc: PerfScore, t: float) -> Optional[JobRecord]:
        """Trial-only: find a victim whose eviction mints an origin for
        ``sc.profile``, passes the power gate, and whose checkpoint save
        drain still lets ``rec`` meet its deadline (checked per victim —
        a huge-resident victim must not mask a feasible small one). The
        victim's rectangle is released and re-allocated in place — grid
        state is unchanged on return (only its internal slice id
        advances)."""
        part = pod.partitioner
        for victim in self._preempt_victims(pod, rec):
            save_s = self.perf.checkpoint_cost(victim.resident_bytes,
                                               self._pod_host_bw).save_s
            if not self._meets_after(rec, t, sc, save_s):
                continue   # this victim's save drain would blow the SLO
            profile = get_profile(victim.profile_name)
            origin = victim.origin
            part.release(victim.slice_id)
            ok = (bool(part.origins_for(sc.profile))
                  and self._preempt_power_ok(pod, victim, rec, sc))
            alloc = part.allocate(profile, tag=victim.job.tag, origin=origin)
            pod.slice_jobs.pop(victim.slice_id)
            victim.slice_id = alloc.slice_id
            pod.slice_jobs[alloc.slice_id] = victim
            if ok:
                return victim
        return None

    def _preempt_power_ok(self, pod: PodState, victim: JobRecord,
                          rec: JobRecord, sc: PerfScore) -> bool:
        loads = [r.load() for r in pod.jobs.values() if r is not victim]
        loads.append(InstanceLoad(sc.profile.n_chips,
                                  self._u_for(rec, sc.terms),
                                  sc.step_time, 1))
        return self.perf.throttle(loads, self.pod_spec) >= self.min_throttle

    def _do_preempt(self, pod: PodState, victim: JobRecord, rec: JobRecord,
                    sc: PerfScore, t: float) -> None:
        """Checkpoint-evict ``victim`` and place ``rec`` in its rectangle.

        The save volume (victim's resident bytes — what ``checkpoint.save``
        host-gathers) crosses the pod's host links before the rectangle is
        usable, so the beneficiary starts after ``save_s``; the victim's
        chips do no work while draining (wasted checkpoint chip-seconds).
        Progress survives in the ``SuspendSnapshot`` (``work_done`` nominal
        seconds) and the job re-queues for a later resume."""
        self._preemptions += 1
        cost = self.perf.checkpoint_cost(victim.resident_bytes,
                                         self._pod_host_bw)
        self._wasted_checkpoint_chip_s += victim.n_chips * cost.save_s
        sim = pod.sim.remove(victim.job.job_id)
        victim.suspended = SuspendSnapshot(
            work_done=sim.work_done, work_total=sim.work_total,
            fixed_remaining=sim.fixed_s, pinned=sim.pinned,
            step_time=sim.step_time, bytes=cost.bytes,
            delay_remaining=sim.delay_s)
        victim.preemptions += 1
        victim.suspend_s = t
        victim.checkpoint_bytes += cost.bytes
        victim.checkpoint_delay_s += cost.save_s
        pod.jobs.pop(victim.job.job_id)
        pod.slice_jobs.pop(victim.slice_id)
        pod.partitioner.release(victim.slice_id)
        victim.pod_idx = None
        victim.slice_id = None
        victim.finish_s = None
        victim.version += 1   # orphan the victim's pending finish event
        self._queue.append(victim)
        cand = candidate_on(pod, rec.job, sc, t, rec.deadline_s)
        assert cand is not None, "eviction was probed to mint an origin"
        self._place(rec, cand, t, start_delay=cost.save_s)

    # ------------------------------------------------------------------
    # elastic grow (partitioner.extend — the symmetric move to shrink)
    # ------------------------------------------------------------------
    def _grow_into_free(self, pod: PodState, t: float) -> None:
        """After a completion (and queue drain), let running progress jobs
        absorb still-free neighbouring chips. Deterministic order (job id);
        each job takes at most one grow per completion event."""
        for rec in sorted(pod.jobs.values(), key=lambda r: r.job.job_id):
            if rec.executed or rec.finished or rec.job.duration_s is not None:
                continue   # pinned wall-clock jobs gain nothing from chips
            self._try_grow(pod, rec, t)

    def _try_grow(self, pod: PodState, rec: JobRecord, t: float) -> bool:
        """Extend ``rec`` to the largest power-feasible profile whose
        rectangle extension fits in the free neighbourhood and whose step
        time beats the current one. Priced exactly like a shrink: the
        job's (re-planned) resident bytes cross the pod's host links,
        delaying it by the migration time; ``PodSimulator.resize``
        re-bases remaining work and re-solves the pod throttle."""
        bigger = sorted((sc for sc in self.perf.options(rec.job,
                                                        ignore_pin=True)
                         if sc.profile.n_chips > rec.n_chips
                         and sc.step_time < rec.step_time_s),
                        key=lambda sc: -sc.profile.n_chips)
        free = pod.partitioner.free_chips()
        for sc in bigger:
            if sc.profile.n_chips - rec.n_chips > free:
                continue   # not even the chip count fits, let alone power
            if not self._grow_power_ok(pod, rec, sc):
                continue
            try:
                pod.partitioner.extend(rec.slice_id, sc.profile)
            except (RuntimeError, ValueError):
                continue   # extend is transactional: nothing changed
            self._commit_grow(pod, rec, sc, t)
            return True
        return False

    def _grow_power_ok(self, pod: PodState, rec: JobRecord,
                       sc: PerfScore) -> bool:
        loads = [InstanceLoad(sc.profile.n_chips,
                              self._u_for(rec, sc.terms), sc.step_time, 1)
                 if r is rec else r.load() for r in pod.jobs.values()]
        return self.perf.throttle(loads, self.pod_spec) >= self.min_throttle

    def _commit_grow(self, pod: PodState, rec: JobRecord, sc: PerfScore,
                     t: float) -> None:
        self._grows += 1
        moved_bytes = int(sc.plan.resident_bytes)
        rec.profile_name = sc.profile.name
        rec.origin = pod.partitioner.allocations[rec.slice_id].origin
        rec.u_compute = self._u_for(rec, sc.terms)
        rec.step_time_s = sc.step_time
        rec.resident_bytes = moved_bytes
        rec.grown = True
        pod.sim.resize(rec.job.job_id, sc.profile.n_chips,
                       rec.u_compute, sc.step_time)
        self._charge_migration(pod, moved_bytes, [rec], t)
        self._reissue_after_resize(pod, rec, t)

    # ------------------------------------------------------------------
    # live serving execution
    # ------------------------------------------------------------------
    def _start_tenant(self, rec: JobRecord, pod: PodState,
                      cand: Candidate) -> int:
        """Admit the serving job as a real SliceRuntime tenant (reduced-scale
        config on the host backend, same profile and origin the scheduler
        chose) and drain its requests through the live engine."""
        from repro.configs import get_config
        from repro.serving import Request, TenantSpec
        job = rec.job
        cfg = get_config(job.arch).reduced().with_(remat="none")
        tenant = pod.runtime.add_tenant(TenantSpec(
            name=job.tag, cfg=cfg, profile=cand.profile,
            origin=cand.origin, slots=self.serving_slots,
            max_seq=self.serving_max_seq, seed=job.job_id))
        if job.requests:
            rng = np.random.default_rng(1000 + job.job_id)
            reqs = [Request(i, rng.integers(
                        0, cfg.vocab_size,
                        size=int(rng.integers(4, 9))).astype(np.int32),
                        self.serving_max_new)
                    for i in range(job.requests)]
            pod.runtime.submit(job.tag, reqs)
            while not tenant.engine.idle:
                tenant.engine.tick()
            rec.tokens_out = tenant.engine.stats.tokens_out
        return tenant.alloc.slice_id
