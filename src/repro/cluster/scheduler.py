"""ClusterScheduler — event-loop scheduling of a job trace onto N pods.

Each pod is a ``StaticPartitioner`` grid (and optionally a live
``SliceRuntime`` so serving jobs execute on the real engine). The loop is
discrete-event in virtual seconds: arrivals and completions are the events,
placements happen greedily at each event via a ``PlacementPolicy``, and the
scheduler integrates energy / busy chips / fragmentation over the timeline
between events.

Beyond plain packing, the two interference surfaces static partitioning
does NOT remove (paper §V) are modeled at admission time:

* **Power** — a candidate placement is rejected when the pod's predicted
  ``core.power.throttle_factor`` with the new instance falls below
  ``min_throttle`` (the §V-B shared-cap effect); the job waits instead of
  dragging every co-tenant below the cap.
* **Fragmentation** — when a queued job fits a pod's total free chips but
  no aligned rectangle (arXiv 2512.16099 stranding), a repack-enabled
  policy triggers the partitioner's transactional ``repack()`` and pays a
  modeled migration cost: the moved slices' resident state crosses the
  pod's host links (``core.hw`` PCIe-class bandwidth), delaying the new
  job's start and stretching the moved jobs' completions.

Modeling notes: a job's duration is fixed at placement time using the
throttle factor at that moment (later arrivals do not retroactively stretch
running jobs — the admission gate keeps the error small); crafted jobs with
pinned ``duration_s`` skip throttle stretching entirely so tests stay
exactly deterministic.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.hw import PodSpec, V5E_POD
from repro.core.partitioner import StaticPartitioner
from repro.core.power import InstanceLoad, pod_draw, throttle_factor
from repro.core.slices import get_profile

from repro.cluster.metrics import ClusterMetrics, summarize
from repro.cluster.placement import (Candidate, PlacementPolicy,
                                     candidate_on, feasible_options,
                                     get_policy, ideal_duration)
from repro.cluster.trace import SERVING, Job

ARRIVE = "arrive"
FINISH = "finish"


@dataclass
class JobRecord:
    """Mutable scheduling state of one trace job."""
    job: Job
    deadline_s: Optional[float] = None
    pod_idx: Optional[int] = None
    slice_id: Optional[int] = None
    profile_name: Optional[str] = None
    origin: Optional[Tuple[int, int]] = None
    place_s: Optional[float] = None
    finish_s: Optional[float] = None
    duration_s: Optional[float] = None
    u_compute: float = 0.0
    step_time_s: float = 0.0
    resident_bytes: int = 0
    finished: bool = False
    executed: bool = False        # ran on a live SliceRuntime tenant
    tokens_out: int = 0
    power_deferred: int = 0
    version: int = 0              # bumps invalidate stale finish events

    @property
    def placed(self) -> bool:
        return self.place_s is not None

    @property
    def n_chips(self) -> int:
        return get_profile(self.profile_name).n_chips if self.profile_name else 0

    def load(self) -> InstanceLoad:
        return InstanceLoad(self.n_chips, self.u_compute, self.step_time_s, 1)


@dataclass
class PodState:
    idx: int
    partitioner: StaticPartitioner
    runtime: Optional[object] = None   # serving.SliceRuntime when executing
    jobs: Dict[int, JobRecord] = field(default_factory=dict)       # by job_id
    slice_jobs: Dict[int, JobRecord] = field(default_factory=dict)  # by slice

    def loads(self) -> List[InstanceLoad]:
        return [r.load() for r in self.jobs.values()]


class ClusterScheduler:
    def __init__(self, n_pods: int = 2,
                 policy: Union[str, PlacementPolicy] = "frag_repack",
                 pod: PodSpec = V5E_POD, *,
                 min_throttle: float = 0.8,
                 horizon_s: Optional[float] = None,
                 execute_serving: bool = False,
                 mesh=None,
                 serving_slots: int = 2,
                 serving_max_seq: int = 32,
                 serving_max_new: int = 4):
        self.pod_spec = pod
        self.chip = pod.chip
        self.policy = get_policy(policy) if isinstance(policy, str) else policy
        self.min_throttle = min_throttle
        self.horizon_s = horizon_s
        self.execute_serving = execute_serving
        self.serving_slots = serving_slots
        self.serving_max_seq = serving_max_seq
        self.serving_max_new = serving_max_new
        self.pods = [PodState(i, StaticPartitioner(pod)) for i in range(n_pods)]
        if execute_serving:
            from repro.serving import SliceRuntime
            if mesh is None:
                from repro.launch.mesh import make_host_mesh
                mesh = make_host_mesh(1, 1)
            for p in self.pods:
                p.runtime = SliceRuntime(pod=pod, mesh=mesh,
                                         partitioner=p.partitioner)
        # migration path: every moved byte crosses the pod's host links once
        n_hosts = max(1, pod.n_chips // self.chip.chips_per_host)
        self._pod_host_bw = n_hosts * self.chip.host_link_bw
        # timeline integrals
        self._now = 0.0
        self._busy_chip_s = 0.0
        self._frag_s = 0.0
        self._energy_J = 0.0
        # counters
        self._repacks = 0
        self._repack_failures = 0
        self._migrated_bytes = 0
        self._migration_s = 0.0
        self._power_deferrals = 0
        self._heap: List[tuple] = []
        self._seq = 0
        self.records: Optional[List[JobRecord]] = None

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[Job]) -> Tuple[List[JobRecord], ClusterMetrics]:
        assert self.records is None, "ClusterScheduler instances are single-use"
        records = []
        for job in sorted(jobs, key=lambda j: (j.arrival_s, j.job_id)):
            ideal = ideal_duration(job, self.chip)
            rec = JobRecord(job, deadline_s=(
                job.arrival_s + job.slo_factor * ideal
                if ideal is not None else None))
            records.append(rec)
            self._push(job.arrival_s, ARRIVE, rec)
        self.records = records

        queue: List[JobRecord] = []
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if self.horizon_s is not None and t > self.horizon_s:
                break
            self._advance(t)
            if kind == ARRIVE:
                if not self._try_place(payload, t):
                    queue.append(payload)
            else:
                rec, version = payload
                if version != rec.version or rec.finished:
                    continue  # stale event (migration moved the finish)
                self._complete(rec, t)
                self._drain(queue, t)

        end_s = self.horizon_s if self.horizon_s is not None else self._now
        if end_s > self._now:
            self._advance(end_s)
        metrics = summarize(
            self.policy.name, records,
            elapsed_s=end_s,
            total_chips=len(self.pods) * self.pod_spec.n_chips,
            busy_chip_s=self._busy_chip_s,
            frag_time_avg=(self._frag_s / (len(self.pods) * end_s)
                           if end_s > 0 else 0.0),
            energy_J=self._energy_J,
            repacks=self._repacks,
            repack_failures=self._repack_failures,
            migrated_bytes=self._migrated_bytes,
            migration_s=self._migration_s,
            power_deferrals=self._power_deferrals,
        )
        return records, metrics

    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    def _advance(self, t: float) -> None:
        dt = t - self._now
        if dt <= 0:
            return
        for pod in self.pods:
            draw = min(pod_draw(pod.loads(), self.pod_spec),
                       self.pod_spec.power_cap_watts)
            self._energy_J += draw * dt
            self._busy_chip_s += pod.partitioner.used_chips() * dt
            self._frag_s += pod.partitioner.fragmentation_ratio() * dt
        self._now = t

    def _drain(self, queue: List[JobRecord], t: float) -> None:
        progressed = True
        while progressed:
            progressed = False
            for rec in list(queue):
                if self._try_place(rec, t):
                    queue.remove(rec)
                    progressed = True

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _try_place(self, rec: JobRecord, t: float) -> bool:
        cands = self.policy.candidates(rec.job, self.pods, self.chip, t,
                                       rec.deadline_s)
        power_blocked = False
        for cand in cands:
            if self._power_ok(cand, rec):
                self._place(rec, cand, t)
                return True
            power_blocked = True
        if power_blocked:
            if rec.power_deferred == 0:
                self._power_deferrals += 1  # count jobs, not retry attempts
            rec.power_deferred += 1
            return False
        if self.policy.repack_enabled:
            placed = self._repack_and_place(rec, t)
            if placed:
                return True
        return False

    def _power_ok(self, cand: Candidate, rec: JobRecord) -> bool:
        return self._power_ok_profile(self.pods[cand.pod_idx], rec,
                                      cand.profile, cand.terms)

    def _power_ok_profile(self, pod: PodState, rec: JobRecord,
                          profile, terms) -> bool:
        loads = pod.loads()
        if not loads:
            return True  # a job alone on a pod is always admitted
        new = InstanceLoad(profile.n_chips, self._u_for(rec, terms),
                          terms.step_time, 1)
        return throttle_factor(loads + [new], self.pod_spec) >= self.min_throttle

    def _u_for(self, rec: JobRecord, terms) -> float:
        if rec.job.u_compute is not None:
            return rec.job.u_compute
        step = terms.step_time
        return terms.t_compute / step if step else 0.0

    def _place(self, rec: JobRecord, cand: Candidate, t: float,
               start_delay: float = 0.0) -> None:
        pod = self.pods[cand.pod_idx]
        job = rec.job
        u = self._u_for(rec, cand.terms)
        if job.duration_s is not None:
            dur = job.duration_s
        else:
            new = InstanceLoad(cand.profile.n_chips, u, cand.terms.step_time, 1)
            f = throttle_factor(pod.loads() + [new], self.pod_spec)
            step = cand.terms.step_time
            t_comp = step * u
            dur = job.steps * (t_comp / f + (step - t_comp))
        rec.pod_idx = pod.idx
        rec.profile_name = cand.profile.name
        rec.origin = cand.origin
        rec.place_s = t
        rec.duration_s = dur
        rec.finish_s = t + start_delay + dur
        rec.u_compute = u
        rec.step_time_s = cand.terms.step_time
        rec.resident_bytes = int(cand.plan.resident_bytes)
        if (job.kind == SERVING and self.execute_serving
                and pod.runtime is not None):
            rec.slice_id = self._start_tenant(rec, pod, cand)
            rec.executed = True
        else:
            alloc = pod.partitioner.allocate(cand.profile, tag=job.tag,
                                             origin=cand.origin)
            rec.slice_id = alloc.slice_id
        pod.jobs[job.job_id] = rec
        pod.slice_jobs[rec.slice_id] = rec
        rec.version += 1
        self._push(rec.finish_s, FINISH, (rec, rec.version))

    def _complete(self, rec: JobRecord, t: float) -> None:
        pod = self.pods[rec.pod_idx]
        rec.finished = True
        rec.finish_s = t
        pod.jobs.pop(rec.job.job_id)
        pod.slice_jobs.pop(rec.slice_id)
        if rec.executed:
            pod.runtime.remove_tenant(rec.job.tag)
        else:
            pod.partitioner.release(rec.slice_id)

    # ------------------------------------------------------------------
    # repack path (arXiv 2512.16099 stranding fix, priced)
    # ------------------------------------------------------------------
    def _repack_and_place(self, rec: JobRecord, t: float) -> bool:
        for prof, plan, terms in feasible_options(rec.job, self.chip):
            for pod in self.pods:
                part = pod.partitioner
                if (part.free_chips() < prof.n_chips
                        or part.origins_for(prof)):
                    continue  # either truly full, or no stranding to fix
                # power gate BEFORE paying for migration: a repack whose
                # beneficiary then fails admission would stretch the moved
                # jobs for nothing
                if not self._power_ok_profile(pod, rec, prof, terms):
                    continue
                try:
                    moved = part.repack()
                except RuntimeError:
                    self._repack_failures += 1
                    continue
                cand = candidate_on(pod, rec.job, prof, plan, terms, t,
                                    rec.deadline_s)
                if cand is None:
                    # compaction could not mint an aligned origin after
                    # all; the grid stays valid (and tidier) — charge
                    # nothing, keep looking
                    continue
                self._repacks += 1
                t_mig = self._migration_cost(pod, moved)
                self._place(rec, cand, t, start_delay=t_mig)
                return True
        return False

    def _migration_cost(self, pod: PodState, moved: Dict[int, tuple]) -> float:
        """Seconds to migrate the moved slices' resident state across the
        pod's host links; stretches the moved running jobs by the same
        amount (their completion events are re-issued)."""
        moved_bytes = sum(pod.slice_jobs[sid].resident_bytes
                          for sid in moved if sid in pod.slice_jobs)
        t_mig = moved_bytes / self._pod_host_bw
        self._migrated_bytes += moved_bytes
        self._migration_s += t_mig
        if t_mig > 0:
            for sid in moved:
                r = pod.slice_jobs.get(sid)
                if r is not None and not r.finished:
                    r.finish_s += t_mig
                    r.version += 1
                    self._push(r.finish_s, FINISH, (r, r.version))
        return t_mig

    # ------------------------------------------------------------------
    # live serving execution
    # ------------------------------------------------------------------
    def _start_tenant(self, rec: JobRecord, pod: PodState,
                      cand: Candidate) -> int:
        """Admit the serving job as a real SliceRuntime tenant (reduced-scale
        config on the host backend, same profile and origin the scheduler
        chose) and drain its requests through the live engine."""
        from repro.configs import get_config
        from repro.serving import Request, TenantSpec
        job = rec.job
        cfg = get_config(job.arch).reduced().with_(remat="none")
        tenant = pod.runtime.add_tenant(TenantSpec(
            name=job.tag, cfg=cfg, profile=cand.profile,
            origin=cand.origin, slots=self.serving_slots,
            max_seq=self.serving_max_seq, seed=job.job_id))
        if job.requests:
            rng = np.random.default_rng(1000 + job.job_id)
            reqs = [Request(i, rng.integers(
                        0, cfg.vocab_size,
                        size=int(rng.integers(4, 9))).astype(np.int32),
                        self.serving_max_new)
                    for i in range(job.requests)]
            pod.runtime.submit(job.tag, reqs)
            while not tenant.engine.idle:
                tenant.engine.tick()
            rec.tokens_out = tenant.engine.stats.tokens_out
        return tenant.alloc.slice_id
