"""ClusterScheduler — a thin event loop over the Action API.

Each pod is a ``StaticPartitioner`` grid plus a ``core.perfmodel.
PodSimulator`` (and optionally a live ``SliceRuntime`` so serving jobs
execute on the real engine). The loop is discrete-event in virtual seconds:
arrivals and completions are the events. Everything that *changes* cluster
state at an event is a first-class ``Action`` from
``cluster/actions.py`` — ``Place``, ``Repack``, ``Shrink``, ``Grow``,
``Preempt``, ``MigrateAcrossPods`` — each with a uniform
``probe → ActionOutcome`` (feasibility + priced cost + projected SLO
effect via the shared ``PerfModel``) and transactional
``apply()``/``rollback()``. The scheduler itself only:

1. pops events and advances the timeline integrals (energy / busy chips /
   fragmentation),
2. enumerates placement candidates (``PlacementPolicy``) and probes
   ``Place``/``Repack`` for arrivals,
3. hands blocked deadline jobs to a ``SchedulerPolicy``
   (``GreedyCheapestRescue`` or ``LookAheadPolicy``) that selects and
   commits a rescue plan from the ``PolicySpec`` action allowlist,
4. re-drains the queue after completions (queued jobs have first claim on
   freed chips; ``Grow`` actions then absorb what is still free).

All performance and power questions go through the shared ``PerfModel`` /
``PodSimulator`` pair — no roofline or power-model glue lives here, and no
rescue selection does either (that is the policies' job). Beyond plain
packing, the interference surfaces static partitioning does NOT remove
(paper §V) are modeled:

* **Power** — a candidate placement is rejected when the pod simulator's
  predicted throttle with the new instance falls below ``min_throttle``
  (the §V-B shared-cap effect); the job waits instead of dragging every
  co-tenant below the cap. Admissions, completions, repack delays, and
  elastic resizes re-solve the whole pod and re-project every running
  job's finish under the new mix.
* **Fragmentation** — when a queued job fits a pod's total free chips but
  no aligned rectangle (arXiv 2512.16099 stranding), a repack-enabled
  placement policy triggers the ``Repack`` action: the partitioner's
  transactional ``repack()`` plus a modeled migration cost over the pod's
  host links.

Which elastic moves exist at all is the declarative ``PolicySpec``
allowlist: ``"shrink"`` (resize a running batch job to a smaller
profile), ``"preempt"`` (checkpoint-evict a strictly lower-priority batch
job), ``"grow"`` (extend a running job into freed neighbour chips), and
``"migrate"`` (relocate a lower-priority job to another pod over the DCN
— see ``MigrateAcrossPods``). The legacy ``elastic``/``priorities``/
``grow`` boolean kwargs are deprecation shims onto that allowlist and
reproduce the PR 2/3/4 behaviour bit-for-bit.

``frozen_durations=True`` is the compatibility mode: durations are fixed
at admission time with the legacy float arithmetic and never re-solved,
reproducing the PR 2 scheduler's numbers bit-for-bit. Crafted jobs with
pinned ``duration_s`` skip throttle modeling in both modes so tests stay
exactly deterministic.

Units, everywhere in this module: virtual time and durations in seconds
(nominal = unthrottled work seconds; wall = after throttle stretch and
delays), state volumes in bytes, slice sizes in chips.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.hw import (PodSpec, V5E_POD, default_mode, ladder_for,
                           partition_modes)
from repro.core.offload import TwinSpec
from repro.core.partitioner import StaticPartitioner
from repro.core.perfmodel import (InstanceLoad, PerfModel, PodSimulator,
                                  model_for_mode)
from repro.core.slices import PROFILES, get_profile

from repro.cluster.actions import (Grow, Place, PolicySpec, ProbeCache,
                                   Repack, RESCUE_KINDS,
                                   deprecated_flags_spec,
                                   get_scheduler_policy, txn_touch)
from repro.cluster.metrics import ClusterMetrics, summarize
from repro.cluster.placement import (Candidate, PlacementPolicy, get_policy,
                                     ideal_duration)
from repro.cluster.trace import SERVING, Job

ARRIVE = "arrive"
FINISH = "finish"
CONTROL = "control"   # autoscaler tick (only pushed when autoscaler= is set)
TICK = "tick"         # advance-clock point left behind by heap compaction


@dataclass(frozen=True)
class SuspendSnapshot:
    """Progress frozen at checkpoint-eviction time, restored at resume.

    ``work_done``/``work_total`` are nominal (unthrottled) seconds for
    progress jobs; ``fixed_remaining`` is remaining wall seconds for
    pinned/frozen jobs (``pinned`` tells which); ``step_time`` is the
    evicted slice's nominal seconds per step (re-bases a frozen remainder
    onto a different resume profile); ``bytes`` is the checkpoint volume
    written at save time — the restore pays the same bytes back;
    ``delay_remaining`` is unburned wall delay (seconds) from an earlier
    charged migration, still owed after the resume."""
    work_done: float
    work_total: float
    fixed_remaining: Optional[float]
    pinned: bool
    step_time: float
    bytes: int
    delay_remaining: float = 0.0


@dataclass
class JobRecord:
    """Mutable scheduling state of one trace job.

    Units: ``*_s`` fields are virtual seconds, ``resident_bytes`` /
    ``checkpoint_bytes`` / ``dcn_bytes`` are bytes, profiles imply chips.
    ``place_s`` is the *first* placement (queue delay = ``place_s −
    arrival_s``; a checkpoint resume keeps it), ``duration_s`` is the most
    recent admission's modeled remaining duration."""
    job: Job
    deadline_s: Optional[float] = None
    pod_idx: Optional[int] = None
    slice_id: Optional[int] = None
    profile_name: Optional[str] = None
    rung: Optional[str] = None    # priced rung: profile name, "+cpuX.XX" if twin
    origin: Optional[Tuple[int, int]] = None
    place_s: Optional[float] = None
    finish_s: Optional[float] = None
    duration_s: Optional[float] = None
    u_compute: float = 0.0
    step_time_s: float = 0.0
    resident_bytes: int = 0
    finished: bool = False
    executed: bool = False        # ran on a live SliceRuntime tenant
    shrunk: bool = False          # resized to a smaller profile mid-flight
    grown: bool = False           # absorbed freed chips via extend()
    tokens_out: int = 0
    power_deferred: int = 0
    version: int = 0              # bumps invalidate stale finish events
    # checkpoint preemption bookkeeping
    preemptions: int = 0          # times checkpoint-evicted
    resumes: int = 0              # times resumed from a checkpoint
    suspend_s: Optional[float] = None   # last eviction time
    resume_s: Optional[float] = None    # last resume time
    checkpoint_bytes: int = 0     # total save+restore volume paid (bytes)
    checkpoint_delay_s: float = 0.0     # total save+restore seconds paid
    suspended: Optional[SuspendSnapshot] = None  # set while evicted
    # cross-pod migration bookkeeping (MigrateAcrossPods)
    migrations: int = 0           # times relocated to another pod
    migrate_s: Optional[float] = None   # last relocation time
    dcn_bytes: int = 0            # resident state moved over the DCN (bytes)
    dcn_delay_s: float = 0.0      # save+restore seconds paid over the DCN

    @property
    def placed(self) -> bool:
        return self.place_s is not None

    @property
    def n_chips(self) -> int:
        return get_profile(self.profile_name).n_chips if self.profile_name else 0

    def load(self) -> InstanceLoad:
        return InstanceLoad(self.n_chips, self.u_compute, self.step_time_s, 1)


@dataclass
class PodState:
    idx: int
    partitioner: StaticPartitioner
    sim: PodSimulator
    runtime: Optional[object] = None   # serving.SliceRuntime when executing
    jobs: Dict[int, JobRecord] = field(default_factory=dict)       # by job_id
    slice_jobs: Dict[int, JobRecord] = field(default_factory=dict)  # by slice
    gen: int = 0   # pod-level mutation counter (transaction rollbacks)
    # current partition mode (mutable scheduler state): the name of one of
    # the chip's PartitionModes. "fixed" for the v5e family; MI300-class
    # pods boot in the scheduler's base mode and ReconfigurePartition
    # switches it at runtime (undo-log rollback restores it).
    mode: str = "fixed"

    @property
    def generation(self) -> Tuple:
        """Composite structural-validity token for this pod: the pod-level
        counter plus the current partition mode, the partitioner's grid
        generation and the simulator's mix generation. Every mutation a
        rescue probe can observe — grid shape, partition mode (and with it
        the roofline constants and slice ladder), resident-job membership,
        per-job load parameters, power mix, transaction rollback — moves
        at least one component, so equal tuples mean every cached probe
        outcome against this pod is still exact. The ``ProbeCache`` keys
        on this; the mode component is what keeps cached probe cores from
        leaking across a ReconfigurePartition."""
        return (self.gen, self.mode, self.partitioner.generation,
                self.sim.generation)


class EventHeap:
    """The scheduler's event queue with lazy invalidation.

    Re-projection (``_resync``) never edits or scans pending events: it
    bumps the record's version and pushes a fresh finish event, orphaning
    the old entry, which is recognized as stale in O(1) at pop time by
    comparing its pushed version against the record's current one. Entries
    are ``(t, seq, kind, payload)`` — ``seq`` is the monotone push counter
    that breaks time ties deterministically (FIFO among equal times).

    When ``compact=True``, pushes amortize a purge of stale entries once
    they dominate the heap, bounding tuple/payload retention to O(live).
    Purging must not change *when* the event loop advances virtual time:
    the progress/energy accruals are piecewise float sums whose grouping
    is set by pop times, so dropping a stale pop point regroups the
    summation and drifts the pinned goldens by ulps (measured: the
    progress-mode trace0 timeline sha). Compaction therefore keeps each
    purged entry's bare *time* in a side heap of floats (one boxed
    double per entry vs a ~150+-byte tuple chain whose payload pins
    records and versions alive) and replays it as a
    ``TICK`` event — the integration grid, and with it every accumulated
    float, is bit-identical to the uncompacted heap, which is what lets
    compaction default on."""

    def __init__(self, compact: bool = True):
        self._h: List[tuple] = []
        self._seq = 0
        self.compact = compact
        self._compact_at = 256
        self._ticks: List[float] = []   # heapified purged-entry times

    def __len__(self) -> int:
        return len(self._h) + len(self._ticks)

    def __bool__(self) -> bool:
        return bool(self._h) or bool(self._ticks)

    @staticmethod
    def _stale(entry: tuple) -> bool:
        _, _, kind, payload = entry
        if kind != FINISH:
            return False
        rec, version = payload
        return version != rec.version or rec.finished

    def push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._h, (t, self._seq, kind, payload))
        self._seq += 1
        if self.compact and len(self._h) > self._compact_at:
            live = [e for e in self._h if not self._stale(e)]
            if len(live) * 2 <= len(self._h):
                for e in self._h:
                    if self._stale(e):
                        heapq.heappush(self._ticks, e[0])
                heapq.heapify(live)   # (t, seq) order is preserved exactly
                self._h = live
            self._compact_at = max(256, 2 * len(self._h))

    def pop(self) -> tuple:
        # a tick and a real event at the same time: pop the real event
        # first — the tick's only job is advancing the clock, and the
        # second same-t pop advances by dt=0, so the order is untimed
        if self._ticks and (not self._h or self._ticks[0] < self._h[0][0]):
            return (heapq.heappop(self._ticks), -1, TICK, None)
        return heapq.heappop(self._h)


class ClusterScheduler:
    """Discrete-event scheduler for a job trace over ``n_pods`` pods.

    ``policy`` is the *placement* policy (candidate enumeration:
    ``first_fit``/``frag``/``frag_repack``); ``spec`` is the
    ``PolicySpec`` that declares which elastic actions exist and which
    ``SchedulerPolicy`` selects among them. The default spec (no actions,
    greedy selector) reproduces PR 2/3 behaviour; the deprecated
    ``elastic``/``priorities``/``grow`` booleans shim onto
    ``PolicySpec.from_flags``.

    Units: event times and all ``*_s`` quantities are virtual seconds,
    in-pod migrated/checkpointed volumes are bytes priced over the pod's
    aggregate host-link bandwidth (bytes/s), cross-pod volumes over its
    aggregate DCN bandwidth (``PodSpec.dcn_bw``), slice sizes are chips.
    Instances are single-use: one ``run()`` per scheduler."""

    def __init__(self, n_pods: int = 2,
                 policy: Union[str, PlacementPolicy] = "frag_repack",
                 pod: PodSpec = V5E_POD, *,
                 min_throttle: float = 0.8,
                 horizon_s: Optional[float] = None,
                 frozen_durations: bool = False,
                 spec: Optional[PolicySpec] = None,
                 elastic: Optional[bool] = None,
                 priorities: Optional[bool] = None,
                 grow: Optional[bool] = None,
                 perf: Optional[PerfModel] = None,
                 execute_serving: bool = False,
                 mesh=None,
                 serving_slots: int = 2,
                 serving_max_seq: int = 32,
                 serving_max_new: int = 4,
                 snapshot_rollback: bool = False,
                 heap_compaction: bool = True,
                 probe_cache: bool = True,
                 autoscaler=None,
                 twin: Union[bool, TwinSpec] = False,
                 mode: Optional[str] = None):
        self.pod_spec = pod
        self.chip = pod.chip
        # partition-mode state: the chip's mode table and the base mode
        # every pod boots in ("fixed" for v5e — the only mode it has).
        # ReconfigurePartition mutates per-pod PodState.mode at runtime.
        self._modes = partition_modes(pod.chip)
        self.base_mode = mode if mode is not None else default_mode(pod.chip)
        if self.base_mode not in self._modes:
            raise ValueError(
                f"unknown partition mode {self.base_mode!r} for chip "
                f"{self.chip.name!r}; valid: {sorted(self._modes)}")
        self.policy = get_policy(policy) if isinstance(policy, str) else policy
        self.min_throttle = min_throttle
        self.horizon_s = horizon_s
        self.frozen_durations = frozen_durations
        flag_spec = deprecated_flags_spec(elastic, priorities, grow)
        if flag_spec is not None and spec is not None:
            raise ValueError("pass either spec= or the deprecated "
                             "elastic/priorities/grow booleans, not both")
        self.spec = flag_spec if flag_spec is not None \
            else (spec if spec is not None else PolicySpec())
        self.selector = get_scheduler_policy(self.spec.selector)
        # twin-offload rungs (default off): True enables the default
        # TwinSpec, or pass a TwinSpec directly; an explicit perf= wins
        self.twin = (twin if isinstance(twin, TwinSpec)
                     else (TwinSpec() if twin else None))
        # the base-mode model: for the v5e/fixed default this is exactly
        # get_model(pod.chip, twin=...) — same shared object, same memos,
        # every pre-existing pin untouched
        self.perf = (perf if perf is not None
                     else model_for_mode(pod.chip,
                                         self._modes[self.base_mode],
                                         twin=self.twin))
        self.execute_serving = execute_serving
        self.serving_slots = serving_slots
        self.serving_max_seq = serving_max_seq
        self.serving_max_new = serving_max_new
        self.pods = [PodState(i, StaticPartitioner(pod),
                              PodSimulator(pod, frozen=frozen_durations),
                              mode=self.base_mode)
                     for i in range(n_pods)]
        base_ladder = ladder_for(self._modes[self.base_mode])
        if base_ladder != PROFILES:   # granularity-floored mode (MI300 SPX)
            for p in self.pods:
                p.partitioner.set_profiles(base_ladder)
        if execute_serving:
            from repro.serving import SliceRuntime
            if mesh is None:
                from repro.launch.mesh import make_host_mesh
                mesh = make_host_mesh(1, 1)
            for p in self.pods:
                p.runtime = SliceRuntime(pod=pod, mesh=mesh,
                                         partitioner=p.partitioner)
        # migration paths: in-pod moves cross the pod's host links once,
        # cross-pod moves cross the DCN (both aggregate bytes/s)
        self._pod_host_bw = pod.n_hosts * self.chip.host_link_bw
        self._dcn_bw = pod.dcn_bw
        # timeline integrals
        self._now = 0.0
        self._busy_chip_s = 0.0
        self._frag_s = 0.0
        self._energy_J = 0.0
        # counters
        self._repacks = 0
        self._repack_failures = 0
        self._shrinks = 0
        self._grows = 0
        self._preemptions = 0
        self._resumes = 0
        self._wasted_checkpoint_chip_s = 0.0
        self._migrated_bytes = 0
        self._migration_s = 0.0
        self._migrations = 0
        self._dcn_migrated_bytes = 0
        self._dcn_migration_s = 0.0
        self._reconfigs = 0
        self._power_deferrals = 0
        self._probes = 0          # placement/rescue probes (perf telemetry)
        # rescue-probe structural cores: priced = actually evaluated
        # (grid trial + power solve), hits = served from the ProbeCache.
        # Deliberately NOT in the transaction counter set — a core priced
        # inside a rolled-back trial branch was still priced.
        self._probes_priced = 0
        self._probe_hits = 0
        self.probe_cache = ProbeCache() if probe_cache else None
        self._heap = EventHeap(compact=heap_compaction)
        self._queue: List[JobRecord] = []
        self._queued_ids: set = set()   # id(rec) mirror for _drain sweeps
        self._min_chips: Dict[int, int] = {}  # id(rec) -> cheapest profile
        self._can_rescue = any(self.spec.enabled(k) for k in RESCUE_KINDS)
        self.snapshot_rollback = snapshot_rollback
        self._txns: List[object] = []   # open undo-log transactions (LIFO)
        # the autoscale control loop (cluster/autoscale.py), duck-typed:
        # spec.interval_s, control(sched, t), finalize(sched, end_s),
        # metrics_fields(). None = no CONTROL events, timelines untouched.
        self.autoscaler = autoscaler
        if autoscaler is not None and horizon_s is None:
            raise ValueError("autoscaler= needs horizon_s: the control "
                             "loop ticks over a bounded virtual day")
        self.records: Optional[List[JobRecord]] = None

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[Job]) -> Tuple[List[JobRecord], ClusterMetrics]:
        """Schedule ``jobs`` to completion (or ``horizon_s`` virtual
        seconds) and return (per-job records, aggregate metrics). Each
        record's deadline is ``arrival + slo_factor × ideal`` seconds,
        where ideal is the job's fastest unthrottled feasible duration."""
        assert self.records is None, "ClusterScheduler instances are single-use"
        records = []
        for job in sorted(jobs, key=lambda j: (j.arrival_s, j.job_id)):
            ideal = ideal_duration(job, self.chip, self.perf)
            rec = JobRecord(job, deadline_s=(
                job.arrival_s + job.slo_factor * ideal
                if ideal is not None else None))
            records.append(rec)
            self._push(job.arrival_s, ARRIVE, rec)
        self.records = records
        if self.autoscaler is not None:
            dt = self.autoscaler.spec.interval_s
            for k in range(1, int(self.horizon_s / dt) + 1):
                self._push(k * dt, CONTROL, None)

        queue = self._queue
        while self._heap:
            t, _, kind, payload = self._heap.pop()
            if self.horizon_s is not None and t > self.horizon_s:
                break
            self._advance(t)
            if kind == TICK:
                continue   # compaction's advance-clock point, nothing else
            if kind == ARRIVE:
                if not self._try_place(payload, t):
                    self._enqueue(payload)
            elif kind == CONTROL:
                if self.autoscaler.control(self, t):
                    # a shrink/migrate may have freed chips a queued
                    # job was waiting for
                    self._drain(queue, t)
            else:
                rec, version = payload
                if version != rec.version or rec.finished:
                    continue  # stale event (a re-solve moved the finish)
                pod = self.pods[rec.pod_idx]
                self._complete(rec, t)
                self._drain(queue, t)
                if self.spec.enabled("grow"):
                    # queued jobs had first claim on the freed chips; a
                    # running neighbour may absorb what is still free
                    self._grow_into_free(pod, t)

        end_s = self.horizon_s if self.horizon_s is not None else self._now
        if end_s > self._now:
            self._advance(end_s)
        autoscale_kw = {}
        if self.autoscaler is not None:
            self.autoscaler.finalize(self, end_s)
            autoscale_kw = self.autoscaler.metrics_fields()
        metrics = summarize(
            self.policy.name, records,
            elapsed_s=end_s,
            total_chips=len(self.pods) * self.pod_spec.n_chips,
            busy_chip_s=self._busy_chip_s,
            frag_time_avg=(self._frag_s / (len(self.pods) * end_s)
                           if end_s > 0 else 0.0),
            energy_J=self._energy_J,
            repacks=self._repacks,
            repack_failures=self._repack_failures,
            shrinks=self._shrinks,
            grows=self._grows,
            preemptions=self._preemptions,
            resumes=self._resumes,
            wasted_checkpoint_chip_s=self._wasted_checkpoint_chip_s,
            migrated_bytes=self._migrated_bytes,
            migration_s=self._migration_s,
            migrations=self._migrations,
            dcn_migrated_bytes=self._dcn_migrated_bytes,
            dcn_migration_s=self._dcn_migration_s,
            reconfigs=self._reconfigs,
            power_deferrals=self._power_deferrals,
            rescue_probes_priced=self._probes_priced,
            probe_cache_hits=self._probe_hits,
            **autoscale_kw,
        )
        return records, metrics

    def _push(self, t: float, kind: str, payload) -> None:
        self._heap.push(t, kind, payload)

    def _revive_finish(self, rec: JobRecord) -> None:
        """Bump ``rec``'s version (orphaning any events pushed by a rolled-
        back action) and, if the record is a live placement, re-issue its
        finish event at the restored time. Called by ``actions.restore``."""
        rec.version += 1
        if (rec.pod_idx is not None and not rec.finished
                and rec.finish_s is not None
                and rec.job.job_id in self.pods[rec.pod_idx].jobs):
            self._push(rec.finish_s, FINISH, (rec, rec.version))

    def _advance(self, t: float) -> None:
        dt = t - self._now
        if dt <= 0:
            return
        for pod in self.pods:
            self._energy_J += pod.sim.draw(capped=True) * dt
            self._busy_chip_s += pod.partitioner.used_chips() * dt
            self._frag_s += pod.partitioner.fragmentation_ratio() * dt
            pod.sim.advance(t)
        self._now = t

    def _drain(self, queue: List[JobRecord], t: float) -> None:
        """Place every queued job that now fits; sweeps repeat until a
        full pass places nothing. A placement may mutate the queue
        underneath the sweep snapshot (a rescue suspends a victim into
        it, or resumes one out of it), so membership is re-checked by
        identity before each attempt — placing a record twice would
        double-admit it."""
        self._queued_ids = {id(q) for q in queue}
        queued_ids = self._queued_ids
        min_chips = self._min_chips
        # With no rescue actions allowed, a job whose cheapest profile
        # exceeds the largest per-pod free-chip count is provably
        # unplaceable (no origin can be free, Repack.find guards itself
        # out, rescue is a no-op), so the sweep can skip its whole probe
        # cascade. Placements only consume chips on this path, so the
        # bound is refreshed after each success and stays exact.
        gate = not self._can_rescue
        max_free = 0
        progressed = True
        while progressed:
            progressed = False
            if gate:
                max_free = max(p.partitioner.free_chips()
                               for p in self.pods)
            for rec in list(queue):
                if id(rec) not in queued_ids:
                    continue   # resumed by a nested rescue this sweep
                if gate:
                    need = min_chips.get(id(rec))
                    if need is None:
                        need = self._min_need(rec)
                    if need < 0 or need > max_free:
                        continue
                if self._try_place(rec, t):
                    self._unqueue(rec)
                    progressed = True
                    if gate:
                        max_free = max(p.partitioner.free_chips()
                                       for p in self.pods)

    def _enqueue(self, rec: JobRecord) -> None:
        if self._txns:
            self._txns[-1].note_queue("add", rec)
        self._queue.append(rec)
        self._queued_ids.add(id(rec))

    def _min_need(self, rec: JobRecord) -> int:
        """Chips of the job's cheapest feasible profile (−1: none fit),
        memoized by record identity — the drain gate's threshold."""
        need = min((sc.profile.n_chips
                    for sc in self.perf.options(rec.job)), default=-1)
        self._min_chips[id(rec)] = need
        return need

    def _unqueue(self, rec: JobRecord) -> None:
        """Remove ``rec`` from the queue by identity (JobRecord equality
        is field-wise, which could alias distinct records)."""
        self._queued_ids.discard(id(rec))
        for i, q in enumerate(self._queue):
            if q is rec:
                if self._txns:
                    self._txns[-1].note_queue("del", rec, i)
                del self._queue[i]
                return

    # ------------------------------------------------------------------
    # partition-mode surface (ReconfigurePartition and mode-aware scoring)
    # ------------------------------------------------------------------
    def mode_model(self, mode_name: str) -> PerfModel:
        """The shared PerfModel of this cluster's chip under partition mode
        ``mode_name`` — the mode's roofline deltas and slice ladder folded
        in. Hits the process-wide model memo, so repeated lookups are
        dict-cheap."""
        return model_for_mode(self.chip, self._modes[mode_name],
                              twin=self.twin)

    def perf_for(self, pod: PodState) -> PerfModel:
        """The PerfModel matching ``pod``'s *current* mode. Base-mode pods
        (every pod, on a fixed-mode chip) get ``self.perf`` itself — the
        exact object pins were recorded against."""
        if pod.mode == self.base_mode:
            return self.perf
        return self.mode_model(pod.mode)

    def candidates_for(self, job, t: float,
                       deadline_s: Optional[float]) -> List[Candidate]:
        """Placement candidates across all pods, each pod scored under its
        current partition mode. With every pod in the base mode (always
        true for v5e and for any run without ReconfigurePartition) this is
        exactly the legacy single-model enumeration — bit-identical
        ordering. A mode-split cluster enumerates per pod and re-sorts
        with the fragmentation-aware ranking (candidates from different
        modes are still comparable: perf-per-chip and deadlines are
        mode-absolute), falling back to plain pod-order concatenation for
        the first-fit baseline."""
        if all(p.mode == self.base_mode for p in self.pods):
            return self.policy.candidates(job, self.pods, self.chip, t,
                                          deadline_s, perf=self.perf)
        cands: List[Candidate] = []
        for pod in self.pods:
            cands.extend(self.policy.candidates(
                job, (pod,), self.chip, t, deadline_s,
                perf=self.perf_for(pod)))
        if self.policy.name != "first_fit":
            cands.sort(key=lambda c: (
                not c.meets_deadline, -c.perf_per_chip, -c.largest_after,
                c.pod_idx, c.origin))
        return cands

    def _is_fixed(self, rec: JobRecord) -> bool:
        """Fixed-duration jobs (pinned or frozen mode) are event-driven and
        never re-projected; only explicit delays move their finish."""
        return self.frozen_durations or rec.job.duration_s is not None

    def _resync(self, pod: PodState, t: float) -> None:
        """Re-project every progress job on the pod after a mix change and
        re-issue the finish events that moved (stale versions are skipped
        by the event loop). No-op in frozen mode."""
        txn_touch(self, pod)
        for jid, fin in pod.sim.finish_times(t).items():
            rec = pod.jobs.get(jid)
            if rec is None or rec.finished or fin == rec.finish_s:
                continue
            rec.finish_s = fin
            rec.version += 1
            self._push(fin, FINISH, (rec, rec.version))

    # ------------------------------------------------------------------
    # placement: probe Place / Repack, then delegate to the SchedulerPolicy
    # ------------------------------------------------------------------
    def _try_place(self, rec: JobRecord, t: float) -> bool:
        """Place ``rec`` now if any action allows it: a ``Place`` on a free
        aligned origin, a ``Repack``, or a rescue plan selected by the
        ``SchedulerPolicy`` from the ``PolicySpec`` action allowlist.
        Returns False → the job queues."""
        if not self._can_rescue:
            # Same infeasibility gate as the _drain sweep, for the
            # arrival path: if every pod has fewer free chips than the
            # job's smallest profile, the full probe cascade below fails
            # without side effects — skip it outright.
            need = self._min_chips.get(id(rec))
            if need is None:
                need = self._min_need(rec)
            if need < 0 or all(p.partitioner.free_chips() < need
                               for p in self.pods):
                return False
        cands = self.candidates_for(rec.job, t, rec.deadline_s)
        self._probes += 1
        power_blocked = False
        for cand in cands:
            act = Place(rec, cand)
            if act.probe(self, t).feasible:
                act.apply(self, t, record=False)   # the loop never rolls back
                return True
            power_blocked = True
        if power_blocked:
            # shrinking (or evicting, or relocating) a victim lowers its
            # pod's dynamic draw, so a rescue can lift the shared cap too
            if self._rescue_and_place(rec, t):
                return True
            if rec.power_deferred == 0:
                self._power_deferrals += 1  # count jobs, not retry attempts
            rec.power_deferred += 1
            return False
        if self.policy.repack_enabled:
            act = Repack.find(self, rec, t, record=False)
            if act is not None:
                act.apply(self, t, record=False)
                return True
        return self._rescue_and_place(rec, t)

    def _rescue_and_place(self, rec: JobRecord, t: float) -> bool:
        """Hand the blocked deadline job to the ``SchedulerPolicy``: it
        probes the allowed actions (probe → price), selects, and commits a
        plan. Returns False → queue (no SLO-preserving plan exists)."""
        plan = self.selector.rescue(self, rec, t)
        if plan is None:
            return False
        if any(a.kind == "preempt" for a in plan):
            # the evicted victim may fit *right now* — a smaller profile,
            # another pod — instead of idling until the next completion
            # event drains the queue
            for r in [q for q in self._queue if q.suspended is not None]:
                if self._try_place(r, t):
                    self._unqueue(r)
        if self.selector.chains_grow and self.spec.enabled("grow"):
            # chain a grow after the rescue: any committed plan may have
            # freed chips (an eviction's leftover, a shrunk victim's old
            # rectangle), and a running neighbour may absorb them now
            # instead of waiting for the next completion event
            for pod in self.pods:
                self._grow_into_free(pod, t)
        return True

    def _power_ok(self, cand: Candidate, rec: JobRecord) -> bool:
        return self._power_ok_profile(self.pods[cand.pod_idx], rec,
                                      cand.profile, cand.terms)

    def _power_ok_profile(self, pod: PodState, rec: JobRecord,
                          profile, terms) -> bool:
        if not pod.jobs:
            return True  # a job alone on a pod is always admitted
        new = InstanceLoad(profile.n_chips, self._u_for(rec, terms),
                          terms.step_time, 1)
        return pod.sim.throttle(new) >= self.min_throttle

    def _u_for(self, rec: JobRecord, terms) -> float:
        if rec.job.u_compute is not None:
            return rec.job.u_compute
        step = terms.step_time
        return terms.t_compute / step if step else 0.0

    def _place(self, rec: JobRecord, cand: Candidate, t: float,
               start_delay: float = 0.0) -> None:
        """Admit ``rec`` on ``cand``'s pod/profile/origin at time ``t``
        (virtual seconds), optionally after ``start_delay`` wall seconds
        of migration or checkpoint traffic. A suspended record (evicted
        earlier) is *resumed*: its snapshotted progress carries over and
        the checkpoint restore volume is paid before work continues."""
        pod = self.pods[cand.pod_idx]
        txn_touch(self, pod, rec)
        job = rec.job
        u = self._u_for(rec, cand.terms)
        duration = job.duration_s
        admit_kw = {}
        if rec.suspended is not None:
            snap = rec.suspended
            restore_s = self.perf.checkpoint_cost(
                snap.bytes, self._pod_host_bw).restore_s
            # restore traffic, plus any migration delay still owed from
            # before the eviction — suspension never forgives a debt
            start_delay += restore_s + snap.delay_remaining
            self._resumes += 1
            self._wasted_checkpoint_chip_s += (cand.profile.n_chips
                                               * restore_s)
            rec.resumes += 1
            rec.resume_s = t
            rec.checkpoint_bytes += snap.bytes
            rec.checkpoint_delay_s += restore_s
            if snap.fixed_remaining is not None and snap.pinned:
                duration = snap.fixed_remaining   # wall-clock contract
            elif snap.fixed_remaining is not None:
                # frozen remainder re-based onto the resume profile
                admit_kw["fixed_remaining"] = (
                    snap.fixed_remaining
                    * cand.terms.step_time / snap.step_time)
            else:
                frac = (snap.work_done / snap.work_total
                        if snap.work_total else 0.0)
                admit_kw["work_done"] = frac * (job.steps
                                                * cand.terms.step_time)
            rec.suspended = None
        finish = pod.sim.admit(
            job.job_id, cand.profile.n_chips, u, cand.terms.step_time,
            job.steps, t, duration_s=duration, start_delay=start_delay,
            **admit_kw)
        rec.pod_idx = pod.idx
        rec.profile_name = cand.profile.name
        rec.rung = cand.rung or cand.profile.name
        rec.origin = cand.origin
        if rec.place_s is None:
            rec.place_s = t   # queue delay measures the FIRST placement
        rec.duration_s = finish - t - start_delay
        rec.finish_s = finish
        rec.u_compute = u
        rec.step_time_s = cand.terms.step_time
        rec.resident_bytes = int(cand.plan.resident_bytes)
        if (job.kind == SERVING and self.execute_serving
                and pod.runtime is not None):
            rec.slice_id = self._start_tenant(rec, pod, cand)
            rec.executed = True
        else:
            alloc = pod.partitioner.allocate(cand.profile, tag=job.tag,
                                             origin=cand.origin)
            rec.slice_id = alloc.slice_id
        pod.jobs[job.job_id] = rec
        pod.slice_jobs[rec.slice_id] = rec
        rec.version += 1
        self._push(rec.finish_s, FINISH, (rec, rec.version))
        if not self.frozen_durations:
            self._resync(pod, t)   # the new tenant slows every co-tenant

    def _complete(self, rec: JobRecord, t: float) -> None:
        pod = self.pods[rec.pod_idx]
        rec.finished = True
        rec.finish_s = t
        pod.jobs.pop(rec.job.job_id)
        pod.slice_jobs.pop(rec.slice_id)
        pod.sim.remove(rec.job.job_id)
        if rec.executed:
            pod.runtime.remove_tenant(rec.job.tag)
        else:
            pod.partitioner.release(rec.slice_id)
        if not self.frozen_durations:
            self._resync(pod, t)   # survivors speed back up

    # ------------------------------------------------------------------
    # shared pricing mechanics the actions call
    # ------------------------------------------------------------------
    def _migration_cost(self, pod: PodState, moved: Dict[int, tuple],
                        t: float) -> float:
        """Seconds to migrate the moved slices' resident state across the
        pod's host links; stretches the moved running jobs by the same
        amount (their completion events are re-issued)."""
        moved_bytes = sum(pod.slice_jobs[sid].resident_bytes
                          for sid in moved if sid in pod.slice_jobs)
        victims = [pod.slice_jobs[sid] for sid in moved
                   if sid in pod.slice_jobs
                   and not pod.slice_jobs[sid].finished]
        return self._charge_migration(pod, moved_bytes, victims, t)

    def _charge_migration(self, pod: PodState, moved_bytes: int,
                          victims: Sequence[JobRecord], t: float) -> float:
        """Price ``moved_bytes`` over the pod's host links and stretch the
        given running records by the resulting delay — the single pricing
        path for in-pod repack, shrink, and grow migrations."""
        txn_touch(self, pod)
        t_mig = moved_bytes / self._pod_host_bw
        self._migrated_bytes += moved_bytes
        self._migration_s += t_mig
        if t_mig > 0:
            for r in victims:
                pod.sim.delay(r.job.job_id, t_mig)
                if self._is_fixed(r):
                    r.finish_s += t_mig
                    r.version += 1
                    self._push(r.finish_s, FINISH, (r, r.version))
            if not self.frozen_durations:
                self._resync(pod, t)
        return t_mig

    def _reissue_after_resize(self, pod: PodState, rec: JobRecord,
                              t: float) -> None:
        """Frozen durations never self-re-project, but a resize re-bases
        the remaining frozen wall time — re-issue the finish event."""
        if not (self.frozen_durations and rec.job.duration_s is None):
            return
        txn_touch(self, pod)
        fin = pod.sim.projected_finish(rec.job.job_id, t)
        if fin != rec.finish_s:
            rec.finish_s = fin
            rec.version += 1
            self._push(fin, FINISH, (rec, rec.version))

    # ------------------------------------------------------------------
    # elastic grow sweep (the Grow action, after completions and — under
    # the look-ahead policy — after rescue plans that freed chips)
    # ------------------------------------------------------------------
    def _grow_into_free(self, pod: PodState, t: float) -> None:
        """Let running progress jobs absorb still-free neighbouring chips.
        Deterministic order (job id); each job takes at most one grow per
        sweep."""
        for rec in sorted(pod.jobs.values(), key=lambda r: r.job.job_id):
            if rec.executed or rec.finished or rec.job.duration_s is not None:
                continue   # pinned wall-clock jobs gain nothing from chips
            act = Grow.find(self, pod, rec, t, record=False)
            if act is not None:
                act.apply(self, t, record=False)

    # ------------------------------------------------------------------
    # live serving execution
    # ------------------------------------------------------------------
    def _start_tenant(self, rec: JobRecord, pod: PodState,
                      cand: Candidate) -> int:
        """Admit the serving job as a real SliceRuntime tenant (reduced-scale
        config on the host backend, same profile and origin the scheduler
        chose) and drain its requests through the live engine."""
        from repro.configs import get_config
        from repro.serving import Request, TenantSpec
        job = rec.job
        cfg = get_config(job.arch).reduced().with_(remat="none")
        tenant = pod.runtime.add_tenant(TenantSpec(
            name=job.tag, cfg=cfg, profile=cand.profile,
            origin=cand.origin, slots=self.serving_slots,
            max_seq=self.serving_max_seq, seed=job.job_id))
        if job.requests:
            rng = np.random.default_rng(1000 + job.job_id)
            reqs = [Request(i, rng.integers(
                        0, cfg.vocab_size,
                        size=int(rng.integers(4, 9))).astype(np.int32),
                        self.serving_max_new)
                    for i in range(job.requests)]
            pod.runtime.submit(job.tag, reqs)
            while not tenant.engine.idle:
                tenant.engine.tick()
            rec.tokens_out = tenant.engine.stats.tokens_out
        return tenant.alloc.slice_id
