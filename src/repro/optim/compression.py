"""Gradient compression for the cross-pod (DCN) reduction.

The pod axis crosses data-center network, ~10× slower than ICI, so the
multi-pod train step optionally compresses gradients before the cross-pod
sync: int8 block quantization with error feedback (the quantization residual
is added back into the next step's gradient, keeping the optimizer unbiased
in expectation — standard EF-SGD construction).

``cross_pod_sync`` runs as a shard_map over ONLY the "pod" axis (data/model
stay under automatic GSPMD partitioning), so the compressed all-gather is
explicit in the HLO and its byte reduction is measurable in the dry-run
(benchmarks/bench_compression.py compares collective bytes on/off).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256  # quantization block (last-dim groups)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 along the LAST dim (shape-preserving up to
    last-dim padding — leading dims keep their sharding; a flatten-based
    quantizer forces GSPMD to replicate the whole gradient)."""
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf[None]
    last = xf.shape[-1]
    pad = (-last) % BLOCK
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, pad)])
    blocks = xf.reshape(xf.shape[:-1] + (xf.shape[-1] // BLOCK, BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, size: int
                    ) -> jnp.ndarray:
    full = (q.astype(jnp.float32) * scale)
    full = full.reshape(full.shape[:-2] + (full.shape[-2] * BLOCK,))
    last = shape[-1] if len(shape) else 1
    if full.shape[-1] != last:
        full = full[..., :last]
    return full.reshape(shape)


def compress_residual(x: jnp.ndarray, err: jnp.ndarray
                      ) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    """Error-feedback quantization: q(x + err), new_err = (x+err) - deq."""
    target = x.astype(jnp.float32) + err
    q, s = quantize_int8(target)
    deq = dequantize_int8(q, s, x.shape, x.size)
    return (q, s), target - deq


def cross_pod_sync(grads: PyTree, err: PyTree, mesh, *, compress: bool = True
                   ) -> Tuple[PyTree, PyTree]:
    """Mean-reduce grads across the "pod" mesh axis.

    With compress=True: per-pod int8(+EF) quantization, all-gather of the
    compressed payload over "pod", local dequant-sum — 4× fewer DCN bytes
    than an fp32 all-reduce. Without: plain psum.
    """
    from jax.sharding import PartitionSpec as P

    if "pod" not in mesh.axis_names:
        return grads, err
    npods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]

    def sync_leaf(g, e):
        if not compress:
            return jax.lax.pmean(g, "pod"), e
        (q, s), new_e = compress_residual(g, e)
        q_all = jax.lax.all_gather(q, "pod")       # (npods, nblk, BLOCK) int8
        s_all = jax.lax.all_gather(s, "pod")
        total = sum(dequantize_int8(q_all[i], s_all[i], g.shape, g.size)
                    for i in range(npods))
        return (total / npods).astype(g.dtype), new_e

    def inner(gs, es):
        flat_g, td = jax.tree_util.tree_flatten(gs)
        flat_e = td.flatten_up_to(es)
        out = [sync_leaf(g, e) for g, e in zip(flat_g, flat_e)]
        return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])

    spec = P()  # replicated over pod inside; data/model stay automatic
    try:
        fn = jax.shard_map(inner, mesh=mesh, in_specs=(spec, spec),
                           out_specs=(spec, spec), axis_names={"pod"},
                           check_vma=False)
    except AttributeError:
        # older jax: experimental shard_map (check_vma named check_rep).
        # With replicated in/out specs full-manual mode is equivalent to
        # manual-over-"pod"; partial-auto crashes old XLA's partitioner.
        from jax.experimental.shard_map import shard_map as _shard_map
        fn = _shard_map(inner, mesh=mesh, in_specs=(spec, spec),
                        out_specs=(spec, spec), check_rep=False)
    return fn(grads, err)


def init_error_feedback(grads_like: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
