"""repro.optim"""
