"""AdamW with cosine schedule and global-norm clipping (pytree-native).

Optimizer state shares the parameter sharding specs (FSDP: moments shard with
their parameters), and is the prime offloading target of the planner — the
coldest large state in training (touched exactly once per step).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def state_specs(param_specs: PyTree) -> "AdamWState":
    """Sharding specs mirroring init()'s structure."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), mu=param_specs, nu=param_specs)


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(cfg: AdamWConfig, grads: PyTree, state: AdamWState, params: PyTree
           ) -> Tuple[PyTree, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, state.step)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step_vec + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
