"""Jit'd public wrappers for the Pallas kernels.

On non-TPU backends the wrappers run the kernels in interpret mode (Python
emulation of the kernel body — bit-accurate block semantics, no Mosaic), so
the whole test suite exercises the real kernel code on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import ssd_scan as _ssd
from repro.kernels import stream_matmul as _sm


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q, k, v: (B, S, H, hd) — heads are folded/unfolded here."""
    B, S, H, hd = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, t.shape[1], hd)
    out = _fa.flash_attention_fwd(
        fold(q), fold(k), fold(v), causal=causal,
        block_q=block_q, block_k=block_k, interpret=not _on_tpu())
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention_grads(q, k, v, dout, *, causal: bool = True,
                          block_q: int = 128, block_k: int = 128):
    """Full flash backward via the Pallas kernels.
    q, k, v, dout: (BH, S, hd). Returns (out, dq, dk, dv)."""
    interp = not _on_tpu()
    out, lse = _fa.flash_attention_fwd_stats(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interp)
    dq, dk, dv = _fa.flash_attention_bwd(
        q, k, v, out, lse, dout, causal=causal, block_q=block_q,
        block_k=block_k, interpret=interp)
    return out, dq, dk, dv


@functools.partial(jax.jit, static_argnames=("chunk", "nh_block"))
def ssd(x, dt, A, B_, C_, *, chunk: int = 128, nh_block: int = 4):
    return _ssd.ssd_scan(x, dt, A, B_, C_, chunk=chunk, nh_block=nh_block,
                         interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_k"))
def grouped_matmul(x, w, *, block_c: int = 128, block_f: int = 128,
                   block_k: int = 128):
    return _gmm.grouped_matmul(x, w, block_c=block_c, block_f=block_f,
                               block_k=block_k, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def stream_matmul(x, w, *, block_m: int = 128, block_n: int = 128,
                  block_k: int = 512):
    return _sm.stream_matmul(x, w, block_m=block_m, block_n=block_n,
                             block_k=block_k, interpret=not _on_tpu())
