"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

The XLA-level SSD in ``repro.models.ssm`` materializes (B, nc, Q, Q, nh)
decay/score tensors in HBM — the dominant memory-roofline cost of the SSM
archs. This kernel fuses the whole chunk computation in VMEM: the (Q, Q)
intra-chunk matrices never leave the core, and the recurrent (nh_b, hp, N)
state is carried in fp32 VMEM scratch across the sequential chunk dimension
of the grid (TPU grids iterate the last axis innermost, so scratch persists
chunk-to-chunk for a fixed (batch, head-block)).

Grid: (B, nh_blocks, n_chunks). Per-step VMEM at (Q=128, nh_b=4, hp=64,
N=128): x 128 KiB + B/C 128 KiB + intra (Q,Q,nh_b) fp32 256 KiB + state
128 KiB — comfortably inside VMEM.

Oracle: ``repro.kernels.ref.ssd_ref`` (naive sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int, nh_b: int, hp: int, n_state: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)        # (Q, nh_b, hp)
    dt = dt_ref[0].astype(jnp.float32)      # (Q, nh_b)
    A = a_ref[0].astype(jnp.float32)        # (nh_b,)
    Bm = b_ref[0].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)       # (Q, N)

    dA = dt * A[None, :]                    # (Q, nh_b), negative
    cum = jnp.cumsum(dA, axis=0)            # within-chunk cumulative decay
    seg_total = cum[-1, :]                  # (nh_b,)

    # ---- intra-chunk (matmul form) ----
    # L[i,j,h] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None, :] - cum[None, :, :]            # (Q, Q, nh_b)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (rows >= cols)[:, :, None]
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    M = jnp.where(causal, G[:, :, None] * jnp.exp(diff), 0.0)    # (Q, Q, nh_b)
    xdt = x * dt[:, :, None]                                     # (Q, nh_b, hp)
    y = jnp.einsum("qkh,khp->qhp", M, xdt)

    # ---- inter-chunk: contribution of the carried state ----
    state = state_scr[...]                                       # (nh_b, hp, N)
    y += jnp.einsum("qn,hpn,qh->qhp", Cm, state, jnp.exp(cum))

    # ---- state update ----
    decay_to_end = jnp.exp(seg_total[None, :] - cum) * dt        # (Q, nh_b)
    upd = jnp.einsum("qn,qh,qhp->hpn", Bm, decay_to_end, x)
    state_scr[...] = state * jnp.exp(seg_total)[:, None, None] + upd

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, B_, C_, *, chunk: int = 128, nh_block: int = 4,
             interpret: bool = False):
    """x: (B, S, nh, hp); dt: (B, S, nh) (softplus-ed); A: (nh,) negative;
    B_, C_: (B, S, N). Returns y: (B, S, nh, hp). S % chunk == 0."""
    Bb, S, nh, hp = x.shape
    N = B_.shape[-1]
    nh_block = min(nh_block, nh)
    assert S % chunk == 0 and nh % nh_block == 0, (S, chunk, nh, nh_block)
    grid = (Bb, nh // nh_block, S // chunk)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nh_b=nh_block,
                               hp=hp, n_state=N)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, nh_block, hp),
                         lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, nh_block), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, nh_block), lambda b, h, c: (0, h)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, nh_block, hp),
                               lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, S, nh, hp), x.dtype),
        scratch_shapes=[pltpu.VMEM((nh_block, hp, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A[None, :], B_, C_)
