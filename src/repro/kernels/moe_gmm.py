"""Pallas TPU grouped matmul for MoE expert FFNs.

Computes out[e] = x[e] @ w[e] for E experts over capacity-padded buffers
(E, C, d) × (E, d, f) → (E, C, f) — the compute core of the capacity-based
dispatch in ``repro.models.moe``. Grid: (E, C/bc, f/bf, d/bd) with the
contraction dim innermost, fp32 accumulation in VMEM scratch, MXU-aligned
128-multiple tiles. The weight blocks stream HBM→VMEM through the grid
pipeline — with expert weights spilled to host memory by the offload planner,
the same pipeline hides the host link behind the matmul (paper §VI-A,
TPU-idiomatic form).

Oracle: ``repro.kernels.ref.gmm_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr, *, k_blocks: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]                      # (bc, bk)
    w = w_ref[0]                      # (bk, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == k_blocks - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def grouped_matmul(x, w, *, block_c: int = 128, block_f: int = 128,
                   block_k: int = 128, interpret: bool = False):
    """x: (E, C, d) capacity buffers; w: (E, d, f) expert weights."""
    E, C, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    block_k = min(block_k, d)
    assert C % block_c == 0 and f % block_f == 0 and d % block_k == 0, \
        (C, d, f, block_c, block_k, block_f)
    grid = (E, C // block_c, f // block_f, d // block_k)

    kernel = functools.partial(_gmm_kernel, k_blocks=d // block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_k),
                         lambda e, ic, jf, ik: (e, ic, ik)),
            pl.BlockSpec((1, block_k, block_f),
                         lambda e, ic, jf, ik: (e, ik, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ic, jf, ik: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
