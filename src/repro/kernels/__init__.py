"""repro.kernels: Pallas TPU kernels (+ ops wrappers, ref oracles)."""
