"""Pallas TPU streaming matmul — the offload-path compute pattern.

The paper's §III-D finding on Grace Hopper is that *direct access* (compute
units touching CPU memory) beats copy-engine transfers. TPUs have no
load/store path to host DRAM, so the TPU-idiomatic equivalent (DESIGN.md §2)
is a weight-STREAMING matmul: weights live one tier down (host DRAM via
``pinned_host``; HBM in this kernel's tiling), and blocks are double-buffered
into VMEM by the Pallas grid pipeline while the MXU works on the previous
block. The kernel is the structural template: on hardware, the same BlockSpec
pipeline drives host→HBM→VMEM DMA chains for offloaded weights.

Used by the offloaded-serving example to bound the achievable overlap, and
micro-benchmarked in benchmarks/bench_kernels.py.

Oracle: ``repro.kernels.ref.matmul_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stream_kernel(x_ref, w_ref, o_ref, acc_scr, *, k_blocks: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == k_blocks - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def stream_matmul(x, w, *, block_m: int = 128, block_n: int = 128,
                  block_k: int = 512, interpret: bool = False):
    """x: (M, K) activations (resident); w: (K, N) streamed weights."""
    M, K = x.shape
    _, N = w.shape
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    grid = (M // block_m, N // block_n, K // block_k)

    kernel = functools.partial(_stream_kernel, k_blocks=K // block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda im, jn, ik: (im, ik)),
            pl.BlockSpec((block_k, block_n), lambda im, jn, ik: (ik, jn)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda im, jn, ik: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w)
