"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """Naive softmax attention. q, k, v: (BH, S, hd)."""
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Sk = s.shape[1], s.shape[2]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, A, B_, C_):
    """Naive sequential SSD recurrence (fp32).
    x: (B,S,nh,hp); dt: (B,S,nh); A: (nh,); B_, C_: (B,S,N)."""
    Bb, S, nh, hp = x.shape
    N = B_.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B_.astype(jnp.float32)
    Cf = C_.astype(jnp.float32)

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp           # (B,nh,hp), (B,nh), (B,N), (B,N)
        decay = jnp.exp(dt_t * Af[None, :])  # (B,nh)
        upd = jnp.einsum("bn,bh,bhp->bhpn", b_t, dt_t, x_t)
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y

    s0 = jnp.zeros((Bb, nh, hp, N), jnp.float32)
    _, ys = jax.lax.scan(step, s0, (jnp.moveaxis(xf, 1, 0),
                                    jnp.moveaxis(dtf, 1, 0),
                                    jnp.moveaxis(Bf, 1, 0),
                                    jnp.moveaxis(Cf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,nh,hp)


def gmm_ref(x, w):
    """x: (E, C, d); w: (E, d, f)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def matmul_ref(x, w):
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)
