"""Pallas TPU flash attention (forward), MXU-tiled, online softmax.

Grid: (batch*heads, q_blocks, kv_blocks) — the kv dim is innermost, so on TPU
it executes sequentially per (bh, q_block) and the fp32 running max / sum /
accumulator live in VMEM scratch across kv steps. Block shapes are multiples
of 128 on the matmul dims to keep the MXU systolic array full; K/V blocks are
pipelined HBM→VMEM by the grid (the same double-buffering structure that
serves the paper's offload streaming on real hardware).

VMEM budget per step at (block_q, block_k, hd) = (128, 128, 128), bf16 inputs:
q+k+v blocks ≈ 96 KiB, s/p ≈ 64 KiB fp32, scratch ≈ 65 KiB fp32 → well under
the ~16 MiB/core VMEM with double-buffering headroom.

Validated against ``repro.kernels.ref.attention_ref`` in interpret mode
(tests/test_kernels.py sweeps shapes and dtypes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # under causality, blocks fully above the diagonal contribute nothing
    needed = jnp.asarray(True) if not causal else (k_start <= q_start + block_q - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq,bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * corr[:, None] +
                        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd_stats(q, k, v, *, causal: bool = True, scale=None,
                              block_q: int = 128, block_k: int = 128,
                              interpret: bool = False):
    """Forward + logsumexp stats (for the backward kernel).
    Returns (out (BH,S,hd), lse (BH,S))."""
    BH, S, hd = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    assert S % block_q == 0 and Sk % block_k == 0
    grid = (BH, S // block_q, Sk // block_k)
    kernel = functools.partial(
        _flash_stats_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, kv_blocks=Sk // block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_q), lambda bh, iq, ik: (bh, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _flash_stats_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                        acc_scr, *, scale, block_q, block_k, causal,
                        kv_blocks):
    _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  scale=scale, block_q=block_q, block_k=block_k,
                  causal=causal, kv_blocks=kv_blocks)

    @pl.when(pl.program_id(2) == kv_blocks - 1)
    def _stats():
        lse_ref[0] = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))


def _flash_bwd_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dk_ref, dv_ref, dk_scr, dv_scr, *,
                      scale: float, block_q: int, block_k: int, causal: bool,
                      q_blocks: int):
    """Backward: grid (BH, kv_block, q_block) — q innermost so dk/dv
    accumulate in VMEM scratch per kv block; dq accumulates via the output
    ref (revisited across the kv grid dim is NOT allowed, so dq uses the
    q-block output with accumulation over kv handled by re-running the kv
    loop per q block — see flash_attention_bwd which transposes the grids).
    This kernel computes dk/dv; dq comes from `_flash_dq_kernel`."""
    ik = pl.program_id(1)
    iq = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    needed = jnp.asarray(True) if not causal else (k_start <= q_start + block_q - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)                # (bq, hd)
        lse = lse_ref[0]                                  # (bq,)
        delta = delta_ref[0]                              # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                     # (bq, bk)
        dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])                    # (bq, bk)
        dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(iq == q_blocks - 1)
    def _finish():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _flash_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                     dq_ref, dq_scr, *, scale: float, block_q: int,
                     block_k: int, causal: bool, kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    needed = jnp.asarray(True) if not causal else (k_start <= q_start + block_q - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dq_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(ik == kv_blocks - 1)
    def _finish():
        dq_ref[0] = (dq_scr[...] * scale).astype(dq_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, dout, *, causal: bool = True,
                        scale=None, block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """Flash backward: (dq, dk, dv), each (BH, S, hd). ``lse`` from
    flash_attention_fwd_stats. Two pallas_calls: dk/dv with the q dim
    innermost (accumulated in VMEM), dq with the kv dim innermost."""
    BH, S, hd = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)  # (BH, S)

    kv_kernel = functools.partial(
        _flash_bwd_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, q_blocks=S // block_q)
    dk, dv = pl.pallas_call(
        kv_kernel,
        grid=(BH, Sk // block_k, S // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, ik, iq: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, ik, iq: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, ik, iq: (bh, ik, 0)),
            pl.BlockSpec((1, block_q, hd), lambda bh, ik, iq: (bh, iq, 0)),
            pl.BlockSpec((1, block_q), lambda bh, ik, iq: (bh, iq)),
            pl.BlockSpec((1, block_q), lambda bh, ik, iq: (bh, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, hd), lambda bh, ik, iq: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, ik, iq: (bh, ik, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((BH, Sk, hd), k.dtype),
                   jax.ShapeDtypeStruct((BH, Sk, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    dq_kernel = functools.partial(
        _flash_dq_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, kv_blocks=Sk // block_k)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(BH, S // block_q, Sk // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_q), lambda bh, iq, ik: (bh, iq)),
            pl.BlockSpec((1, block_q), lambda bh, iq, ik: (bh, iq)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return dq, dk, dv


def flash_attention_fwd(q, k, v, *, causal: bool = True, scale=None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q, k, v: (BH, S, hd) with heads folded into the leading dim."""
    BH, S, hd = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    assert S % block_q == 0 and Sk % block_k == 0, (S, Sk, block_q, block_k)
    grid = (BH, S // block_q, Sk // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, kv_blocks=Sk // block_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
