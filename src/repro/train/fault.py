"""Fault-tolerant training runner: checkpoint/restart, stragglers, elasticity.

Failure model (documented; the container has one CPU, so failures are
injected, not observed):
  * step failure / chip loss → restore newest complete checkpoint, ask the
    StaticPartitioner for the largest still-free slice, re-plan offloading
    for the smaller HBM pool (the paper's mechanism doubles as the
    elasticity mechanism), rebuild the step function on the new mesh, resume
    from the restored step with the deterministic pipeline's batch_at().
  * straggler → per-step deadline = straggler_factor × EWMA(step time);
    overruns are counted and surfaced; with a spare slice available the
    runner re-admits the job there (hot-spare mitigation).

The runner is deliberately synchronous/DI-friendly: failure hooks are
injectable callables so tests drive every path deterministically.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.partitioner import StaticPartitioner
from repro.core.slices import SliceProfile
from repro.train import checkpoint as ckpt

PyTree = Any


class StepFailure(Exception):
    """Raised by the step (or injected) to signal a lost chip/host."""


@dataclass
class RunnerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 25
    keep: int = 3
    straggler_factor: float = 3.0
    max_restarts: int = 3


@dataclass
class RunnerStats:
    steps_done: int = 0
    restarts: int = 0
    straggler_events: int = 0
    repartitions: List[str] = field(default_factory=list)
    losses: List[float] = field(default_factory=list)


class FaultTolerantRunner:
    """Drives (build_step, state) through failures.

    build_step(profile) -> (step_fn, state)  — rebuilds program + state for a
    slice profile (restoring params from the newest checkpoint when one
    exists). step_fn(state, batch) -> (state, metrics).
    """

    def __init__(self, cfg: RunnerConfig,
                 partitioner: StaticPartitioner,
                 initial_profile: SliceProfile,
                 build_step: Callable[[SliceProfile], Any],
                 get_batch: Callable[[int], Dict],
                 save_state: Callable[[Any], PyTree],
                 fail_hook: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.partitioner = partitioner
        self.profile = initial_profile
        self.build_step = build_step
        self.get_batch = get_batch
        self.save_state = save_state
        self.fail_hook = fail_hook or (lambda step: None)
        self.stats = RunnerStats()
        self._ewma: Optional[float] = None

    # ------------------------------------------------------------------
    def run(self, total_steps: int) -> RunnerStats:
        step_fn, state, start = self._admit(self.profile)
        step = start
        while step < total_steps:
            batch = self.get_batch(step)
            t0 = time.monotonic()
            try:
                self.fail_hook(step)  # test injection point
                state, metrics = step_fn(state, batch)
            except StepFailure:
                step_fn, state, step = self._recover()
                continue
            dt = time.monotonic() - t0
            self._track_stragglers(dt)
            self.stats.steps_done += 1
            if "loss" in metrics:
                self.stats.losses.append(float(metrics["loss"]))
            step += 1
            if step % self.cfg.ckpt_every == 0:
                ckpt.save(self.cfg.ckpt_dir, step, self.save_state(state),
                          keep=self.cfg.keep)
        ckpt.save(self.cfg.ckpt_dir, step, self.save_state(state),
                  keep=self.cfg.keep)
        return self.stats

    # ------------------------------------------------------------------
    def _admit(self, profile: SliceProfile):
        step_fn, state = self.build_step(profile)
        start = ckpt.latest_step(self.cfg.ckpt_dir) or 0
        return step_fn, state, start

    def _recover(self):
        self.stats.restarts += 1
        if self.stats.restarts > self.cfg.max_restarts:
            raise RuntimeError("restart budget exhausted")
        # elastic: take the largest profile that still fits in the pod
        new_profile = self.partitioner.largest_free_profile() or self.profile
        self.stats.repartitions.append(
            f"{self.profile.name}->{new_profile.name}")
        self.profile = new_profile
        step_fn, state = self.build_step(self.profile)
        start = ckpt.latest_step(self.cfg.ckpt_dir) or 0
        return step_fn, state, start

    def _track_stragglers(self, dt: float) -> None:
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.cfg.straggler_factor * self._ewma:
            self.stats.straggler_events += 1
        self._ewma = 0.9 * self._ewma + 0.1 * dt
