"""repro.train"""
