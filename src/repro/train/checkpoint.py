"""Sharded numpy checkpointing with manifest + atomic commit.

Layout:  <dir>/step_<N>/
           manifest.json   — step, leaf paths, shapes, dtypes, tree hash
           <idx>.npy       — one file per leaf (host-gathered)
Writes go to ``step_<N>.tmp`` then rename — a torn write can never be taken
for a valid checkpoint (restore picks the newest *complete* step). Optional
async mode hands the host copy to a writer thread so the train loop never
blocks on disk (checkpoint/restart is the fault-tolerance substrate).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _tree_paths(tree: PyTree) -> List[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
            for kp, _ in flat]


def _structure_hash(tree: PyTree) -> str:
    desc = json.dumps([(p, list(np.shape(l)), str(np.asarray(l).dtype) if not
                        hasattr(l, "dtype") else str(l.dtype))
                       for p, l in zip(_tree_paths(tree),
                                       jax.tree_util.tree_leaves(tree))])
    return hashlib.sha256(desc.encode()).hexdigest()[:16]


def volume_bytes(tree: PyTree) -> int:
    """Bytes one ``save`` writes / one ``restore`` reads for ``tree`` —
    the sum of every leaf's payload. This is the volume the cluster
    scheduler's preemption path prices over the pod's host links
    (``core.perfmodel.PerfModel.checkpoint_cost``): suspend = one
    host-gather of this many bytes, resume = the same bytes streamed
    back onto the (possibly different) slice."""
    return int(sum(np.asarray(l).nbytes
                   for l in jax.tree_util.tree_leaves(tree)))


def save(directory: str, step: int, tree: PyTree, *, keep: int = 3,
         async_: bool = False) -> str:
    leaves = jax.tree_util.tree_leaves(tree)
    paths = _tree_paths(tree)
    # host-gather while devices keep working
    host = [np.asarray(l) for l in leaves]

    def commit():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        for i, arr in enumerate(host):
            np.save(os.path.join(tmp, f"{i}.npy"), arr)
        manifest = {
            "step": step,
            "paths": paths,
            "hash": _structure_hash(tree),
            "n_leaves": len(host),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep)

    if async_:
        t = threading.Thread(target=commit, daemon=True)
        t.start()
        return f"async:{step}"
    commit()
    return os.path.join(directory, f"step_{step:08d}")


def _gc(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, tree_like: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, int]:
    """Restore into the structure of ``tree_like`` (validates the manifest
    hash). ``shardings`` re-places leaves (supports restoring onto a
    DIFFERENT slice/mesh than the one that saved — elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest["hash"] != _structure_hash(tree_like):
        raise ValueError("checkpoint structure mismatch (wrong config?)")
    host = [np.load(os.path.join(d, f"{i}.npy"))
            for i in range(manifest["n_leaves"])]
    treedef = jax.tree_util.tree_structure(tree_like)
    tree = jax.tree_util.tree_unflatten(treedef, host)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step
