"""Train-step factory: microbatched grad accumulation, donation, shardings.

The produced step is a single jit'd function
    (params, opt_state, batch [, err]) -> (params, opt_state, metrics [, err])
with in/out shardings derived from the model's spec tree (FSDP × TP per
DESIGN.md §5), buffers donated, bf16 compute / fp32 master params, optional
int8+EF gradient compression across the "pod" axis.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model_zoo import Model
from repro.optim import adamw
from repro.optim.compression import cross_pod_sync

PyTree = Any


@dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    grad_compression: bool = False   # int8+EF across the pod axis
    opt: adamw.AdamWConfig = adamw.AdamWConfig()


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        return model.loss_fn(params, batch)
    return loss_fn


def _accumulate_grads(model: Model, params, batch, microbatches: int):
    """lax.scan over microbatches; batch leading dim must divide evenly."""
    loss_fn = make_loss_fn(model)
    if microbatches <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def reshape(x, axis=0):
        b = x.shape[axis]
        assert b % microbatches == 0, (b, microbatches)
        x = x.reshape(x.shape[:axis] + (microbatches, b // microbatches)
                      + x.shape[axis + 1:])
        return jnp.moveaxis(x, axis, 0)

    # batch dims: "positions" is (3, B, S) — batch on axis 1 (M-RoPE streams)
    mb = {k: reshape(v, 1 if k == "positions" else 0)
          for k, v in batch.items()}

    def body(carry, one):
        loss_acc, grads_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, one)
        grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
        return (loss_acc + loss, grads_acc), None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), mb)
    scale = 1.0 / microbatches
    return loss * scale, jax.tree_util.tree_map(lambda g: g * scale, grads)


def make_train_step(model: Model, mesh, cfg: TrainStepConfig,
                    batch_specs: PyTree):
    """Returns (jit_step, state_shardings). ``batch_specs``: PartitionSpec
    tree for the batch dict (from Model.batch_specs)."""
    _, param_specs = model.init(None, abstract=True)
    compress = cfg.grad_compression and "pod" in mesh.axis_names

    sh = lambda spec_tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
    params_sh = sh(param_specs)
    opt_sh = adamw.AdamWState(step=NamedSharding(mesh, P()),
                              mu=sh(param_specs), nu=sh(param_specs))
    batch_sh = sh(batch_specs)
    metrics_sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), {"loss": 0, "grad_norm": 0, "lr": 0})

    if compress:
        # NOTE (documented limitation, EXPERIMENTS §Dry-run): ideally the
        # gradient computation would run inside a shard_map over "pod" so the
        # autodiff-inserted pod reduction disappears and ONLY the int8+EF
        # all-gather crosses DCN. jax 0.8 cannot express that here: the
        # model's internal sharding constraints use P(("pod","data"), …)
        # tuples, and a manual "pod" axis may not mix with auto axes in one
        # PartitionSpec dim. The compressed sync therefore runs *after* the
        # (redundant) automatic reduction in this build; the primitive itself
        # is verified to cut cross-pod bytes 4× in isolation
        # (tests/test_sharding.py::test_compressed_grad_sync_reduces_dcn_bytes).
        def step(params, opt_state, batch, err):
            loss, grads = _accumulate_grads(model, params, batch,
                                            cfg.microbatches)
            grads, err = cross_pod_sync(grads, err, mesh, compress=True)
            new_params, new_opt, metrics = adamw.update(cfg.opt, grads,
                                                        opt_state, params)
            metrics["loss"] = loss
            return new_params, new_opt, metrics, err

        jit_step = jax.jit(
            step,
            in_shardings=(params_sh, opt_sh, batch_sh, params_sh),
            out_shardings=(params_sh, opt_sh, metrics_sh, params_sh),
            donate_argnums=(0, 1, 3))
    else:
        def step(params, opt_state, batch):
            loss, grads = _accumulate_grads(model, params, batch,
                                            cfg.microbatches)
            new_params, new_opt, metrics = adamw.update(cfg.opt, grads,
                                                        opt_state, params)
            metrics["loss"] = loss
            return new_params, new_opt, metrics

        jit_step = jax.jit(
            step,
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, metrics_sh),
            donate_argnums=(0, 1))
    return jit_step, {"params": params_sh, "opt": opt_sh, "batch": batch_sh,
                      "compress": compress}


def make_eval_step(model: Model, mesh, batch_specs: PyTree):
    _, param_specs = model.init(None, abstract=True)
    sh = lambda spec_tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

    def step(params, batch):
        return model.loss_fn(params, batch)

    return jax.jit(step, in_shardings=(sh(param_specs), sh(batch_specs)),
                   out_shardings=NamedSharding(mesh, P()))
