"""Paper §IV (Figs. 2-4): derived utilization per workload + the
performance–resource scaling curves across slice profiles.

All numbers are roofline-model estimates (CPU-only container) — the same
estimator that drives the reward metric; the dry-run table in EXPERIMENTS.md
§Roofline anchors the full-pod points against compiled HLO.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs import ASSIGNED_ARCHS, get_config, get_shape
from repro.configs.shapes import applicable
from repro.core.slices import PROFILES
from repro.core.utilization import scaling_curve, utilization_on
from repro.core.workload import WorkloadEstimate

WORKLOADS = [(a, s) for a in ASSIGNED_ARCHS
             for s in ("train_4k", "decode_32k")]


def run() -> None:
    # Fig. 2/3 analogue: utilization on the smallest fitting slice
    for arch, shape_name in WORKLOADS:
        cfg = get_config(arch)
        shape = get_shape(shape_name)
        if not applicable(cfg, shape)[0]:
            continue
        wl = WorkloadEstimate(cfg, shape)
        with timed() as t:
            rep = None
            for prof in PROFILES:
                rep = utilization_on(wl, prof)
                if rep is not None:
                    break
        if rep is None:
            emit(f"fig2-3/{arch}/{shape_name}", t["us"], "does-not-fit-any")
            continue
        emit(f"fig2-3/{arch}/{shape_name}", t["us"],
             f"slice={rep.profile} u_compute={rep.u_compute:.2f} "
             f"u_bw={rep.u_bandwidth:.2f} u_cap={rep.u_capacity:.2f} "
             f"dominant={rep.dominant} offloaded={rep.offloaded_bytes > 0}")

    # Fig. 4 analogue: perf-resource scaling normalized to smallest fit
    for arch, shape_name in WORKLOADS:
        cfg = get_config(arch)
        shape = get_shape(shape_name)
        if not applicable(cfg, shape)[0]:
            continue
        wl = WorkloadEstimate(cfg, shape)
        with timed() as t:
            curve = scaling_curve(wl)
        pts = [(r["profile"], r["rel_perf"], r["ideal"])
               for r in curve if r.get("fits")]
        if not pts:
            continue
        last = pts[-1]
        cls = ("ideal" if last[1] > 0.8 * last[2] else
               "sublinear" if last[1] > 0.35 * last[2] else "poor")
        emit(f"fig4/{arch}/{shape_name}", t["us"],
             f"class={cls} " + " ".join(
                 f"{p}:{rp:.2f}/{ideal:.0f}" for p, rp, ideal in pts))
