"""Perf-regression gate for the cluster scheduler's hot path.

Replays the committed ``benchmarks/BENCH_cluster.json`` regime fresh (same
seed, pods, interarrival — by default the baseline's own ``--scale``) and
fails when throughput regresses by more than 25%:

    PYTHONPATH=src python -m benchmarks.check_perf
    PYTHONPATH=src python -m benchmarks.check_perf --scale 2000 --min-ratio 0.5

Two gates, in order:

1. **Determinism** — the fresh run replays the *identical* seeded trace,
   so when the scale matches the baseline's, ``completed``/``makespan_s``
   must be bit-identical. A mismatch means a scheduling *decision*
   changed, which the timeline-sha tests pin at small scale and this gate
   re-checks at baseline scale.
2. **Throughput** — fresh jobs/sec must be ≥ ``--min-ratio`` (default
   0.75) of the committed baseline's. CI runners are noisy; 25% headroom
   passes machine-to-machine jitter but catches a hot path falling off a
   complexity cliff (the O(pod) snapshot-per-probe regime this PR
   retired was ~15× off, not 25% off).

Four companion gates follow: the autoscale day-in-the-life record
(``BENCH_autoscale.json``), the search-policy record
(``BENCH_search.json`` — showcase verdicts, the ``--policy search``
replay, and the look-ahead probe-cache A/B whose priced-probe drop must
stay >= 3x), the twin-offload record (``BENCH_twin.json`` — showcase
verdicts plus a twin-on replay whose throughput must stay within 0.75x
of a fresh twin-off replay), and the partition-reconfiguration record
(``BENCH_reconfig.json`` — the MI300 mode-switch showcase verdicts plus
an MI300 replay whose throughput must stay within 0.75x of a fresh v5e
replay). All hold their decision fields bit-exact and their throughput
within a generous ratio.

Refreshing the baselines after an intentional perf change:

    PYTHONPATH=src python -m benchmarks.bench_cluster --scale 10000 \
        --json benchmarks/BENCH_cluster.json
    PYTHONPATH=src python -m benchmarks.bench_cluster --search-scale 10000 \
        --json benchmarks/BENCH_search.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):   # `python benchmarks/check_perf.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

from benchmarks.bench_cluster import run_scale, run_search, run_twin
from benchmarks.bench_autoscale import run_baseline as run_autoscale_baseline
from benchmarks.bench_reconfig import SCALE_ACTIONS as RECONFIG_ACTIONS
from benchmarks.bench_reconfig import run_reconfig
from repro.cluster import PolicySpec

BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_cluster.json")
AUTOSCALE_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                  "BENCH_autoscale.json")
SEARCH_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               "BENCH_search.json")
TWIN_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_twin.json")
RECONFIG_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "BENCH_reconfig.json")

# a diverged value here means an autoscale *decision* changed, not speed
_AUTOSCALE_EXACT_KEYS = ("fixed_chip_hours", "fixed_slo_hit_rate",
                         "auto_chip_hours", "auto_slo_hit_rate",
                         "auto_p99_s", "resizes", "grows", "shrinks",
                         "migrations")


def check_autoscale(baseline_path: str, min_ratio: float) -> bool:
    """The autoscale day-in-the-life gate: bit-exact decisions (chip-hours,
    SLO hit rates, resize counts) plus a generous control-loop throughput
    ratio. Refresh after an intentional change with
    ``python -m benchmarks.bench_autoscale --json <path>``."""
    with open(baseline_path) as fh:
        base = json.load(fh)
    fresh = run_autoscale_baseline(seed=base["seed"])
    print(f"autoscale baseline: {base['auto_chip_hours']:,} chip-hours "
          f"(fixed {base['fixed_chip_hours']:,}), "
          f"{base['resizes']} resizes, "
          f"{base['intervals_per_s']:,} intervals/s")
    print(f"autoscale fresh:    {fresh['auto_chip_hours']:,} chip-hours "
          f"(fixed {fresh['fixed_chip_hours']:,}), "
          f"{fresh['resizes']} resizes, "
          f"{fresh['intervals_per_s']:,} intervals/s")
    ok = True
    for key in _AUTOSCALE_EXACT_KEYS:
        if fresh[key] != base[key]:
            print(f"FAIL: autoscale {key} diverged from the committed "
                  f"baseline ({fresh[key]!r} != {base[key]!r}) — a "
                  f"control decision changed, not just its speed")
            ok = False
    ratio = fresh["intervals_per_s"] / base["intervals_per_s"]
    print(f"autoscale ratio:    {ratio:.2f} (gate: >= {min_ratio})")
    if ratio < min_ratio:
        print(f"FAIL: control-loop throughput regressed to {ratio:.0%} "
              f"of baseline (gate {min_ratio:.0%})")
        ok = False
    return ok


# a diverged value here means a *scheduling decision* changed under the
# search policy or the probe cache, not speed — these replay bit-exactly
_SEARCH_EXACT_KEYS = ("completed", "makespan_s", "probes_priced",
                      "probe_cache_hits")


def check_search(baseline_path: str, min_ratio: float,
                 min_probe_drop: float) -> bool:
    """The search-policy gate: the showcase verdicts and every replay
    count must match the committed ``BENCH_search.json`` bit-exactly
    (search run + look-ahead probe-cache A/B), fresh search throughput
    must hold ``min_ratio``, and the probe cache must keep cutting the
    look-ahead's priced probes by ``min_probe_drop``x. Refresh after an
    intentional change with ``python -m benchmarks.bench_cluster
    --search-scale <N> --json <path>``."""
    with open(baseline_path) as fh:
        base = json.load(fh)
    fresh = run_search(base["scale"], pods=base["pods"],
                       mean_interarrival_s=base["mean_interarrival_s"],
                       seed=base["seed"])
    print(f"search baseline: {base['search']['jobs_per_s']:,.0f} jobs/s, "
          f"{base['search']['probes_priced']:,} probes priced, "
          f"probe drop {base['probe_drop_ratio']}x")
    print(f"search fresh:    {fresh['search']['jobs_per_s']:,.0f} jobs/s, "
          f"{fresh['search']['probes_priced']:,} probes priced, "
          f"probe drop {fresh['probe_drop_ratio']}x")
    ok = True
    if fresh["showcase"] != base["showcase"]:
        print(f"FAIL: search showcase verdicts diverged from the "
              f"committed baseline ({fresh['showcase']!r} != "
              f"{base['showcase']!r})")
        ok = False
    for run_key in ("search", "lookahead_cache_on", "lookahead_cache_off"):
        for key in _SEARCH_EXACT_KEYS:
            if fresh[run_key][key] != base[run_key][key]:
                print(f"FAIL: search {run_key}.{key} diverged from the "
                      f"committed baseline ({fresh[run_key][key]!r} != "
                      f"{base[run_key][key]!r}) — a scheduling decision "
                      f"changed, not just its speed")
                ok = False
    ratio = fresh["search"]["jobs_per_s"] / base["search"]["jobs_per_s"]
    print(f"search ratio:    {ratio:.2f} (gate: >= {min_ratio})")
    if ratio < min_ratio:
        print(f"FAIL: search throughput regressed to {ratio:.0%} of "
              f"baseline (gate {min_ratio:.0%})")
        ok = False
    if fresh["probe_drop_ratio"] < min_probe_drop:
        print(f"FAIL: probe cache cuts the look-ahead's priced probes by "
              f"only {fresh['probe_drop_ratio']}x "
              f"(gate >= {min_probe_drop}x)")
        ok = False
    return ok


# a diverged value here means a twin-on *scheduling decision* changed —
# the replay is a pure function of (scale, pods, interarrival, seed)
_TWIN_EXACT_KEYS = ("completed", "makespan_s", "probes")


def check_twin(baseline_path: str, min_ratio: float) -> bool:
    """The twin-offload gate: the showcase verdicts (twin off → miss,
    twin on → hit on the "+cpuX.XX" rung) and the twin-on replay's
    count/timeline fields must match the committed ``BENCH_twin.json``
    bit-exactly, and twin-on throughput must hold ``min_ratio`` of a
    *fresh* twin-off replay of the same trace (both runs on this
    machine, so the ratio is jitter-proof: it bounds the pricing cost
    of the extra rungs, not machine speed). Refresh after an
    intentional change with ``python -m benchmarks.bench_cluster
    --twin-scale <N> --json <path>``."""
    with open(baseline_path) as fh:
        base = json.load(fh)
    fresh = run_twin(base["scale"], pods=base["pods"],
                     mean_interarrival_s=base["mean_interarrival_s"],
                     seed=base["seed"])
    off = run_scale(base["scale"], pods=base["pods"],
                    mean_interarrival_s=base["mean_interarrival_s"],
                    seed=base["seed"])
    print(f"twin baseline: on {base['twin_on']['jobs_per_s']:,.0f} jobs/s, "
          f"showcase off={'hit' if base['showcase']['off']['slo_hit'] else 'miss'} "
          f"on={'hit' if base['showcase']['on']['slo_hit'] else 'miss'} "
          f"rung={base['showcase']['on']['rung']}")
    print(f"twin fresh:    on {fresh['twin_on']['jobs_per_s']:,.0f} jobs/s, "
          f"off {off['jobs_per_s']:,.0f} jobs/s")
    ok = True
    if fresh["showcase"] != base["showcase"]:
        print(f"FAIL: twin showcase verdicts diverged from the committed "
              f"baseline ({fresh['showcase']!r} != {base['showcase']!r})")
        ok = False
    for key in _TWIN_EXACT_KEYS:
        if fresh["twin_on"][key] != base["twin_on"][key]:
            print(f"FAIL: twin twin_on.{key} diverged from the committed "
                  f"baseline ({fresh['twin_on'][key]!r} != "
                  f"{base['twin_on'][key]!r}) — a scheduling decision "
                  f"changed, not just its speed")
            ok = False
    ratio = fresh["twin_on"]["jobs_per_s"] / off["jobs_per_s"]
    print(f"twin ratio:    {ratio:.2f} on/off (gate: >= {min_ratio})")
    if ratio < min_ratio:
        print(f"FAIL: twin pricing costs {1 - ratio:.0%} of twin-off "
              f"throughput (gate: within {1 - min_ratio:.0%})")
        ok = False
    return ok


# a diverged value here means an MI300 *scheduling decision* changed —
# the replay is a pure function of (scale, pods, interarrival, seed, mode)
_RECONFIG_EXACT_KEYS = ("completed", "makespan_s", "reconfigs",
                        "migrations", "slo_attainment")


def check_reconfig(baseline_path: str, min_ratio: float) -> bool:
    """The partition-reconfiguration gate: the mode-switch showcase
    verdicts (reconfigure off → miss, on → hit in cpx-nps4) and the
    MI300 replay's decision fields must match the committed
    ``BENCH_reconfig.json`` bit-exactly, and MI300 throughput must hold
    ``min_ratio`` of a fresh v5e replay of the same trace under the same
    action allowlist (both runs on this machine, so the ratio bounds the
    cost of the mode machinery — heterogeneous candidate scans, mode-keyed
    memo keys — not machine speed). Refresh after an intentional change
    with ``python -m benchmarks.bench_reconfig --scale <N> --json
    <path>``."""
    with open(baseline_path) as fh:
        base = json.load(fh)
    fresh = run_reconfig(base["scale"], pods=base["pods"],
                         mean_interarrival_s=base["mean_interarrival_s"],
                         seed=base["seed"])
    v5e = run_scale(base["scale"], pods=base["pods"],
                    mean_interarrival_s=base["mean_interarrival_s"],
                    seed=base["seed"],
                    spec=PolicySpec(actions=RECONFIG_ACTIONS))
    print(f"reconfig baseline: mi300 {base['mi300']['jobs_per_s']:,.0f} "
          f"jobs/s, showcase "
          f"off={'hit' if base['showcase']['off']['slo_hit'] else 'miss'} "
          f"on={'hit' if base['showcase']['on']['slo_hit'] else 'miss'} "
          f"modes={'/'.join(base['showcase']['on']['modes'])}")
    print(f"reconfig fresh:    mi300 {fresh['mi300']['jobs_per_s']:,.0f} "
          f"jobs/s, v5e {v5e['jobs_per_s']:,.0f} jobs/s")
    ok = True
    if fresh["showcase"] != base["showcase"]:
        print(f"FAIL: reconfigure showcase verdicts diverged from the "
              f"committed baseline ({fresh['showcase']!r} != "
              f"{base['showcase']!r})")
        ok = False
    for key in _RECONFIG_EXACT_KEYS:
        if fresh["mi300"][key] != base["mi300"][key]:
            print(f"FAIL: reconfig mi300.{key} diverged from the committed "
                  f"baseline ({fresh['mi300'][key]!r} != "
                  f"{base['mi300'][key]!r}) — a scheduling decision "
                  f"changed, not just its speed")
            ok = False
    ratio = fresh["mi300"]["jobs_per_s"] / v5e["jobs_per_s"]
    print(f"reconfig ratio:    {ratio:.2f} mi300/v5e (gate: >= {min_ratio})")
    if ratio < min_ratio:
        print(f"FAIL: the mode machinery costs {1 - ratio:.0%} of v5e "
              f"throughput (gate: within {1 - min_ratio:.0%})")
        ok = False
    return ok


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--scale", type=int, default=None,
                    help="fresh-run trace size (default: the baseline's)")
    ap.add_argument("--min-ratio", type=float, default=0.75,
                    help="fail below this fraction of baseline jobs/sec")
    ap.add_argument("--autoscale-baseline", default=AUTOSCALE_BASELINE)
    ap.add_argument("--autoscale-min-ratio", type=float, default=0.2,
                    help="control-loop throughput gate (sub-second walls "
                         "are jittery, so the band is wide; the bit-exact "
                         "keys carry the regression signal)")
    ap.add_argument("--skip-autoscale", action="store_true")
    ap.add_argument("--search-baseline", default=SEARCH_BASELINE)
    ap.add_argument("--search-min-ratio", type=float, default=0.75,
                    help="fail below this fraction of baseline search "
                         "jobs/sec")
    ap.add_argument("--min-probe-drop", type=float, default=3.0,
                    help="fail when the probe cache cuts the look-ahead "
                         "run's priced probes by less than this factor")
    ap.add_argument("--skip-search", action="store_true")
    ap.add_argument("--twin-baseline", default=TWIN_BASELINE)
    ap.add_argument("--twin-min-ratio", type=float, default=0.75,
                    help="fail when twin-on throughput falls below this "
                         "fraction of a fresh twin-off replay of the "
                         "same trace")
    ap.add_argument("--skip-twin", action="store_true")
    ap.add_argument("--reconfig-baseline", default=RECONFIG_BASELINE)
    ap.add_argument("--reconfig-min-ratio", type=float, default=0.75,
                    help="fail when MI300 throughput falls below this "
                         "fraction of a fresh v5e replay of the same "
                         "trace")
    ap.add_argument("--skip-reconfig", action="store_true")
    args = ap.parse_args()

    with open(args.baseline) as fh:
        base = json.load(fh)
    scale = args.scale if args.scale is not None else base["scale"]
    fresh = run_scale(scale, pods=base["pods"],
                      mean_interarrival_s=base["mean_interarrival_s"],
                      seed=base["seed"], placement=base["placement"])

    ratio = fresh["jobs_per_s"] / base["jobs_per_s"]
    print(f"baseline: {base['jobs_per_s']:,.0f} jobs/s "
          f"({base['scale']:,} jobs, {base['wall_s']}s wall, "
          f"{base['peak_rss_mb']} MB RSS)")
    print(f"fresh:    {fresh['jobs_per_s']:,.0f} jobs/s "
          f"({fresh['scale']:,} jobs, {fresh['wall_s']}s wall, "
          f"{fresh['peak_rss_mb']} MB RSS)")
    print(f"ratio:    {ratio:.2f} (gate: >= {args.min_ratio})")

    if scale == base["scale"]:
        for key in ("completed", "makespan_s"):
            if fresh[key] != base[key]:
                print(f"FAIL: {key} diverged from the committed baseline "
                      f"({fresh[key]!r} != {base[key]!r}) — a scheduling "
                      f"decision changed, not just its speed")
                return 1
    if ratio < args.min_ratio:
        print(f"FAIL: throughput regressed to {ratio:.0%} of baseline "
              f"(gate {args.min_ratio:.0%})")
        return 1
    if not args.skip_autoscale:
        if not check_autoscale(args.autoscale_baseline,
                               args.autoscale_min_ratio):
            return 1
    if not args.skip_search:
        if not check_search(args.search_baseline, args.search_min_ratio,
                            args.min_probe_drop):
            return 1
    if not args.skip_twin:
        if not check_twin(args.twin_baseline, args.twin_min_ratio):
            return 1
    if not args.skip_reconfig:
        if not check_reconfig(args.reconfig_baseline,
                              args.reconfig_min_ratio):
            return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
