"""SliceRuntime serving benchmark — multi-tenant co-run on the live engine.

Rows (CSV: name,us_per_call,derived):
  serve/single.<arch>      one tenant alone, us per emitted token
  serve/corun.<arch>       same tenant co-run with a second tenant
  serve/corun.aggregate    both tenants' tokens over the co-run wall time
  serve/offload.<arch>     tenant under a forced offload plan (spill path)

Wall times on the CPU container measure *engine overhead*, not TPU step
time; the modeled throttle/energy figures come from core.power and are
printed in the derived column.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.serving import Request, SliceRuntime, TenantSpec

ARCH_A = "llama3-8b"
ARCH_B = "gpt2-124m"
N_REQ = 4
MAX_NEW = 6


def _requests(cfg, n=N_REQ, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
                    MAX_NEW) for i in range(n)]


def _drive(rt, loads) -> dict:
    for name, reqs in loads.items():
        rt.submit(name, reqs)
    t0 = time.perf_counter()
    report = rt.run()
    report["wall_s"] = time.perf_counter() - t0
    return report


def run() -> None:
    mesh = make_host_mesh(1, 1)
    cfg_a = get_config(ARCH_A).reduced().with_(remat="none")
    cfg_b = get_config(ARCH_B).reduced().with_(remat="none")

    # single-tenant baseline
    rt = SliceRuntime(mesh=mesh)
    rt.add_tenant(TenantSpec(ARCH_A, cfg_a, profile="2s.32c",
                             slots=4, max_seq=48))
    rep = _drive(rt, {ARCH_A: _requests(cfg_a)})
    tok = rep["tenants"][ARCH_A]["tokens_out"]
    emit(f"serve/single.{ARCH_A}", rep["wall_s"] / max(tok, 1) * 1e6,
         f"tokens={tok}")

    # two tenants co-run on distinct slices
    rt = SliceRuntime(mesh=mesh)
    rt.add_tenant(TenantSpec(ARCH_A, cfg_a, profile="2s.32c",
                             slots=4, max_seq=48))
    rt.add_tenant(TenantSpec(ARCH_B, cfg_b, profile="1s.16c",
                             slots=4, max_seq=32))
    rep = _drive(rt, {ARCH_A: _requests(cfg_a), ARCH_B: _requests(cfg_b)})
    total = 0
    for arch in (ARCH_A, ARCH_B):
        row = rep["tenants"][arch]
        total += row["tokens_out"]
        emit(f"serve/corun.{arch}", rep["wall_s"] / max(row["tokens_out"], 1) * 1e6,
             f"tokens={row['tokens_out']},profile={row['profile']}")
    emit("serve/corun.aggregate", rep["wall_s"] / max(total, 1) * 1e6,
         f"tokens={total},pod_util={rep['pod_utilization']:.2f},"
         f"throttle={rep['modeled']['throttle_factor']:.2f}")

    # forced offload plan (budget below footprint -> spill path engaged)
    rt = SliceRuntime(mesh=mesh)
    t = rt.add_tenant(TenantSpec(ARCH_A, cfg_a, profile="2s.32c",
                                 slots=4, max_seq=48,
                                 hbm_budget=380_000, spill_granule=4096))
    rep = _drive(rt, {ARCH_A: _requests(cfg_a)})
    row = rep["tenants"][ARCH_A]
    emit(f"serve/offload.{ARCH_A}",
         rep["wall_s"] / max(row["tokens_out"], 1) * 1e6,
         f"tokens={row['tokens_out']},host_bytes={t.plan.host_bytes},"
         f"partial={len(t.plan.partial)}")
