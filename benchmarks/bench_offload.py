"""Paper §VI (Fig. 8 + Table IV analogue): reward-based configuration
selection with and without fine-grained host offloading, and the modeled
host-link bandwidth table."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs import get_config, get_shape
from repro.core.hw import GiB, V5E
from repro.core.reward import sweep
from repro.core.slices import PROFILES
from repro.core.workload import WorkloadEstimate

# the paper applies offloading to FAISS / Llama3 / Qiskit; our analogues:
CASES = [
    ("llama3-8b", "decode_32k"),    # footprint slightly above 2s.32c (527GiB)
    ("qwen3-32b", "decode_32k"),    # mid-size decode
    ("phi3.5-moe-42b-a6.6b", "prefill_32k"),  # burst-heavy prefill (FAISS-like)
    ("qwen2-vl-72b", "train_4k"),   # capacity-bound training (Qiskit-like)
]
ALPHAS = (0.0, 0.1, 0.5, 1.0)


def run() -> None:
    # Table IV analogue: achievable host-link bandwidth per slice (modeled)
    for p in PROFILES:
        emit(f"tableIV/{p.name}", 0.0,
             f"host_link={p.host_link_bw(V5E) / 1e9:.0f}GB/s "
             f"hbm_agg={p.n_chips * V5E.hbm_bw / 1e12:.1f}TB/s "
             f"ratio={p.host_link_bw(V5E) / (p.n_chips * V5E.hbm_bw):.4f} "
             f"(paper NVLink-C2C ratio: 0.15 — see DESIGN.md §2)")

    # Fig. 8: reward sweeps
    for arch, shape_name in CASES:
        wl = WorkloadEstimate(get_config(arch), get_shape(shape_name))
        emit(f"fig8/{arch}/{shape_name}/footprint", 0.0,
             f"{wl.footprint_bytes() / GiB:.0f}GiB")
        for alpha in ALPHAS:
            with timed() as t:
                pts = sweep(wl, alpha=alpha)
            if not pts:
                emit(f"fig8/{arch}/{shape_name}/a{alpha}", t["us"], "infeasible")
                continue
            best = pts[0]
            detail = " ".join(f"{p.label}:{p.reward:.2f}" for p in pts[:4])
            emit(f"fig8/{arch}/{shape_name}/a{alpha}", t["us"],
                 f"best={best.label} R={best.reward:.3f} "
                 f"perf_rel={best.perf_rel:.3f} | {detail}")
