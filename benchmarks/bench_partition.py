"""Paper Table II analogue: the slice-profile table for a v5e pod —
usable/wasted resources per profile + partitioner packing properties."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.hw import V5E_POD
from repro.core.partitioner import StaticPartitioner
from repro.core.slices import PROFILES, profile_table


def run() -> None:
    with timed() as t:
        rows = profile_table()
    for r in rows:
        emit(f"tableII/{r['profile']}", t["us"] / len(rows),
             f"inst={r['max_instances']} chips={r['chips']} "
             f"hbm={r['hbm_gib']:.0f}GiB tflops={r['peak_tflops']:.0f} "
             f"host_dram={r['host_dram_gib']:.0f}GiB "
             f"host_bw={r['host_link_gbps']:.0f}GB/s "
             f"wasted_chips={r['wasted_chips_pct']:.1f}%")

    # packing: fill the pod with the finest slices (paper's 7×1g analogue)
    with timed() as t:
        part = StaticPartitioner()
        n = 0
        try:
            while True:
                part.allocate(PROFILES[0])
                n += 1
        except RuntimeError:
            pass
        part.validate()
    emit("tableII/full-pack-1s", t["us"],
         f"instances={n} pod_util={part.utilization():.2f} "
         f"(waste from packing: {100 * (1 - part.utilization()):.1f}%)")
