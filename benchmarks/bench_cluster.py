"""Cluster scheduling benchmark — placement policies, rescue actions, and
scheduler policies on fixed traces (modeled runs, no live engine).

Rows (CSV: name,us_per_call,derived):
  cluster/showcase.<policy>   the crafted stranding trace (one pod): the
                              8×16 job fits free chips but no rectangle;
                              first_fit leaves it queued at the horizon,
                              frag_repack repacks once and places it
  cluster/showcase.stranded-job  the head-to-head verdict for that job
  cluster/elastic.<on|off>    crafted SLO-rescue trace: shrink flips miss→hit
  cluster/preempt.<on|off>    crafted checkpoint-eviction trace: priorities
                              flip miss→hit where a shrink cannot; the
                              victim resumes with work_done preserved
  cluster/grow.<on|off>       crafted elastic-grow trace: extend() absorbs
                              freed neighbour chips, finish improves
  cluster/migrate.<on|off>    crafted load-imbalanced two-pod trace: only a
                              DCN-priced MigrateAcrossPods meets the
                              deadline (the victim keeps running on the
                              destination pod)
  cluster/lookahead.<policy>  crafted two-blocker trace: no single action
                              rescues the deadline job; the look-ahead's
                              two-eviction chain does (and the search
                              policy matches it)
  cluster/search.<policy>     crafted three-blocker trace: the rescue
                              chain is one action deeper than the
                              two-step look-ahead explores; only the
                              budgeted best-first search finds it
  cluster/twin.<off|on>       crafted twin-offload trace: with twin pricing
                              on, the PerfModel's "+cpuX.XX" rung (spilled
                              KV tail co-executed host-side) lets a shrink
                              rescue a deadline job no plain rung can reach
  cluster/trace0.<policy>     seeded mixed trace (one pod, seed 0, heavy
                              enough that queues form and repack triggers)

Run directly for a custom comparison (the Action-API flags mirror
``repro.launch.cluster``):

    PYTHONPATH=src python -m benchmarks.bench_cluster \
        --policy lookahead --actions shrink,preempt,migrate --pods 2

``--scale N`` switches to the seeded large-trace perf mode (the ISSUE-6
100k-job acceptance run): one deterministic Poisson trace of N jobs
replayed through an 8-pod cluster, reporting jobs/sec, probes/sec and
peak RSS as JSON. ``--json PATH`` additionally writes the record —
``benchmarks/BENCH_cluster.json`` is the committed baseline that
``benchmarks/check_perf.py`` gates CI against:

    PYTHONPATH=src python benchmarks/bench_cluster.py --scale 100000

``--search-scale N`` produces the search-policy companion record
(``benchmarks/BENCH_search.json``): the search showcase suite, one
seeded N-job trace under ``--policy search``, and a look-ahead
probe-cache A/B whose ``probe_drop_ratio`` the CI gate holds at >= 3x.
``--twin-scale N`` produces the twin-offload companion record
(``benchmarks/BENCH_twin.json``): the twin showcase verdicts plus one
seeded N-job trace replayed with twin pricing on, which the CI gate
holds at >= 0.75x the twin-off throughput of the same trace.
``--profile N`` wraps any mode in cProfile and prints the top-N
functions by cumulative time.
"""
from __future__ import annotations

import json
import os
import resource
import sys
import time

if __package__ in (None, ""):   # `python benchmarks/bench_cluster.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

from benchmarks.common import emit, timed
from repro.cluster import (ClusterScheduler, PolicySpec, TraceConfig,
                           elastic_showcase, fragmentation_showcase,
                           generate_trace, grow_showcase,
                           lookahead_showcase, migration_showcase,
                           preemption_showcase, search_showcase,
                           twin_showcase)
from repro.cluster.placement import POLICY_NAMES

SHOWCASE_HORIZON_S = 3000.0
STRANDED_JOB_ID = 10
SLO_JOB_ID = 2
PREEMPT_SLO_JOB_ID = 2
PREEMPT_VICTIM_ID = 0
GROW_JOB_ID = 0
MIGRATE_SLO_JOB_ID = 3
MIGRATE_VICTIM_ID = 0
LOOKAHEAD_SLO_JOB_ID = 3
SEARCH_SLO_JOB_ID = 3
TWIN_SLO_JOB_ID = 4
TWIN_VICTIM_ID = 2


def _run(policy: str, jobs, n_pods: int, horizon=None, **kw):
    sched = ClusterScheduler(n_pods=n_pods, policy=policy, horizon_s=horizon,
                             **kw)
    with timed() as t:
        records, metrics = sched.run(jobs)
    return records, metrics, t["us"]


def _slo_verdict(records, job_id):
    rec = next(r for r in records if r.job.job_id == job_id)
    return rec, (rec.finished and rec.finish_s <= rec.deadline_s)


def run() -> None:
    # crafted stranding trace: same jobs under every policy
    jobs = fragmentation_showcase()
    verdicts = {}
    for policy in POLICY_NAMES:
        records, m, us = _run(policy, jobs, n_pods=1,
                              horizon=SHOWCASE_HORIZON_S)
        big = next(r for r in records if r.job.job_id == STRANDED_JOB_ID)
        verdicts[policy] = big
        emit(f"cluster/showcase.{policy}", us,
             f"placed={m.placed}/{m.n_jobs} queued={m.left_queued} "
             f"repacks={m.repacks} migrated_gib={m.migrated_bytes / 2**30:.1f} "
             f"frag_avg={m.frag_time_avg:.3f}")
    ff, rp = verdicts["first_fit"], verdicts["frag_repack"]
    emit("cluster/showcase.stranded-job", 0.0,
         f"first_fit={'queued' if not ff.placed else 'placed'} "
         f"frag_repack={'placed@t=' + format(rp.place_s, '.0f') if rp.placed else 'queued'}")

    # elastic SLO rescue: the same crafted trace with and without shrink
    for elastic in (False, True):
        spec = PolicySpec(actions=("shrink",) if elastic else ())
        records, m, us = _run("frag_repack", elastic_showcase(), n_pods=1,
                              horizon=SHOWCASE_HORIZON_S, spec=spec)
        _, hit = _slo_verdict(records, SLO_JOB_ID)
        emit(f"cluster/elastic.{'on' if elastic else 'off'}", us,
             f"slo_job={'hit' if hit else 'miss'} shrinks={m.shrinks} "
             f"slo={m.slo_attainment:.2f} "
             f"migrated_gib={m.migrated_bytes / 2**30:.1f}")

    # checkpoint preemption: priorities flip the deadline job's SLO verdict
    # on the same crafted trace (a shrink cannot mint the 8x16 origin);
    # the evicted batch job resumes from its checkpoint and completes
    for priorities in (False, True):
        spec = PolicySpec(actions=("shrink", "preempt") if priorities
                          else ("shrink",))
        records, m, us = _run("frag_repack", preemption_showcase(), n_pods=1,
                              spec=spec)
        victim = next(r for r in records if r.job.job_id == PREEMPT_VICTIM_ID)
        _, hit = _slo_verdict(records, PREEMPT_SLO_JOB_ID)
        if priorities:   # the showcase contract, asserted end-to-end
            assert hit and m.preemptions == 1 and m.resumes == 1
            assert victim.finished and victim.resumes == 1
        else:
            assert not hit and m.preemptions == 0
        emit(f"cluster/preempt.{'on' if priorities else 'off'}", us,
             f"slo_job={'hit' if hit else 'miss'} "
             f"preemptions={m.preemptions} resumes={m.resumes} "
             f"wasted_ckpt_chip_s={m.wasted_checkpoint_chip_s:.1f} "
             f"victim_ckpt_delay_s={victim.checkpoint_delay_s:.2f}")

    # elastic grow: a running job absorbs the chips a short neighbour
    # frees, via the partitioner's extend() — projected finish improves
    finishes = {}
    for grow in (False, True):
        spec = PolicySpec(actions=("grow",) if grow else ())
        records, m, us = _run("frag_repack", grow_showcase(), n_pods=1,
                              spec=spec)
        job = next(r for r in records if r.job.job_id == GROW_JOB_ID)
        finishes[grow] = job.finish_s
        if grow:
            assert m.grows == 1 and job.grown
            assert finishes[True] < finishes[False]   # finish improved
        emit(f"cluster/grow.{'on' if grow else 'off'}", us,
             f"job0_profile={job.profile_name} finish={job.finish_s:.0f}s "
             f"grows={m.grows} migrated_gib={m.migrated_bytes / 2**30:.1f}")

    # cross-pod migration: on the load-imbalanced two-pod trace every
    # in-pod rescue fails (training holders are never shrunk/evicted, the
    # only free rectangle is power-blocked); relocating the cold holder
    # over the DCN re-balances the pods and flips the SLO verdict
    for migrate in (False, True):
        spec = PolicySpec(actions=("shrink", "preempt", "migrate")
                          if migrate else ("shrink", "preempt"))
        records, m, us = _run("frag_repack", migration_showcase(), n_pods=2,
                              spec=spec)
        victim = next(r for r in records if r.job.job_id == MIGRATE_VICTIM_ID)
        _, hit = _slo_verdict(records, MIGRATE_SLO_JOB_ID)
        if migrate:   # the showcase contract, asserted end-to-end
            assert hit and m.migrations == 1
            assert victim.migrations == 1 and victim.pod_idx == 1
            assert victim.finished and not victim.preemptions
            assert m.dcn_migrated_bytes == victim.dcn_bytes > 0
        else:
            assert not hit and m.migrations == 0
        emit(f"cluster/migrate.{'on' if migrate else 'off'}", us,
             f"slo_job={'hit' if hit else 'miss'} migrations={m.migrations} "
             f"dcn_gib={m.dcn_migrated_bytes / 2**30:.1f} "
             f"dcn_s={m.dcn_migration_s:.2f} "
             f"power_deferrals={m.power_deferrals}")

    # look-ahead selection: no single action mints the 8x16 origin (each
    # eviction frees one 8x8), so greedy queues the job to a miss; the
    # look-ahead chains two evictions and commits the pair — and the
    # best-first search finds the same chain without pricing extra probes
    for selector in ("greedy", "lookahead", "search"):
        spec = PolicySpec(selector=selector, actions=("shrink", "preempt"))
        records, m, us = _run("frag_repack", lookahead_showcase(), n_pods=1,
                              spec=spec)
        _, hit = _slo_verdict(records, LOOKAHEAD_SLO_JOB_ID)
        if selector == "greedy":
            assert not hit and m.preemptions == 0
        else:   # the showcase contract: both chain policies commit the pair
            assert hit and m.preemptions == 2 and m.resumes == 2
        emit(f"cluster/lookahead.{selector}", us,
             f"slo_job={'hit' if hit else 'miss'} "
             f"preemptions={m.preemptions} resumes={m.resumes} "
             f"probes_priced={m.rescue_probes_priced} "
             f"completed={m.completed}")

    # best-first search: freeing the 16x16 origin takes *three* evictions
    # (two enablers + the closing preempt), one deeper than the two-step
    # look-ahead explores; only the budgeted search commits the chain
    for selector in ("greedy", "lookahead", "search"):
        spec = PolicySpec(selector=selector, actions=("shrink", "preempt"))
        records, m, us = _run("frag_repack", search_showcase(), n_pods=1,
                              spec=spec)
        _, hit = _slo_verdict(records, SEARCH_SLO_JOB_ID)
        if selector == "search":   # the showcase contract
            assert hit and m.preemptions == 3 and m.resumes == 3
        else:
            assert not hit and m.preemptions == 0
        emit(f"cluster/search.{selector}", us,
             f"slo_job={'hit' if hit else 'miss'} "
             f"preemptions={m.preemptions} resumes={m.resumes} "
             f"probes_priced={m.rescue_probes_priced} "
             f"cache_hits={m.probe_cache_hits}")

    # twin-offload co-execution: the same crafted trace with twin pricing
    # off and on — same shrink/preempt allowlist both times, so the only
    # difference is whether the PerfModel emits the "+cpuX.XX" rung that
    # makes the minted 4x4 hole fast enough for the deadline
    for twin in (False, True):
        spec = PolicySpec(actions=("shrink", "preempt"))
        records, m, us = _run("frag_repack", twin_showcase(), n_pods=1,
                              spec=spec, twin=twin)
        rec, hit = _slo_verdict(records, TWIN_SLO_JOB_ID)
        victim = next(r for r in records if r.job.job_id == TWIN_VICTIM_ID)
        if twin:   # the showcase contract, asserted end-to-end
            assert hit and m.shrinks == 1 and m.preemptions == 0
            assert rec.rung.startswith("1s.16c+cpu")
            assert victim.shrunk and victim.profile_name == "1s.16c"
        else:
            assert not hit and m.shrinks == 0 and m.preemptions == 0
            assert "+cpu" not in rec.rung
        emit(f"cluster/twin.{'on' if twin else 'off'}", us,
             f"slo_job={'hit' if hit else 'miss'} rung={rec.rung} "
             f"shrinks={m.shrinks} slo={m.slo_attainment:.2f} "
             f"queue_s={rec.place_s - rec.job.arrival_s:.0f}")

    # seeded mixed trace, heavier than the CLI default so queues form;
    # run both engines — frozen (PR 2 compatibility) and progress-based
    # (every admission/completion re-solves the shared-cap throttle)
    trace = generate_trace(TraceConfig(seed=0, n_jobs=48,
                                       mean_interarrival_s=5.0))
    for policy in POLICY_NAMES:
        _, m, us = _run(policy, trace, n_pods=1)
        emit(f"cluster/trace0.{policy}", us,
             f"makespan={m.makespan_s:.0f}s slo={m.slo_attainment:.2f} "
             f"util={m.chip_hour_utilization:.2f} "
             f"queue_p95={m.p95_queue_delay_s:.0f}s "
             f"energy_MJ={m.energy_J / 1e6:.0f} repacks={m.repacks} "
             f"power_deferrals={m.power_deferrals}")
    _, mf, us = _run("frag_repack", trace, n_pods=1, frozen_durations=True)
    emit("cluster/trace0.frozen-vs-progress", us,
         f"frozen_makespan={mf.makespan_s:.0f}s "
         f"frozen_slo={mf.slo_attainment:.2f} "
         f"frozen_energy_MJ={mf.energy_J / 1e6:.0f}")


# the committed-baseline regime: 8 pods keep a 12s-interarrival Poisson
# stream busy without collapsing into one unbounded queue, so throughput
# measures the scheduler hot path, not a pathological backlog
SCALE_PODS = 8
SCALE_INTERARRIVAL_S = 12.0


def run_scale(scale: int, *, pods: int = SCALE_PODS,
              mean_interarrival_s: float = SCALE_INTERARRIVAL_S,
              seed: int = 0, spec: PolicySpec = PolicySpec(),
              placement: str = "frag_repack",
              probe_cache: bool = True, twin: bool = False) -> dict:
    """Seeded large-trace perf mode: one deterministic N-job Poisson trace
    replayed end-to-end, returning the JSON perf-baseline record
    (jobs/sec, probes/sec, peak RSS). Pure function of its arguments —
    the committed ``BENCH_cluster.json`` and ``check_perf.py``'s fresh
    run replay the identical stream, so makespan/completed must match
    exactly and only the timings may differ."""
    t0 = time.perf_counter()
    trace = generate_trace(TraceConfig(
        seed=seed, n_jobs=scale, mean_interarrival_s=mean_interarrival_s))
    gen_s = time.perf_counter() - t0
    sched = ClusterScheduler(n_pods=pods, policy=placement, spec=spec,
                             probe_cache=probe_cache, twin=twin)
    t0 = time.perf_counter()
    records, metrics = sched.run(trace)
    wall_s = time.perf_counter() - t0
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    peak_rss_mb = rss / (1024.0 if sys.platform != "darwin" else 1024.0 ** 2)
    return {
        "bench": "cluster.scale",
        "scale": scale,
        "pods": pods,
        "mean_interarrival_s": mean_interarrival_s,
        "seed": seed,
        "placement": placement,
        "gen_s": round(gen_s, 3),
        "wall_s": round(wall_s, 3),
        "jobs_per_s": round(scale / wall_s, 1),
        "probes": sched._probes,
        "probes_per_s": round(sched._probes / wall_s, 1),
        "probes_priced": metrics.rescue_probes_priced,
        "probe_cache_hits": metrics.probe_cache_hits,
        "peak_rss_mb": round(peak_rss_mb, 1),
        "completed": metrics.completed,
        "makespan_s": metrics.makespan_s,
    }


# the search-policy companion regime: 4 pods under the same 12s Poisson
# stream stay loaded enough that deadline jobs actually trigger rescue
# scans (8 pods never do), yet queues stay transient — so probes_priced
# is a real hot-path signal rather than a backlog pathology
SEARCH_PODS = 4
SEARCH_ACTIONS = ("shrink", "preempt", "migrate")


def run_search(scale: int = 10000, *, pods: int = SEARCH_PODS,
               mean_interarrival_s: float = SCALE_INTERARRIVAL_S,
               seed: int = 0) -> dict:
    """The ``BENCH_search.json`` record: the search showcase suite (the
    three-eviction chain only the search policy finds), one seeded
    ``scale``-job trace replayed under ``--policy search``, and a
    look-ahead probe-cache A/B on the same trace whose
    ``probe_drop_ratio`` (uncached / cached probes priced) the CI gate
    holds at >= 3x. Pure function of its arguments: every count and
    timeline field must replay bit-identically; only timings may differ.

    Refreshing after an intentional change:

        PYTHONPATH=src python -m benchmarks.bench_cluster \\
            --search-scale 10000 --json benchmarks/BENCH_search.json
    """
    showcase = {}
    for selector in ("greedy", "lookahead", "search"):
        spec = PolicySpec(selector=selector, actions=("shrink", "preempt"))
        records, m, _ = _run("frag_repack", search_showcase(), n_pods=1,
                             spec=spec)
        _, hit = _slo_verdict(records, SEARCH_SLO_JOB_ID)
        showcase[selector] = {
            "slo_hit": hit,
            "preemptions": m.preemptions,
            "probes_priced": m.rescue_probes_priced,
        }
    search_spec = PolicySpec(selector="search", actions=SEARCH_ACTIONS)
    s_rec = run_scale(scale, pods=pods,
                      mean_interarrival_s=mean_interarrival_s, seed=seed,
                      spec=search_spec)
    la_spec = PolicySpec(selector="lookahead", actions=SEARCH_ACTIONS)
    la_on = run_scale(scale, pods=pods,
                      mean_interarrival_s=mean_interarrival_s, seed=seed,
                      spec=la_spec)
    la_off = run_scale(scale, pods=pods,
                       mean_interarrival_s=mean_interarrival_s, seed=seed,
                       spec=la_spec, probe_cache=False)
    keep = ("wall_s", "jobs_per_s", "probes_priced", "probe_cache_hits",
            "completed", "makespan_s", "peak_rss_mb")
    return {
        "bench": "cluster.search",
        "scale": scale,
        "pods": pods,
        "mean_interarrival_s": mean_interarrival_s,
        "seed": seed,
        "actions": list(SEARCH_ACTIONS),
        "showcase": showcase,
        "search": {k: s_rec[k] for k in keep},
        "lookahead_cache_on": {k: la_on[k] for k in keep},
        "lookahead_cache_off": {k: la_off[k] for k in keep},
        "probe_drop_ratio": round(
            la_off["probes_priced"] / max(1, la_on["probes_priced"]), 2),
    }


def run_twin(scale: int = 10000, *, pods: int = SCALE_PODS,
             mean_interarrival_s: float = SCALE_INTERARRIVAL_S,
             seed: int = 0) -> dict:
    """The ``BENCH_twin.json`` record: the twin showcase verdicts (twin
    pricing off → the deadline job queues past its SLO; on → the shrink
    commits the "+cpuX.XX" rung and the job hits), plus one seeded
    ``scale``-job trace replayed with twin pricing enabled. The showcase
    block and the replay's count/timeline fields are pure functions of
    the arguments and must match the committed record bit-exactly; the
    CI gate additionally holds the twin-on replay's throughput at >=
    0.75x a fresh twin-off replay of the same trace (the extra rungs are
    priced per profile, so scoring cost rises but must stay bounded).

    Refreshing after an intentional change:

        PYTHONPATH=src python -m benchmarks.bench_cluster \\
            --twin-scale 10000 --json benchmarks/BENCH_twin.json
    """
    showcase = {}
    for twin in (False, True):
        spec = PolicySpec(actions=("shrink", "preempt"))
        records, m, _ = _run("frag_repack", twin_showcase(), n_pods=1,
                             spec=spec, twin=twin)
        rec, hit = _slo_verdict(records, TWIN_SLO_JOB_ID)
        victim = next(r for r in records if r.job.job_id == TWIN_VICTIM_ID)
        showcase["on" if twin else "off"] = {
            "slo_hit": hit,
            "rung": rec.rung,
            "queue_s": round(rec.place_s - rec.job.arrival_s, 2),
            "shrinks": m.shrinks,
            "victim_profile": victim.profile_name,
            "slo_attainment": m.slo_attainment,
        }
    on = run_scale(scale, pods=pods,
                   mean_interarrival_s=mean_interarrival_s, seed=seed,
                   twin=True)
    keep = ("wall_s", "jobs_per_s", "probes", "completed", "makespan_s",
            "peak_rss_mb")
    return {
        "bench": "cluster.twin",
        "scale": scale,
        "pods": pods,
        "mean_interarrival_s": mean_interarrival_s,
        "seed": seed,
        "showcase": showcase,
        "twin_on": {k: on[k] for k in keep},
    }


def main() -> None:
    """Custom comparison CLI: schedule one seeded trace under the given
    placement policy and ``PolicySpec`` and print the metrics table;
    ``--scale N`` switches to the large-trace perf mode and
    ``--search-scale N`` to the search-policy companion record instead.
    ``--profile N`` wraps whichever mode runs in cProfile."""
    import argparse

    from repro.cluster import format_metrics
    from repro.launch.cluster import add_policy_args, spec_from_args

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--pods", type=int, default=None,
                    help=f"default 1 (comparison) / {SCALE_PODS} (--scale)")
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--jobs", type=int, default=48)
    ap.add_argument("--mean-interarrival", type=float, default=None,
                    help="default 5.0 (comparison) / "
                         f"{SCALE_INTERARRIVAL_S} (--scale)")
    ap.add_argument("--placement", default="frag_repack",
                    choices=POLICY_NAMES)
    ap.add_argument("--scale", type=int, default=None, metavar="N",
                    help="large-trace perf mode: replay one seeded N-job "
                         "trace and print the JSON baseline record")
    ap.add_argument("--search-scale", type=int, default=None, metavar="N",
                    help="search-policy perf mode: showcase suite + one "
                         "seeded N-job trace under --policy search + a "
                         "look-ahead probe-cache A/B; prints the JSON "
                         "record committed as benchmarks/BENCH_search.json")
    ap.add_argument("--twin-scale", type=int, default=None, metavar="N",
                    help="twin-offload perf mode: the twin showcase "
                         "verdicts + one seeded N-job trace replayed with "
                         "twin pricing on; prints the JSON record "
                         "committed as benchmarks/BENCH_twin.json")
    ap.add_argument("--twin", action="store_true",
                    help="enable twin-offload co-execution pricing in the "
                         "comparison/--scale modes")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="with --scale/--search-scale/--twin-scale: also "
                         "write the record to PATH")
    ap.add_argument("--profile", type=int, default=None, metavar="N",
                    help="run under cProfile and print the top-N "
                         "functions by cumulative time after the output")
    add_policy_args(ap)
    args = ap.parse_args()
    spec = spec_from_args(args)

    def work() -> None:
        if args.scale or args.search_scale or args.twin_scale:
            if args.search_scale:
                rec = run_search(
                    args.search_scale,
                    pods=(args.pods if args.pods is not None
                          else SEARCH_PODS),
                    mean_interarrival_s=(args.mean_interarrival
                                         if args.mean_interarrival
                                         is not None
                                         else SCALE_INTERARRIVAL_S),
                    seed=args.trace_seed)
            elif args.twin_scale:
                rec = run_twin(
                    args.twin_scale,
                    pods=(args.pods if args.pods is not None
                          else SCALE_PODS),
                    mean_interarrival_s=(args.mean_interarrival
                                         if args.mean_interarrival
                                         is not None
                                         else SCALE_INTERARRIVAL_S),
                    seed=args.trace_seed)
            else:
                rec = run_scale(
                    args.scale,
                    pods=args.pods if args.pods is not None else SCALE_PODS,
                    mean_interarrival_s=(args.mean_interarrival
                                         if args.mean_interarrival
                                         is not None
                                         else SCALE_INTERARRIVAL_S),
                    seed=args.trace_seed, spec=spec,
                    placement=args.placement, twin=args.twin)
            out = json.dumps(rec, indent=2)
            print(out)
            if args.json:
                with open(args.json, "w") as fh:
                    fh.write(out + "\n")
            return
        trace = generate_trace(TraceConfig(
            seed=args.trace_seed, n_jobs=args.jobs,
            mean_interarrival_s=(args.mean_interarrival
                                 if args.mean_interarrival is not None
                                 else 5.0)))
        _, metrics, us = _run(
            args.placement, trace,
            n_pods=args.pods if args.pods is not None else 1, spec=spec,
            twin=args.twin)
        print(f"# placement={args.placement} policy={spec.selector} "
              f"actions={','.join(spec.actions) or '-'} "
              f"jobs={len(trace)} sched_us={us:.0f}")
        print(format_metrics([metrics]))

    if args.profile:
        import cProfile
        import pstats
        prof = cProfile.Profile()
        prof.enable()
        try:
            work()
        finally:
            prof.disable()
            pstats.Stats(prof).sort_stats("cumulative").print_stats(
                args.profile)
    else:
        work()


if __name__ == "__main__":
    main()
