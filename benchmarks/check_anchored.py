"""Anchored-vs-analytic scheduling drift check (CI gate).

``PerfModel.from_artifacts`` calibrates the analytic roofline terms from
dry-run HLO anchors (``benchmarks/artifacts/dryrun/single/``, committed —
the ROADMAP "anchored placement in CI" item). This check schedules every
crafted showcase trace twice — once under the pure analytic model, once
under the anchored one — and fails when the two disagree:

* **decision metrics** must match exactly: which jobs placed/completed,
  how many repacks / shrinks / grows / preemptions / resumes / cross-pod
  migrations fired, the SLO attainment, and the power deferrals. A small
  measured recalibration (a few percent on compute/memory terms) must
  never flip a scheduling decision on these traces.
* **continuous metrics** (makespan, energy, mean queue delay) may drift
  with the recalibrated step times, but by at most ``MAX_DRIFT`` (5%).

Exit status is nonzero on any violation, so CI can gate on it:

    PYTHONPATH=src python -m benchmarks.check_anchored
"""
from __future__ import annotations

import os
import sys

from repro.core.perfmodel import PerfModel
from repro.cluster import (ClusterScheduler, PolicySpec, elastic_showcase,
                           fragmentation_showcase, grow_showcase,
                           lookahead_showcase, migration_showcase,
                           preemption_showcase)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
MAX_DRIFT = 0.05   # relative drift allowed on continuous golden metrics

EXACT_METRICS = ("placed", "completed", "left_queued", "repacks",
                 "repack_failures", "shrinks", "grows", "preemptions",
                 "resumes", "migrations", "power_deferrals",
                 "slo_attainment")
DRIFT_METRICS = ("makespan_s", "energy_J", "mean_queue_delay_s")

# every crafted showcase, with its canonical scheduler configuration
SCENARIOS = (
    ("fragmentation", fragmentation_showcase, dict(
        n_pods=1, horizon_s=3000.0, spec=PolicySpec())),
    ("elastic", elastic_showcase, dict(
        n_pods=1, horizon_s=3000.0, spec=PolicySpec(actions=("shrink",)))),
    ("preemption", preemption_showcase, dict(
        n_pods=1, spec=PolicySpec(actions=("shrink", "preempt")))),
    ("grow", grow_showcase, dict(
        n_pods=1, spec=PolicySpec(actions=("grow",)))),
    ("migration", migration_showcase, dict(
        n_pods=2, spec=PolicySpec(actions=("shrink", "preempt",
                                           "migrate")))),
    ("lookahead", lookahead_showcase, dict(
        n_pods=1, spec=PolicySpec(selector="lookahead",
                                  actions=("shrink", "preempt")))),
)


def _drift(a: float, b: float) -> float:
    if a == b:
        return 0.0
    denom = max(abs(a), abs(b))
    return abs(a - b) / denom if denom else 0.0


def check(artifact_dir: str = ARTIFACT_DIR, verbose: bool = True
          ) -> list:
    """Run every scenario under both models; return a list of violation
    strings (empty = pass)."""
    anchored = PerfModel.from_artifacts(artifact_dir)
    if not anchored.anchors:
        return [f"no dry-run anchors found under {artifact_dir}/single"]
    analytic = PerfModel()
    violations = []
    for name, trace_fn, kw in SCENARIOS:
        results = {}
        for label, perf in (("analytic", analytic), ("anchored", anchored)):
            sched = ClusterScheduler(policy="frag_repack", perf=perf, **kw)
            results[label] = sched.run(trace_fn())[1]
        ana, anc = results["analytic"], results["anchored"]
        for metric in EXACT_METRICS:
            a, b = getattr(ana, metric), getattr(anc, metric)
            if a != b:
                violations.append(
                    f"{name}: decision metric {metric} flipped under "
                    f"anchors (analytic={a} anchored={b})")
        for metric in DRIFT_METRICS:
            d = _drift(getattr(ana, metric), getattr(anc, metric))
            if d > MAX_DRIFT:
                violations.append(
                    f"{name}: {metric} drifts {d:.1%} > {MAX_DRIFT:.0%} "
                    f"(analytic={getattr(ana, metric):.6g} "
                    f"anchored={getattr(anc, metric):.6g})")
        if verbose:
            drifts = ", ".join(
                f"{m}={_drift(getattr(ana, m), getattr(anc, m)):.2%}"
                for m in DRIFT_METRICS)
            print(f"anchored-check/{name}: slo={ana.slo_attainment:.2f} "
                  f"drift[{drifts}]")
    return violations


def main() -> None:
    violations = check()
    for v in violations:
        print(f"ANCHORED-CHECK FAILURE: {v}", file=sys.stderr)
    if violations:
        sys.exit(1)
    print(f"anchored-check: OK ({len(SCENARIOS)} scenarios, "
          f"exact={len(EXACT_METRICS)} metrics, "
          f"drift<={MAX_DRIFT:.0%} on {len(DRIFT_METRICS)})")


if __name__ == "__main__":
    main()
