"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only partition,scaling,...]

Prints ``name,us_per_call,derived`` CSV rows. Roofline rows require the
dry-run artifacts (python -m repro.launch.dryrun --all --mesh both).
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

# section name -> module exposing run()
SECTIONS = {
    "partition": "benchmarks.bench_partition",
    "scaling": "benchmarks.bench_scaling",
    "cosched": "benchmarks.bench_cosched",
    "offload": "benchmarks.bench_offload",
    "serving": "benchmarks.bench_serving",
    "kernels": "benchmarks.bench_kernels",
    "cluster": "benchmarks.bench_cluster",
    "roofline": "benchmarks.roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SECTIONS))
    ap.add_argument("--list", action="store_true",
                    help="print the section name -> module map and exit")
    args = ap.parse_args()
    if args.list:
        width = max(len(n) for n in SECTIONS)
        for name, module in SECTIONS.items():
            print(f"{name.ljust(width)}  {module}")
        return
    wanted = args.only.split(",") if args.only else list(SECTIONS)
    unknown = [n for n in wanted if n not in SECTIONS]
    if unknown:
        ap.error(f"unknown section(s) {unknown}; valid: {sorted(SECTIONS)}")

    failures = 0
    for name in wanted:
        print(f"# === {name} ===")
        try:
            importlib.import_module(SECTIONS[name]).run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# SECTION {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
