"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only partition,scaling,...]

Prints ``name,us_per_call,derived`` CSV rows. Roofline rows require the
dry-run artifacts (python -m repro.launch.dryrun --all --mesh both).
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

# section name -> module exposing run()
SECTIONS = {
    "partition": "benchmarks.bench_partition",
    "scaling": "benchmarks.bench_scaling",
    "cosched": "benchmarks.bench_cosched",
    "offload": "benchmarks.bench_offload",
    "serving": "benchmarks.bench_serving",
    "kernels": "benchmarks.bench_kernels",
    "cluster": "benchmarks.bench_cluster",
    "autoscale": "benchmarks.bench_autoscale",
    "reconfig": "benchmarks.bench_reconfig",
    "roofline": "benchmarks.roofline",
}


def resolve_sections(only=None):
    """Validate a ``--only`` spec against SECTIONS and return the section
    names to run (all of them when ``only`` is None/empty). An unknown
    name raises ``SystemExit`` with a readable message (nonzero exit, no
    KeyError traceback) — shared by the ``--list`` and run paths."""
    wanted = [n.strip() for n in only.split(",")] if only else list(SECTIONS)
    unknown = [n for n in wanted if n not in SECTIONS]
    if unknown:
        raise SystemExit(
            f"error: unknown benchmark section(s) {unknown}; "
            f"valid: {sorted(SECTIONS)}")
    return wanted


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SECTIONS))
    ap.add_argument("--list", action="store_true",
                    help="print the section name -> module map (restricted "
                         "to --only when given) and exit")
    args = ap.parse_args()
    wanted = resolve_sections(args.only)
    if args.list:
        width = max(len(n) for n in wanted)
        for name in wanted:
            print(f"{name.ljust(width)}  {SECTIONS[name]}")
        return

    failures = 0
    for name in wanted:
        print(f"# === {name} ===")
        try:
            importlib.import_module(SECTIONS[name]).run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# SECTION {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
