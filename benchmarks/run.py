"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only partition,scaling,...]

Prints ``name,us_per_call,derived`` CSV rows. Roofline rows require the
dry-run artifacts (python -m repro.launch.dryrun --all --mesh both).
"""
from __future__ import annotations

import argparse
import sys
import traceback

SECTIONS = ("partition", "scaling", "cosched", "offload", "serving",
            "kernels", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SECTIONS))
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else list(SECTIONS)

    failures = 0
    for name in wanted:
        print(f"# === {name} ===")
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"]) \
                if name != "roofline" else \
                __import__("benchmarks.roofline", fromlist=["run"])
            mod.run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# SECTION {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
