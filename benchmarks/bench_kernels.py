"""Kernel micro-benchmarks: wall time per call (interpret mode on CPU —
structural validation; real-TPU numbers come from the roofline model) and
oracle agreement."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref


def _bench(fn, *args, iters: int = 3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> None:
    key = jax.random.PRNGKey(0)
    # flash attention
    q = jax.random.normal(key, (2, 256, 4, 64), jnp.float32)
    us = _bench(lambda a: ops.flash_attention(a, q, q, causal=True), q)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(8, 256, 64)
    err = float(np.max(np.abs(
        np.asarray(ops.flash_attention(q, q, q, causal=True)) -
        np.asarray(ref.attention_ref(fold(q), fold(q), fold(q), causal=True)
                   .reshape(2, 4, 256, 64).transpose(0, 2, 1, 3)))))
    emit("kernel/flash_attention/B2S256H4d64", us, f"max_abs_err={err:.2e}")

    # ssd scan
    B, S, nh, hp, N = 2, 256, 8, 32, 64
    ks = jax.random.split(key, 5)
    x = 0.5 * jax.random.normal(ks[0], (B, S, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(0.3 * jax.random.normal(ks[2], (nh,)))
    B_ = 0.3 * jax.random.normal(ks[3], (B, S, N))
    C_ = 0.3 * jax.random.normal(ks[4], (B, S, N))
    us = _bench(lambda a: ops.ssd(a, dt, A, B_, C_, chunk=128, nh_block=4), x)
    err = float(np.max(np.abs(np.asarray(ops.ssd(x, dt, A, B_, C_, chunk=128,
                                                 nh_block=4)) -
                              np.asarray(ref.ssd_ref(x, dt, A, B_, C_)))))
    emit("kernel/ssd_scan/B2S256nh8", us, f"max_abs_err={err:.2e}")

    # grouped matmul
    xg = jax.random.normal(ks[0], (4, 256, 128))
    wg = jax.random.normal(ks[1], (4, 128, 256))
    us = _bench(lambda a: ops.grouped_matmul(a, wg), xg)
    emit("kernel/moe_gmm/E4C256", us,
         f"max_abs_err={float(np.max(np.abs(np.asarray(ops.grouped_matmul(xg, wg)) - np.asarray(ref.gmm_ref(xg, wg))))):.2e}")

    # stream matmul (offload streaming analogue)
    xs = jax.random.normal(ks[2], (256, 1024))
    ws = jax.random.normal(ks[3], (1024, 512))
    us = _bench(lambda a: ops.stream_matmul(a, ws, block_k=512), xs)
    emit("kernel/stream_matmul/256x1024x512", us,
         f"max_abs_err={float(np.max(np.abs(np.asarray(ops.stream_matmul(xs, ws)) - np.asarray(ref.matmul_ref(xs, ws))))):.2e}")
