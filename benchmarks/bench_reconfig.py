"""Partition-reconfiguration benchmark — the MI300 mode-switch rescue.

Rows (CSV: name,us_per_call,derived):
  reconfig/showcase.<off|on>  the crafted MI300 mode-switch trace: with
                              ``"reconfigure"`` off the HBM-bound decode
                              job waits out the priority-blocked tenants
                              to an SLO miss; on, the planner drains one
                              tenant, switches pod 0 into cpx-nps4
                              (+30% effective bandwidth) and hits
  reconfig/modes.<chip>       how many partition modes each registered
                              chip family exposes
  reconfig/scale.mi300        the seeded Poisson trace replayed on an
                              MI300 cluster (full-ladder cpx-nps1 boot
                              mode, reconfigure allowed) — the mode
                              machinery priced on the hot path

``--scale N`` produces the committed companion record
(``benchmarks/BENCH_reconfig.json``): the showcase verdicts plus one
seeded N-job MI300 replay, which ``benchmarks/check_perf.py``
(``check_reconfig``) holds bit-exact on every decision field and at
>= 0.75x the throughput of a fresh v5e replay of the same trace:

    PYTHONPATH=src python -m benchmarks.bench_reconfig \\
        --scale 10000 --json benchmarks/BENCH_reconfig.json
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import sys
import time

if __package__ in (None, ""):   # `python benchmarks/bench_reconfig.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

from benchmarks.common import emit, timed
from repro.cluster import (ClusterScheduler, PolicySpec, TraceConfig,
                           generate_trace, reconfigure_showcase)
from repro.core.hw import CHIPS, MI300_POD, partition_modes

RECONFIG_SLO_JOB_ID = 2
SCALE_PODS = 8
SCALE_INTERARRIVAL_S = 12.0
# the MI300 replay boots in cpx-nps1: the full slice ladder is exposed
# (SPX floors it at 64 cells, stranding every small trace job), and the
# mode's flops delta keeps the mode-scaled PerfModel path hot
SCALE_MODE = "cpx-nps1"
SCALE_ACTIONS = ("shrink", "preempt", "migrate", "reconfigure")


def _showcase(actions):
    sched = ClusterScheduler(n_pods=2, pod=MI300_POD, policy="frag_repack",
                             spec=PolicySpec(actions=actions))
    with timed() as t:
        records, metrics = sched.run(reconfigure_showcase())
    rec = next(r for r in records if r.job.job_id == RECONFIG_SLO_JOB_ID)
    verdict = {
        "slo_hit": rec.finished and rec.finish_s <= rec.deadline_s,
        "queue_s": round(rec.place_s - rec.job.arrival_s, 2),
        "reconfigs": metrics.reconfigs,
        "migrations": metrics.migrations,
        "modes": [p.mode for p in sched.pods],
        "slo_attainment": metrics.slo_attainment,
    }
    return verdict, t["us"]


def run_mi300_scale(scale: int, *, pods: int = SCALE_PODS,
                    mean_interarrival_s: float = SCALE_INTERARRIVAL_S,
                    seed: int = 0) -> dict:
    """One deterministic N-job Poisson trace replayed on an MI300 cluster
    (boot mode ``cpx-nps1``, every rescue kind allowed). Pure function of
    its arguments — the committed ``BENCH_reconfig.json`` and the CI
    gate's fresh run replay the identical stream, so every decision field
    must match exactly and only the timings may differ."""
    trace = generate_trace(TraceConfig(
        seed=seed, n_jobs=scale, mean_interarrival_s=mean_interarrival_s))
    sched = ClusterScheduler(n_pods=pods, pod=MI300_POD, mode=SCALE_MODE,
                             policy="frag_repack",
                             spec=PolicySpec(actions=SCALE_ACTIONS))
    t0 = time.perf_counter()
    records, metrics = sched.run(trace)
    wall_s = time.perf_counter() - t0
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    peak_rss_mb = rss / (1024.0 if sys.platform != "darwin" else 1024.0 ** 2)
    return {
        "wall_s": round(wall_s, 3),
        "jobs_per_s": round(scale / wall_s, 1),
        "completed": metrics.completed,
        "makespan_s": metrics.makespan_s,
        "reconfigs": metrics.reconfigs,
        "migrations": metrics.migrations,
        "slo_attainment": metrics.slo_attainment,
        "peak_rss_mb": round(peak_rss_mb, 1),
    }


def run_reconfig(scale: int = 10000, *, pods: int = SCALE_PODS,
                 mean_interarrival_s: float = SCALE_INTERARRIVAL_S,
                 seed: int = 0) -> dict:
    """The ``BENCH_reconfig.json`` record: the mode-switch showcase
    verdicts (reconfigure off → miss, on → hit in cpx-nps4) plus the
    seeded MI300 replay. The CI gate (``check_perf.check_reconfig``)
    holds the showcase block and every replay decision field bit-exact,
    and the MI300 throughput at >= 0.75x a fresh v5e replay of the same
    trace (both runs on this machine, so the ratio bounds the cost of
    the mode machinery, not machine speed)."""
    showcase = {}
    showcase["off"], _ = _showcase(("migrate",))
    showcase["on"], _ = _showcase(("migrate", "reconfigure"))
    mi300 = run_mi300_scale(scale, pods=pods,
                            mean_interarrival_s=mean_interarrival_s,
                            seed=seed)
    return {
        "bench": "cluster.reconfig",
        "scale": scale,
        "pods": pods,
        "mean_interarrival_s": mean_interarrival_s,
        "seed": seed,
        "mode": SCALE_MODE,
        "actions": list(SCALE_ACTIONS),
        "showcase": showcase,
        "mi300": mi300,
    }


def run() -> None:
    """The harness section: showcase verdict rows + a small-scale MI300
    replay (CI-smoke-sized — the committed-baseline regime is produced
    with ``--scale`` and gated by check_perf)."""
    for tag, actions in (("off", ("migrate",)),
                         ("on", ("migrate", "reconfigure"))):
        v, us = _showcase(actions)
        emit(f"reconfig/showcase.{tag}", us,
             f"slo={'hit' if v['slo_hit'] else 'miss'} "
             f"reconfigs={v['reconfigs']} migrations={v['migrations']} "
             f"modes={'/'.join(v['modes'])}")
    for alias, chip in sorted(CHIPS.items()):
        modes = partition_modes(chip)
        emit(f"reconfig/modes.{alias}", 0.0,
             f"{len(modes)} modes: {','.join(sorted(modes))}")
    with timed() as t:
        rec = run_mi300_scale(500, pods=4)
    emit("reconfig/scale.mi300", t["us"],
         f"completed={rec['completed']} "
         f"slo_attainment={rec['slo_attainment']:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--scale", type=int, default=None,
                    help="seeded MI300 replay size; with --json, writes "
                         "the committed baseline record")
    ap.add_argument("--pods", type=int, default=SCALE_PODS)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the record as JSON (the committed "
                         "benchmarks/BENCH_reconfig.json baseline)")
    args = ap.parse_args()
    if args.scale is None:
        run()
        return
    rec = run_reconfig(args.scale, pods=args.pods, seed=args.seed)
    out = json.dumps(rec, indent=2, sort_keys=True)
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(out + "\n")
    print(out)


if __name__ == "__main__":
    main()
