"""Paper §V (Figs. 5-7): co-running throughput, energy, and power-throttling
interference for N identical copies on one pod, plus a mixed-tenancy case."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs import get_config, get_shape
from repro.core.cosched import corun_copies, mixed_tenancy, sharing_table
from repro.core.power import InstanceLoad, pod_draw, throttle_factor
from repro.core.workload import WorkloadEstimate

# one representative per utilization class (paper's app-suite analogue)
CASES = [
    ("mamba2-130m", "decode_32k"),      # memory/latency-idle (NekRS/FAISS class)
    ("zamba2-1.2b", "decode_32k"),      # small hybrid
    ("granite-moe-1b-a400m", "train_4k"),  # small MoE train (llm.c class)
    ("llama3-8b", "decode_32k"),        # the paper's own Llama3 case
    ("phi3-mini-3.8b", "train_4k"),     # mid dense train
    ("qwen3-32b", "prefill_32k"),       # compute-heavy (Qiskit/hotspot class)
]


def run() -> None:
    for arch, shape_name in CASES:
        wl = WorkloadEstimate(get_config(arch), get_shape(shape_name))
        with timed() as t:
            table = sharing_table(wl)
        for r in table:
            emit(f"fig5-6/{arch}/{shape_name}/{r.config}",
                 t["us"] / max(len(table), 1),
                 f"tput_norm={r.throughput_norm:.2f} "
                 f"energy_norm={r.energy_norm:.2f} "
                 f"throttled={r.throttled} f={r.throttle_factor:.2f}")

    # Fig. 7 analogue: power traces summary — single vs 16 concurrent
    hot = InstanceLoad(n_chips=16, u_compute=0.95, step_time=1.0)
    single_draw = pod_draw([hot])
    many_draw = pod_draw([hot] * 16)
    f = throttle_factor([hot] * 16)
    emit("fig7/throttling", 0.0,
         f"single_draw={single_draw:.0f}W many_draw={many_draw:.0f}W "
         f"cap={throttle_cap():.0f}W throttle_factor={f:.2f}")

    # beyond-paper: mixed tenancy (different workloads on one pod)
    workloads = {
        "serve-llm": WorkloadEstimate(get_config("llama3-8b"),
                                      get_shape("decode_32k")),
        "serve-ssm": WorkloadEstimate(get_config("mamba2-130m"),
                                      get_shape("decode_32k")),
        "train-moe": WorkloadEstimate(get_config("granite-moe-1b-a400m"),
                                      get_shape("train_4k")),
    }
    placement = {"serve-llm": "4s.64c", "serve-ssm": "1s.16c",
                 "train-moe": "8s.128c"}
    with timed() as t:
        res = mixed_tenancy(workloads, placement)
    emit("mixed-tenancy/pod", t["us"],
         f"pod_util={res['pod_utilization']:.2f} "
         f"throttle_f={res['throttle_factor']:.2f} "
         f"energy={res['energy_J'] / 1e6:.1f}MJ")


def throttle_cap() -> float:
    from repro.core.hw import V5E_POD
    return V5E_POD.power_cap_watts
