"""§Roofline table builder: reads the dry-run artifacts and emits the
per-(arch × shape × mesh) three-term roofline table (markdown + CSV)."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load(mesh: str = "single") -> List[Dict]:
    d = os.path.join(ART, mesh)
    if not os.path.isdir(d):
        return []
    out = []
    for f in sorted(os.listdir(d)):
        # baseline cells only: arch__shape.json (hillclimb runs are tagged
        # arch__shape__tag.json and reported separately in §Perf)
        if f.endswith(".json") and f.count("__") == 1:
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def fmt_ms(s: float) -> str:
    return f"{s * 1e3:.2f}"


def table_rows(mesh: str = "single") -> List[Dict]:
    rows = []
    for rec in load(mesh):
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": "skip", "note": rec["skipped"]})
            continue
        if rec.get("error"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": "FAIL", "note": rec["error"][:60]})
            continue
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "t_compute_ms": r["t_compute_s"] * 1e3,
            "t_memory_ms": r["t_memory_s"] * 1e3,
            "t_collective_ms": r["t_collective_s"] * 1e3,
            "dominant": r["dominant"],
            "step_ms": r["step_time_s"] * 1e3,
            "mfu": r["roofline_mfu"],
            "useful": r["useful_flops_ratio"],
            "mem_gib": rec["memory"]["per_device_gib"],
            "microbatches": rec.get("microbatches"),
        })
    return rows


def markdown(mesh: str = "single") -> str:
    rows = table_rows(mesh)
    lines = [
        f"### Roofline — {mesh} pod mesh",
        "",
        "| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
        "step ms | roofline-MFU | useful-FLOPs | mem GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']}: {r['note'][:50]} | — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']:.1f} | "
            f"{r['t_memory_ms']:.1f} | {r['t_collective_ms']:.2f} | "
            f"{r['dominant']} | {r['step_ms']:.1f} | {r['mfu'] * 100:.1f}% | "
            f"{r['useful'] * 100:.0f}% | {r['mem_gib']:.2f} |")
    return "\n".join(lines)


def run() -> None:
    from benchmarks.common import emit
    for mesh in ("single", "multi"):
        for r in table_rows(mesh):
            if r["status"] != "ok":
                emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}", 0.0,
                     f"{r['status']}:{r['note'][:60]}")
            else:
                emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                     r["step_ms"] * 1e3,
                     f"dom={r['dominant']} mfu={r['mfu'] * 100:.1f}% "
                     f"useful={r['useful'] * 100:.0f}% "
                     f"mem={r['mem_gib']:.1f}GiB")


if __name__ == "__main__":
    print(markdown("single"))
    print()
    print(markdown("multi"))
