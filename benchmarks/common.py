"""Shared benchmark utilities: CSV emission + timing."""
from __future__ import annotations

import time
from contextlib import contextmanager


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["us"] = (time.perf_counter() - t0) * 1e6
