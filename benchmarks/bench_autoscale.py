"""Autoscale benchmark — the day-in-the-life headline curve.

One seeded diurnal day of serving traffic, run twice over the same
per-tenant load curves:

* **fixed** — every tenant provisioned at peak size (``8s.128c``) for
  the whole day, controller in observe-only mode (so both runs report
  identical latency accounting);
* **autoscale** — tenants start at ``1s.16c`` and the hysteresis
  controller resizes them through the priced Action API (grow / shrink
  / cross-pod migrate) as the tide comes in and out.

Rows (CSV: name,us_per_call,derived):
  autoscale/day.fixed       chip-hours + SLO hit rate at fixed peak size
  autoscale/day.autoscale   same day, autoscaled (resize counts included)
  autoscale/day.verdict     the headline: chip-hours saved at equal-or-
                            better p99 SLO hit rate (asserted, not just
                            printed)

``--json PATH`` writes the seeded record — ``benchmarks/
BENCH_autoscale.json`` is the committed baseline ``benchmarks/
check_perf.py`` gates CI against (bit-exact chip-hours / hit rate /
resize count plus a throughput ratio):

    PYTHONPATH=src python -m benchmarks.bench_autoscale \
        --json benchmarks/BENCH_autoscale.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):   # `python benchmarks/bench_autoscale.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

from benchmarks.common import emit, timed
from repro.cluster import (AutoscaleController, AutoscaleSpec,
                           ClusterScheduler, serving_workload)

DAY_S = 86400.0
PODS = 2
TENANTS = 2
SEED = 0
CURVE = "diurnal"
FIXED_PROFILE = "8s.128c"
START_PROFILE = "1s.16c"


def run_day(mode: str, *, seed: int = SEED, curve: str = CURVE,
            horizon_s: float = DAY_S, pods: int = PODS,
            tenants: int = TENANTS, spec: AutoscaleSpec = None):
    """One modeled serving day. ``mode`` is "autoscale" (start small,
    hysteresis resizes) or "fixed" (peak-size slices, observe only)."""
    assert mode in ("autoscale", "fixed")
    jobs, curves = serving_workload(
        n_tenants=tenants, curve=curve, horizon_s=horizon_s, seed=seed,
        start_profile=START_PROFILE if mode == "autoscale"
        else FIXED_PROFILE)
    if spec is None:
        spec = AutoscaleSpec()
    if mode == "fixed":
        spec = AutoscaleSpec(**{**spec.__dict__, "mode": "observe"})
    ctrl = AutoscaleController(curves, spec, seed=seed)
    sched = ClusterScheduler(n_pods=pods, horizon_s=horizon_s,
                             autoscaler=ctrl)
    records, metrics = sched.run(jobs)
    return records, metrics, ctrl


def run_baseline(seed: int = SEED) -> dict:
    """The committed-baseline regime, as one JSON record."""
    t0 = time.perf_counter()
    _, fixed_m, _ = run_day("fixed", seed=seed)
    _, auto_m, ctrl = run_day("autoscale", seed=seed)
    wall_s = time.perf_counter() - t0
    intervals = ctrl._intervals
    return {
        "bench": "autoscale.day",
        "seed": seed,
        "curve": CURVE,
        "horizon_s": DAY_S,
        "interval_s": AutoscaleSpec().interval_s,
        "pods": PODS,
        "tenants": TENANTS,
        "fixed_chip_hours": round(fixed_m.serving_chip_hours, 6),
        "fixed_slo_hit_rate": round(fixed_m.serving_slo_hit_rate, 6),
        "auto_chip_hours": round(auto_m.serving_chip_hours, 6),
        "auto_slo_hit_rate": round(auto_m.serving_slo_hit_rate, 6),
        "auto_p99_s": round(auto_m.serving_p99_s, 6),
        "resizes": auto_m.autoscale_resizes,
        "grows": ctrl._grows,
        "shrinks": ctrl._shrinks,
        "migrations": ctrl._migrations,
        "savings_pct": round(100.0 * (1.0 - auto_m.serving_chip_hours
                                      / fixed_m.serving_chip_hours), 2),
        "wall_s": round(wall_s, 2),
        "intervals_per_s": round(2 * intervals / wall_s, 1),
    }


def run() -> None:
    with timed() as tf:
        _, fixed_m, _ = run_day("fixed")
    emit("autoscale/day.fixed", tf["us"],
         f"chip_hours={fixed_m.serving_chip_hours:.1f} "
         f"slo_hit={fixed_m.serving_slo_hit_rate:.3f} "
         f"p99={fixed_m.serving_p99_s:.1f}s resizes=0")
    with timed() as ta:
        _, auto_m, ctrl = run_day("autoscale")
    emit("autoscale/day.autoscale", ta["us"],
         f"chip_hours={auto_m.serving_chip_hours:.1f} "
         f"slo_hit={auto_m.serving_slo_hit_rate:.3f} "
         f"p99={auto_m.serving_p99_s:.1f}s "
         f"resizes={auto_m.autoscale_resizes} "
         f"(grow={ctrl._grows} shrink={ctrl._shrinks} "
         f"migrate={ctrl._migrations})")
    # the headline claim, asserted: fewer chip-hours at an
    # equal-or-better p99 SLO hit rate
    assert auto_m.serving_chip_hours < fixed_m.serving_chip_hours, \
        "autoscale must beat fixed provisioning on chip-hours"
    assert auto_m.serving_slo_hit_rate >= fixed_m.serving_slo_hit_rate, \
        "autoscale must not trade SLO hits for the savings"
    saved = 100.0 * (1.0 - auto_m.serving_chip_hours
                     / fixed_m.serving_chip_hours)
    emit("autoscale/day.verdict", 0.0,
         f"chip_hours_saved={saved:.1f}% at slo_hit "
         f"{auto_m.serving_slo_hit_rate:.3f} vs "
         f"{fixed_m.serving_slo_hit_rate:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the baseline record (the committed "
                         "BENCH_autoscale.json regime)")
    args = ap.parse_args()
    record = run_baseline(seed=args.seed)
    print(json.dumps(record, indent=2))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(record, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
