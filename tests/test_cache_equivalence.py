"""Prefill + single-token decode must equal the full forward pass — per
family, including MoE (with no capacity drops) and the SSM/hybrid states."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.shapes import PREFILL, ShapeSuite
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model

ENV = host_axis_env()
S_P, S_MAX, B = 96, 128, 2

ARCHS = ["llama3-8b", "qwen3-32b", "starcoder2-7b", "phi3-mini-3.8b",
         "command-r-35b", "granite-moe-1b-a400m", "phi3.5-moe-42b-a6.6b",
         "mamba2-130m", "zamba2-1.2b", "whisper-large-v3", "qwen2-vl-72b",
         "gpt2-124m"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced().with_(remat="none", capacity_factor=8.0)
    model = build_model(cfg, ENV)
    params, _ = model.init(jax.random.PRNGKey(1))
    full = model.synthetic_batch(ShapeSuite("f", PREFILL, S_P + 1, B),
                                 jax.random.PRNGKey(7))
    logits_full, _, _ = model.forward(params, full)
    want = logits_full[:, -1, :].astype(jnp.float32)

    pf = dict(full)
    if "tokens" in pf:
        pf["tokens"] = full["tokens"][:, :S_P]
    if "embeds" in pf:
        pf["embeds"] = full["embeds"][:, :S_P]
    if "positions" in pf:
        pf["positions"] = full["positions"][:, :, :S_P]
    _, _, cache = model.forward(params, pf, return_cache=True)

    big = model.init_cache(B, S_MAX)
    cache2 = jax.tree_util.tree_map(
        lambda d, s: (d.at[:, :, :S_P].set(s.astype(d.dtype))
                      if d.ndim >= 3 and d.shape[2] == S_MAX and
                      s.shape[2] == S_P else s.astype(d.dtype)),
        big, cache)

    db = {"pos": jnp.asarray(S_P, jnp.int32)}
    if cfg.family == "vlm":
        db["embeds"] = full["embeds"][:, S_P:S_P + 1]
        db["positions"] = full["positions"][:, :, S_P:S_P + 1]
    else:
        db["tokens"] = full["tokens"][:, S_P:S_P + 1]
    got, _ = model.decode(params, cache2, db)
    got = got.astype(jnp.float32)

    rel = float(jnp.max(jnp.abs(got - want)) /
                (jnp.max(jnp.abs(want)) + 1e-9))
    assert rel < 0.02, f"{arch}: rel={rel}"


def test_ragged_positions_match_scalar_decode():
    """Per-row cache positions (continuous batching) must agree with running
    each row separately at its own scalar position."""
    cfg = get_config("llama3-8b").reduced().with_(remat="none")
    model = build_model(cfg, ENV)
    params, _ = model.init(jax.random.PRNGKey(3))
    toks = model.synthetic_batch(ShapeSuite("f", PREFILL, 48, 2),
                                 jax.random.PRNGKey(9))["tokens"]
    lens = [16, 32]

    # batched ragged decode
    cache = model.init_cache(2, 64)
    for b, L in enumerate(lens):
        _, _, pc = model.forward(params, {"tokens": toks[b:b + 1, :L]},
                                 return_cache=True)
        cache = jax.tree_util.tree_map(
            lambda d, s, b=b, L=L: (d.at[:, b:b + 1, :L].set(s.astype(d.dtype))
                                    if d.shape[2] == 64 else
                                    d.at[:, b:b + 1].set(s.astype(d.dtype))),
            cache, pc)
    next_toks = jnp.stack([toks[0, lens[0]:lens[0] + 1],
                           toks[1, lens[1]:lens[1] + 1]])
    ragged, _ = model.decode(params, cache, {
        "tokens": next_toks, "pos": jnp.asarray(lens, jnp.int32)})

    # per-row scalar decode
    for b, L in enumerate(lens):
        c1 = model.init_cache(1, 64)
        _, _, pc = model.forward(params, {"tokens": toks[b:b + 1, :L]},
                                 return_cache=True)
        c1 = jax.tree_util.tree_map(
            lambda d, s, L=L: (d.at[:, :, :L].set(s.astype(d.dtype))
                               if d.shape[2] == 64 else s.astype(d.dtype)),
            c1, pc)
        single, _ = model.decode(params, c1, {
            "tokens": next_toks[b:b + 1], "pos": jnp.asarray(L, jnp.int32)})
        rel = float(jnp.max(jnp.abs(single[0] - ragged[b])) /
                    (jnp.max(jnp.abs(single)) + 1e-9))
        assert rel < 0.02, f"row {b}: rel={rel}"
