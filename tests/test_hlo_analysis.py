"""Loop-aware HLO analyzer: exact flops on known programs, trip-count
recovery, collective accounting, slicing-aware traffic."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hlo_analysis import analyze_hlo, parse_module


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_matmul_flops_exact():
    x = jnp.zeros((64, 128), jnp.float32)
    w = jnp.zeros((128, 256), jnp.float32)
    cost = analyze_hlo(_compile_text(lambda a, b: a @ b, x, w))
    assert cost.flops == 2 * 64 * 128 * 256


def test_scan_trip_count_multiplies_flops():
    x = jnp.zeros((32, 64), jnp.float32)
    w = jnp.zeros((64, 64), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    cost = analyze_hlo(_compile_text(f, x, w))
    assert cost.flops == 7 * 2 * 32 * 64 * 64
    assert 7 in cost.trip_counts.values()


def test_nested_scans_multiply():
    x = jnp.zeros((16, 32), jnp.float32)
    w = jnp.zeros((32, 32), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    cost = analyze_hlo(_compile_text(f, x, w))
    assert cost.flops == 5 * 3 * 2 * 16 * 32 * 32


def test_tuple_types_with_index_comments_parse():
    """Regression: /*index=N*/ comments inside while tuple types must not
    break op parsing (observed in large real modules)."""
    hlo = textwrap.dedent("""\
    HloModule m
    %body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
      %p = (s32[], f32[4,4]) parameter(0)
      ROOT %t = (s32[], f32[4,4]) tuple(%p)
    }
    %cond (p: (s32[], f32[4,4])) -> pred[] {
      %p.1 = (s32[], f32[4,4]) parameter(0)
      %c = s32[] constant(11)
      ROOT %cmp = pred[] compare(%c, %c), direction=LT
    }
    ENTRY %main () -> f32[4,4] {
      %init = (s32[], f32[4,4], /*index=2*/f32[8,8]) tuple()
      %w = (s32[], f32[4,4]) while(%init), condition=%cond, body=%body
      ROOT %g = f32[4,4] get-tuple-element(%w), index=1
    }
    """)
    comps, ops = parse_module(hlo)
    whiles = [o for c in comps.values() for o in c.ops if o.opcode == "while"]
    assert len(whiles) == 1
    cost = analyze_hlo(hlo)
    assert cost.trip_counts.get("body") == 11


def test_slicing_traffic_counts_window_not_operand():
    big = jnp.zeros((1024, 256), jnp.float32)  # 1 MiB

    def f(x):
        return jax.lax.dynamic_slice(x, (0, 0), (8, 256)) * 2.0
    cost = analyze_hlo(_compile_text(f, big))
    # traffic must be ~KBs (window), not ~MBs (whole operand)
    assert cost.bytes_accessed < 200_000, cost.bytes_accessed


def test_collectives_counted_with_trips():
    """Runs in a subprocess with 8 host devices (this process must keep 1)."""
    prog = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.hlo_analysis import analyze_hlo
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ("model",))
        def f(x, w):
            def body(c, _):
                # contraction over the model-sharded dim -> all-reduce that
                # depends on the carry (cannot be hoisted out of the loop)
                y = jnp.tanh(c @ w)
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, P(None, "model")))
                return y, None
            y, _ = jax.lax.scan(body, x, None, length=6)
            return y.sum()
        xs = jax.ShapeDtypeStruct((32, 64), jnp.float32,
                                  sharding=NamedSharding(mesh, P(None, "model")))
        ws = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                                  sharding=NamedSharding(mesh, P("model", None)))
        cost = analyze_hlo(jax.jit(f).lower(xs, ws).compile().as_text())
        counts = cost.collective_counts
        assert sum(counts.values()) >= 6, counts
        print("OK", counts)
        """)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, cwd="/root/repo", timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
