"""The Action API transaction contract (cluster/actions.py).

The headline property: for ANY action, ``apply()`` followed by
``rollback()`` leaves the observable cluster state exactly as it was —
partitioner rectangles (by tenant, with free/dead chip masks),
``PodSimulator`` job sets (every progress/delay/throttle input), pod
power draw, the scheduler queue, and every counter — across randomized
action sequences on randomized mid-flight cluster states (hypothesis).
Slice ids may advance (probe trials release/re-allocate rectangles in
place; that is the documented PR 4 contract), which is why the
fingerprint is id-agnostic.

Also here: probes are observably side-effect-free, probed outcomes price
what apply() then charges, and the uniform probe API returns reasons on
infeasible bindings.
"""
import pytest

from repro.core.hw import MI300_POD, V5E_POD
from repro.cluster import (ClusterScheduler, PolicySpec, TraceConfig,
                           generate_trace, lookahead_showcase,
                           migration_showcase, preemption_showcase,
                           reconfigure_showcase)
from repro.cluster.actions import (Grow, MigrateAcrossPods, Place, Preempt,
                                   ReconfigurePartition, Repack, Shrink,
                                   capture, restore)
from repro.cluster.scheduler import JobRecord
from repro.cluster.trace import BATCH, TRAINING, Job

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:   # the property still runs via the seeded sweep below
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# fingerprinting (id-agnostic observable state)
# ---------------------------------------------------------------------------
def fingerprint(sched):
    out = []
    for pod in sched.pods:
        part = pod.partitioner
        out.append({
            "mode": pod.mode,
            "ladder": tuple(p.name for p in part.profiles),
            "rects": sorted((a.tag, a.profile.name, a.origin)
                            for a in part.allocations.values()),
            "free": (part._grid == -1).tobytes(),
            "dead": (part._grid == -2).tobytes(),
            "sim_now": pod.sim.now,
            "sim": {k: (j.n_chips, j.u_compute, j.step_time, j.steps,
                        j.work_total, j.work_done, j.delay_s, j.fixed_s,
                        j.pinned)
                    for k, j in pod.sim.jobs.items()},
            "draw": pod.sim.draw(),
            "throttle": pod.sim.throttle(),
            "jobs": {jid: (r.profile_name, r.origin, r.finish_s,
                           r.resident_bytes, r.preemptions, r.migrations,
                           r.shrunk, r.grown, r.suspended)
                     for jid, r in pod.jobs.items()},
        })
    out.append(tuple(id(r) for r in sched._queue))
    out.append({n: getattr(sched, n) for n in (
        "_repacks", "_repack_failures", "_shrinks", "_grows",
        "_preemptions", "_resumes", "_wasted_checkpoint_chip_s",
        "_migrated_bytes", "_migration_s", "_migrations",
        "_dcn_migrated_bytes", "_dcn_migration_s", "_power_deferrals",
        "_reconfigs")})
    return out


_PODS = {"v5e": V5E_POD, "mi300": MI300_POD}


def _mid_state(seed, n_pods=2, horizon=400.0, chip="v5e"):
    """A mid-flight cluster: a seeded trace scheduled up to ``horizon``
    virtual seconds, pods still holding running jobs."""
    trace = generate_trace(TraceConfig(seed=seed, n_jobs=14,
                                       mean_interarrival_s=20.0))
    sched = ClusterScheduler(n_pods=n_pods, policy="frag_repack",
                             horizon_s=horizon, spec=PolicySpec(),
                             pod=_PODS[chip])
    sched.run(trace)
    return sched


def _beneficiary(sched, i, profile, kind=TRAINING, arch="llama3-8b",
                 shape="train_4k", slo=50.0):
    """A synthetic high-priority deadline job record the rescue actions
    can fight for."""
    t = sched._now
    job = Job(job_id=10_000 + i, kind=kind, arch=arch, shape=shape,
              arrival_s=t, steps=5, profile=profile, slo_factor=slo,
              priority=3)
    from repro.cluster.placement import ideal_duration
    ideal = ideal_duration(job, sched.chip, sched.perf)
    return JobRecord(job, deadline_s=(t + slo * ideal
                                      if ideal is not None else None))


_PROFILES = ("1s.16c", "2s.32c", "4s.64c", "8s.128c")
_KINDS = ("place", "repack", "shrink", "preempt", "migrate", "grow",
          "reconfigure")


def _find_action(sched, kind, rec, t):
    """Bind one feasible action of ``kind`` on the current state, or
    None."""
    if kind == "place":
        cands = sched.policy.candidates(rec.job, sched.pods, sched.chip,
                                        t, rec.deadline_s, perf=sched.perf)
        for cand in cands:
            act = Place(rec, cand)
            if act.probe(sched, t).feasible:
                return act
        return None
    if kind == "repack":
        return Repack.find(sched, rec, t)
    if kind == "shrink":
        return Shrink.find(sched, rec, t)
    if kind == "preempt":
        return Preempt.find(sched, rec, t)
    if kind == "migrate":
        return MigrateAcrossPods.find(sched, rec, t)
    if kind == "reconfigure":
        return ReconfigurePartition.find(sched, rec, t)
    if kind == "grow":
        for pod in sched.pods:
            for r in sorted(pod.jobs.values(), key=lambda r: r.job.job_id):
                if r.executed or r.finished or r.job.duration_s is not None:
                    continue
                act = Grow.find(sched, pod, r, t)
                if act is not None:
                    return act
        return None
    raise AssertionError(kind)


# ---------------------------------------------------------------------------
# the round-trip property (ISSUE satellite): apply();rollback() == identity
# across randomized action sequences. The body is shared between the
# hypothesis test (CI, where hypothesis is installed) and a deterministic
# seeded sweep (runs everywhere).
# ---------------------------------------------------------------------------
def _roundtrip_body(seed, kinds, profiles, chip="v5e"):
    sched = _mid_state(seed, chip=chip)
    t = sched._now
    before = fingerprint(sched)
    applied = []
    for i, kind in enumerate(kinds):
        rec = _beneficiary(sched, i, profiles[i % len(profiles)])
        act = _find_action(sched, kind, rec, t)
        if act is None:
            continue
        act.apply(sched, t)
        applied.append(act)
    for act in reversed(applied):
        act.rollback(sched)
    assert fingerprint(sched) == before
    return len(applied)


def _probe_body(seed, profile):
    sched = _mid_state(seed)
    t = sched._now
    rec = _beneficiary(sched, 0, profile)
    before = fingerprint(sched)
    for kind in ("place", "shrink", "preempt", "migrate"):
        act = _find_action(sched, kind, rec, t)
        if act is not None:
            act.probe(sched, t)
    # Repack/Grow probe via snapshot+restore
    Repack(rec).probe(sched, t)
    for pod in sched.pods:
        for r in pod.jobs.values():
            if not r.executed and r.job.duration_s is None:
                Grow(r, pod).probe(sched, t)
                break
    assert fingerprint(sched) == before


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 7),
           kinds=st.lists(st.sampled_from(_KINDS), min_size=1, max_size=4),
           profiles=st.lists(st.sampled_from(_PROFILES), min_size=4,
                             max_size=4),
           chip=st.sampled_from(("v5e", "mi300")))
    def test_apply_rollback_roundtrip_over_random_sequences(seed, kinds,
                                                            profiles, chip):
        _roundtrip_body(seed, kinds, profiles, chip=chip)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 7),
           profile=st.sampled_from(_PROFILES))
    def test_probe_is_observably_side_effect_free(seed, profile):
        _probe_body(seed, profile)


def _equivalence_body(seed, kinds, profiles):
    """The undo-log ↔ snapshot equivalence oracle: the same action
    sequence on two byte-identical mid-flight states — one rolling back
    through the copy-on-write undo log (default), one through the legacy
    full capture/restore (``snapshot_rollback=True``) — must agree on
    the observable state after every apply AND after the rollbacks."""
    undo = _mid_state(seed)
    snap = _mid_state(seed)
    snap.snapshot_rollback = True
    assert _x_fingerprint(undo) == _x_fingerprint(snap)
    before = fingerprint(undo)
    applied = []
    for i, kind in enumerate(kinds):
        pair = []
        for sched in (undo, snap):
            rec = _beneficiary(sched, i, profiles[i % len(profiles)])
            act = _find_action(sched, kind, rec, sched._now)
            if act is not None:
                act.apply(sched, sched._now)
            pair.append(act)
        assert (pair[0] is None) == (pair[1] is None)
        assert _x_fingerprint(undo) == _x_fingerprint(snap)
        if pair[0] is not None:
            applied.append(pair)
    for u_act, s_act in reversed(applied):
        u_act.rollback(undo)
        s_act.rollback(snap)
        assert _x_fingerprint(undo) == _x_fingerprint(snap)
    assert fingerprint(undo) == before
    assert not undo._txns          # no leaked open transactions
    return len(applied)


def _x_fingerprint(sched):
    """``fingerprint`` made comparable across scheduler instances: queue
    membership by job id instead of record identity."""
    fp = fingerprint(sched)
    fp[-2] = tuple(r.job.job_id for r in sched._queue)
    return fp


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 7),
           kinds=st.lists(st.sampled_from(_KINDS), min_size=1, max_size=4),
           profiles=st.lists(st.sampled_from(_PROFILES), min_size=4,
                             max_size=4))
    def test_undo_log_matches_snapshot_rollback(seed, kinds, profiles):
        _equivalence_body(seed, kinds, profiles)


def test_undo_log_matches_snapshot_rollback_seeded_sweep():
    import random
    rng = random.Random(1)
    total = 0
    for seed in range(4):
        kinds = rng.sample(_KINDS, k=4)
        profiles = [rng.choice(_PROFILES) for _ in range(4)]
        total += _equivalence_body(seed, kinds, profiles)
    for kind in _KINDS:
        total += _equivalence_body(1, [kind] * 2, list(_PROFILES))
    assert total >= 5


def test_apply_rollback_roundtrip_seeded_sweep():
    """Hypothesis-free sweep of the same property: every action kind must
    round-trip on several mid-flight states, and at least a handful of
    actions must actually have been applied (the sweep is not vacuous)."""
    import itertools
    import random
    rng = random.Random(0)
    total = 0
    for seed in range(4):
        kinds = rng.sample(_KINDS, k=4)
        profiles = [rng.choice(_PROFILES) for _ in range(4)]
        total += _roundtrip_body(seed, kinds, profiles)
    # every kind individually, on one state
    for kind in _KINDS:
        total += _roundtrip_body(1, [kind] * 2, list(_PROFILES))
    assert total >= 5


def test_apply_rollback_roundtrip_mi300_seeded_sweep():
    """The same round-trip property on multi-mode (mi300) mid-flight
    states, with ``reconfigure`` in every sequence — pod ``mode`` and the
    partitioner's profile ladder are part of the fingerprint, so a mode
    switch that survives rollback fails loudly."""
    import random
    rng = random.Random(2)
    total = 0
    for seed in range(4):
        kinds = ["reconfigure"] + rng.sample(_KINDS, k=3)
        profiles = [rng.choice(_PROFILES) for _ in range(4)]
        total += _roundtrip_body(seed, kinds, profiles, chip="mi300")
    assert total >= 3


def test_probe_side_effect_free_seeded_sweep():
    for seed, profile in ((0, "8s.128c"), (1, "1s.16c"), (2, "4s.64c")):
        _probe_body(seed, profile)


# ---------------------------------------------------------------------------
# deterministic transaction checks on the crafted showcase states
# ---------------------------------------------------------------------------
def _paused(trace_fn, n_pods, horizon, spec=None, pod=V5E_POD):
    sched = ClusterScheduler(n_pods=n_pods, policy="frag_repack",
                             horizon_s=horizon,
                             spec=spec or PolicySpec(), pod=pod)
    sched.run(trace_fn())
    return sched


def test_preempt_apply_rollback_exact_on_showcase_state():
    # pause the preemption showcase before the deadline arrival, then
    # drive the eviction by hand
    sched = _paused(preemption_showcase, 1, horizon=5.0)
    t = 10.0
    rec = _beneficiary(sched, 0, "8s.128c")
    before = fingerprint(sched)
    act = Preempt.find(sched, rec, t)
    assert act is not None and act.outcome.feasible
    assert act.victim_id == 0                 # the priority-0 batch holder
    cost = act.outcome.cost_s
    assert cost == pytest.approx(
        2 * act.victim.resident_bytes / sched._pod_host_bw)
    act.apply(sched, t)
    assert sched._preemptions == 1
    assert act.victim.suspended is not None
    assert any(q is act.victim for q in sched._queue)
    act.rollback(sched)
    assert fingerprint(sched) == before
    assert act.victim.suspended is None and act.victim.preemptions == 0
    assert rec.place_s is None                # beneficiary fields restored


def test_migrate_apply_rollback_exact_on_showcase_state():
    sched = _paused(migration_showcase, 2, horizon=5.0)
    t = 10.0
    rec = _beneficiary(sched, 0, "8s.128c", arch="qwen3-32b")
    before = fingerprint(sched)
    act = MigrateAcrossPods.find(sched, rec, t)
    assert act is not None and act.outcome.feasible
    # DCN pricing, not host links
    assert act.outcome.cost_s == pytest.approx(
        2 * act.victim.resident_bytes / sched._dcn_bw)
    victim = act.victim
    src_idx = victim.pod_idx
    act.apply(sched, t)
    assert victim.pod_idx != src_idx and victim.migrations == 1
    assert sched._migrations == 1 and sched._dcn_migrated_bytes > 0
    act.rollback(sched)
    assert fingerprint(sched) == before
    assert victim.pod_idx == src_idx and victim.migrations == 0


def test_lookahead_enabler_rollback_is_exact():
    # the exact path LookAheadPolicy exercises: apply a beneficiary-less
    # eviction, then roll it back
    sched = _paused(lookahead_showcase, 1, horizon=5.0)
    t = 10.0
    rec = _beneficiary(sched, 0, "8s.128c")
    before = fingerprint(sched)
    enablers = list(Preempt.enablers(sched, rec, t))
    assert [e.victim_id for e in enablers] == [0, 1]
    enabler = enablers[0]
    out = enabler.probe(sched, t)
    assert out.feasible and out.start_delay_s > 0
    enabler.apply(sched, t)
    assert sched._preemptions == 1
    enabler.rollback(sched)
    assert fingerprint(sched) == before


def test_shrink_apply_rollback_exact_on_showcase_state():
    from repro.cluster import elastic_showcase
    sched = _paused(elastic_showcase, 1, horizon=5.0)
    t = 10.0
    rec = _beneficiary(sched, 0, "4s.64c", arch="qwen3-32b")
    before = fingerprint(sched)
    act = Shrink.find(sched, rec, t)
    assert act is not None and act.outcome.feasible
    assert act.victim.job.kind == BATCH
    assert act.outcome.cost_s == pytest.approx(
        int(act.small.plan.resident_bytes) / sched._pod_host_bw)
    act.apply(sched, t)
    assert sched._shrinks == 1 and act.victim.shrunk
    assert rec.place_s == t
    act.rollback(sched)
    assert fingerprint(sched) == before
    assert not act.victim.shrunk and rec.place_s is None


def test_repack_find_apply_rollback_spans_the_scan():
    from repro.cluster import fragmentation_showcase
    # pause right after the five short jobs complete (t=100): 128 chips
    # free but scattered — the stranding state repack() exists for
    sched = _paused(fragmentation_showcase, 1, horizon=100.5)
    t = 101.0
    rec = _beneficiary(sched, 0, "8s.128c", arch="qwen3-32b")
    before = fingerprint(sched)
    act = Repack.find(sched, rec, t)
    assert act is not None and act.outcome.feasible
    assert act.outcome.cost_s > 0          # moved resident bytes, priced
    act.apply(sched, t)
    assert sched._repacks == 1 and rec.place_s == t
    act.rollback(sched)                     # spans find()+apply()
    assert fingerprint(sched) == before


def test_grow_find_apply_rollback_on_showcase_state():
    from repro.cluster import grow_showcase
    # pause after the short neighbour completed (t=50): the training job
    # may extend into the freed rectangle
    sched = _paused(grow_showcase, 1, horizon=60.0)
    t = 60.0
    pod = sched.pods[0]
    rec = next(iter(pod.jobs.values()))
    before = fingerprint(sched)
    act = Grow.find(sched, pod, rec, t)
    assert act is not None and act.outcome.feasible
    act.apply(sched, t)
    assert sched._grows == 1 and rec.grown
    act.rollback(sched)
    assert fingerprint(sched) == before
    assert not rec.grown


def test_reconfigure_apply_rollback_exact_on_showcase_state():
    # pause the reconfigure showcase before the deadline arrival, then
    # drive the mode switch by hand: drain, flip, place — and undo it all
    sched = _paused(reconfigure_showcase, 2, horizon=5.0, pod=MI300_POD)
    t = 10.0
    # the slack must cover the 30 s switch downtime: steps=5 of decode is
    # milliseconds of work, so the slo factor carries the slack
    rec = _beneficiary(sched, 0, "16s.256c", kind=BATCH,
                       arch="llama3-8b", shape="decode_32k", slo=1e5)
    before = fingerprint(sched)
    act = ReconfigurePartition.find(sched, rec, t)
    assert act is not None and act.outcome.feasible
    mode = sched._modes[act.mode_name]
    # priced as drain traffic + the fixed mode-switch downtime
    assert act.outcome.cost_s == pytest.approx(
        act.drain_total_s + mode.switch_downtime_s)
    assert act.outcome.start_delay_s >= mode.switch_downtime_s
    act.apply(sched, t)
    assert sched._reconfigs == 1
    assert act.pod.mode == act.mode_name != sched.base_mode
    assert sched._migrations == 1      # the drained holder moved over DCN
    assert rec.place_s == t and rec.pod_idx == act.pod.idx
    act.rollback(sched)
    assert fingerprint(sched) == before
    assert act.pod.mode == sched.base_mode
    assert rec.place_s is None


def test_reconfigure_infeasible_on_single_mode_chip():
    # v5e has only its fixed mode: find() has nothing to scan, so legacy
    # configurations are untouched even with "reconfigure" enabled
    sched = _paused(preemption_showcase, 1, horizon=5.0)
    rec = _beneficiary(sched, 0, "8s.128c")
    assert ReconfigurePartition.find(sched, rec, 10.0) is None


def test_infeasible_probes_carry_reasons():
    sched = _paused(preemption_showcase, 1, horizon=5.0)
    t = 10.0
    # no migration target on a single-pod cluster
    rec = _beneficiary(sched, 0, "8s.128c")
    assert MigrateAcrossPods.find(sched, rec, t) is None
    # a deadline with no slack: every preempt probe must explain itself
    rec_tight = _beneficiary(sched, 1, "8s.128c", slo=1e-9)
    act = Preempt.find(sched, rec_tight, t)
    assert act is None
    pod = sched.pods[0]
    victim = next(r for r in pod.jobs.values() if r.job.kind == BATCH)
    from repro.cluster.actions import slo_profiles
    sc = next(iter(sched.perf.options(rec_tight.job)))
    probe = Preempt(rec_tight, pod, victim, sc).probe(sched, t)
    assert not probe.feasible and "SLO" in probe.reason


def test_capture_restore_roundtrip_direct():
    sched = _paused(preemption_showcase, 1, horizon=5.0)
    before = fingerprint(sched)
    snap = capture(sched)
    # brutalize the state
    pod = sched.pods[0]
    victim = next(iter(pod.jobs.values()))
    pod.sim.jobs[victim.job.job_id].delay_s += 123.0
    pod.partitioner.release(victim.slice_id)
    sched._shrinks += 7
    sched._queue.append(victim)
    assert fingerprint(sched) != before
    restore(sched, snap)
    assert fingerprint(sched) == before
