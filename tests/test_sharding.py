"""Multi-device sharding tests — run in subprocesses so THIS process keeps a
single device (dry-run semantics demand the 512-device env var is only ever
set inside launch/dryrun.py)."""
import json
import subprocess
import sys
import textwrap

import pytest

REPO = "/root/repo"


def _run(prog: str, timeout: int = 560) -> str:
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, cwd=REPO, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    return out.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    """Reduced llama3 on a 2×2 host mesh: the sharded loss must equal the
    single-device loss (GSPMD correctness end-to-end)."""
    prog = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.shapes import ShapeSuite, TRAIN
        from repro.launch.mesh import make_mesh_compat
        from repro.models.model_zoo import build_model
        from repro.models.common import host_axis_env

        mesh = make_mesh_compat((2, 2), ("data", "model"))
        cfg = get_config("llama3-8b").reduced().with_(
            num_heads=4, num_kv_heads=2, remat="none")
        shape = ShapeSuite("t", TRAIN, 64, 4)

        # single device reference
        m1 = build_model(cfg, host_axis_env())
        params, _ = m1.init(jax.random.PRNGKey(0))
        batch = m1.synthetic_batch(shape)
        ref = float(m1.loss_fn(params, batch))

        # sharded
        m = build_model(cfg, mesh)
        _, specs = m.init(None, abstract=True)
        sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        params_s = jax.tree_util.tree_map(jax.device_put, params, sh)
        bspec = {k: NamedSharding(mesh, sp)
                 for k, (_, _, sp) in m.batch_specs(shape).items()}
        batch_s = {k: jax.device_put(v, bspec[k]) for k, v in batch.items()}
        with mesh:
            got = float(jax.jit(m.loss_fn)(params_s, batch_s))
        assert abs(got - ref) / abs(ref) < 5e-3, (got, ref)
        print("LOSS_MATCH", got, ref)
        """)
    assert "LOSS_MATCH" in _run(prog)


def test_dryrun_single_cell_multi_pod():
    """One full dry-run cell on the 2×16×16 multi-pod mesh (512 devices):
    lower + compile must succeed and report roofline terms."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gpt2-124m",
         "--shape", "train_4k", "--mesh", "multi"],
        capture_output=True, text=True, cwd=REPO, timeout=560,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-2000:])
    assert "OK" in out.stdout


def test_compressed_grad_sync_reduces_dcn_bytes():
    """int8+EF cross-pod sync must cut cross-pod collective bytes vs fp32
    psum (measured from the compiled HLO, not claimed)."""
    prog = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.hlo_analysis import analyze_hlo
        from repro.launch.mesh import make_mesh_compat
        from repro.optim.compression import cross_pod_sync, init_error_feedback
        mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
        grads = {"w": jnp.ones((256, 256), jnp.float32)}
        err = init_error_feedback(grads)

        def against(compress):
            def f(g, e):
                return cross_pod_sync(g, e, mesh, compress=compress)
            with mesh:
                c = jax.jit(f).lower(grads, err).compile()
            return analyze_hlo(c.as_text()).total_collective_bytes

        comp = against(True)
        plain = against(False)
        assert comp < plain, (comp, plain)
        print("BYTES", comp, plain)
        """)
    assert "BYTES" in _run(prog)
