"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode,
plus hypothesis property tests on the numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref


def _rel_err(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    denom = np.max(np.abs(want)) + 1e-9
    return float(np.max(np.abs(got - want)) / denom)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,hd,bq,bk", [
    (1, 128, 2, 64, 128, 128),
    (2, 256, 4, 64, 128, 128),
    (1, 256, 1, 128, 64, 128),
    (2, 512, 2, 32, 128, 256),
])
def test_flash_attention_matches_ref(B, S, H, hd, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    want = ref.attention_ref(fold(q), fold(k), fold(v), causal=True)
    want = want.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert _rel_err(got, want) < tol


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 128, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 128, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(4, 128, 64)
    want = ref.attention_ref(fold(q), fold(k), fold(v), causal=False)
    want = want.reshape(2, 2, 128, 64).transpose(0, 2, 1, 3)
    assert _rel_err(got, want) < 2e-5


@pytest.mark.parametrize("causal,bq,bk", [(True, 64, 64), (True, 128, 64),
                                          (False, 64, 128)])
def test_flash_backward_kernel_matches_autodiff(causal, bq, bk):
    """The Pallas dq/dk/dv kernels against jax.vjp of naive attention."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    BH, S, hd = 4, 256, 64
    q, k, v, do = (jax.random.normal(kk, (BH, S, hd), jnp.float32)
                   for kk in ks)

    def naive(q, k, v):
        s = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(hd)
        if causal:
            mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
            s = jnp.where(mask[None], s, -jnp.inf)
        return jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, -1), v)

    out, dq, dk, dv = ops.flash_attention_grads(q, k, v, do, causal=causal,
                                                block_q=bq, block_k=bk)
    want_out, vjp = jax.vjp(naive, q, k, v)
    dq_r, dk_r, dv_r = vjp(do)
    for name, a, b in (("out", out, want_out), ("dq", dq, dq_r),
                       ("dk", dk, dk_r), ("dv", dv, dv_r)):
        assert _rel_err(a, b) < 1e-4, name


def test_flash_custom_vjp_matches_autodiff():
    """XLA-level flash custom VJP (used by attn_impl=xla_cv) vs autodiff."""
    from repro.models.attention import flash_attention_cv
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    B, S, H, hd = 2, 256, 2, 64
    q, k, v = (jax.random.normal(kk, (B, S, H, hd), jnp.float32) for kk in ks)

    def naive(q, k, v):
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
        return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)

    f_cv = lambda *a: jnp.sum(jnp.sin(flash_attention_cv(*a, True, 64, hd ** -0.5)))
    f_nv = lambda *a: jnp.sum(jnp.sin(naive(*a)))
    g1 = jax.grad(f_cv, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_nv, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert _rel_err(a, b) < 1e-4


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,nh,hp,N,chunk,nhb", [
    (1, 128, 4, 32, 64, 64, 4),
    (2, 256, 8, 32, 64, 128, 4),
    (1, 128, 2, 64, 128, 32, 2),
])
def test_ssd_matches_ref(B, S, nh, hp, N, chunk, nhb):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = 0.5 * jax.random.normal(ks[0], (B, S, nh, hp), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(0.3 * jax.random.normal(ks[2], (nh,)))
    B_ = 0.3 * jax.random.normal(ks[3], (B, S, N))
    C_ = 0.3 * jax.random.normal(ks[4], (B, S, N))
    got = ops.ssd(x, dt, A, B_, C_, chunk=chunk, nh_block=nhb)
    want = ref.ssd_ref(x, dt, A, B_, C_)
    assert _rel_err(got, want) < 1e-4


def test_ssd_kernel_agrees_with_model_ssd():
    """The Pallas kernel and the XLA-level chunked SSD in the model zoo
    implement the same recurrence."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, nh, hp, N = 2, 128, 4, 32, 64
    x = 0.5 * jax.random.normal(ks[0], (B, S, nh, hp), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(0.3 * jax.random.normal(ks[2], (nh,)))
    B_ = 0.3 * jax.random.normal(ks[3], (B, S, N))
    C_ = 0.3 * jax.random.normal(ks[4], (B, S, N))
    y_kernel = ops.ssd(x, dt, A, B_, C_, chunk=64, nh_block=4)
    y_model, _ = ssd_chunked(x, dt, A, B_, C_, chunk=64)
    assert _rel_err(y_kernel, y_model) < 1e-4


# ---------------------------------------------------------------------------
# grouped matmul / stream matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("E,C,d,f", [(2, 128, 128, 128), (4, 256, 128, 384),
                                     (1, 128, 256, 128)])
def test_gmm_matches_ref(E, C, d, f):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = jax.random.normal(ks[0], (E, C, d), jnp.float32)
    w = jax.random.normal(ks[1], (E, d, f), jnp.float32)
    assert _rel_err(ops.grouped_matmul(x, w), ref.gmm_ref(x, w)) < 1e-5


@pytest.mark.parametrize("M,K,N,bk", [(128, 512, 128, 256), (256, 1024, 384, 512)])
def test_stream_matmul_matches_ref(M, K, N, bk):
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    x = jax.random.normal(ks[0], (M, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, N), jnp.float32)
    got = ops.stream_matmul(x, w, block_k=bk)
    assert _rel_err(got, ref.matmul_ref(x, w)) < 1e-5


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.floats(0.1, 4.0))
def test_flash_attention_rows_sum_to_convex_combination(seed, scale):
    """Attention output is a convex combination of V rows → bounded by V's
    row-wise min/max (fp32, causal)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = scale * jax.random.normal(ks[0], (1, 128, 1, 64), jnp.float32)
    k = scale * jax.random.normal(ks[1], (1, 128, 1, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 1, 64), jnp.float32)
    out = np.asarray(ops.flash_attention(q, k, v, causal=True))
    vmax = float(np.max(v)) + 1e-4
    vmin = float(np.min(v)) - 1e-4
    assert out.max() <= vmax and out.min() >= vmin


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ssd_zero_input_is_zero(seed):
    B, S, nh, hp, N = 1, 64, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jnp.zeros((B, S, nh, hp), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, nh)))
    A = -jnp.exp(0.3 * jax.random.normal(ks[1], (nh,)))
    B_ = jax.random.normal(ks[2], (B, S, N))
    out = ops.ssd(x, dt, A, B_, B_, chunk=32, nh_block=2)
    assert np.allclose(np.asarray(out), 0.0, atol=1e-6)
