"""Pinned end-to-end timeline hashes (the PR 6 bit-identity contract).

The scale work (free-rectangle index, drain gate, indexed event heap,
undo-log rollback) is pure mechanism: every showcase and golden trace
must schedule each job to the exact same (place, finish) float pair as
before. These hashes were recorded on the pre-optimization tree; any
drift here means a hot-path rewrite changed a *decision*, not just its
speed.

``sha(records)`` hashes the repr of ``(job_id, place_s, finish_s)``
tuples — float repr round-trips exactly, so this pins bit-identical
times, not approximately equal ones.
"""
import hashlib

import pytest

from repro.cluster import (ClusterScheduler, PolicySpec, TraceConfig,
                           elastic_showcase, fragmentation_showcase,
                           generate_trace, grow_showcase,
                           lookahead_showcase, migration_showcase,
                           preemption_showcase, reconfigure_showcase,
                           search_showcase, twin_showcase)
from repro.core.hw import MI300_POD


def sha(records):
    return hashlib.sha256(
        repr([(r.job.job_id, r.place_s, r.finish_s)
              for r in records]).encode()).hexdigest()


SHOWCASE_PINS = {
    "fragmentation": (
        fragmentation_showcase,
        dict(n_pods=1, horizon_s=3000.0, spec=PolicySpec()),
        "00d93ed5aab508724410798f6b27023c3fa7139b5ea10b2caf32ad5e9032076e"),
    "elastic": (
        elastic_showcase,
        dict(n_pods=1, horizon_s=3000.0,
             spec=PolicySpec(actions=("shrink",))),
        "906942ab6d849c5bddd7f43a58d7cfea4f541e9a24395ad08c2e8a4a1cc86945"),
    "preemption": (
        preemption_showcase,
        dict(n_pods=1, spec=PolicySpec(actions=("shrink", "preempt"))),
        "658f1c422ca07647d98f23f065fe0f9dff13fc62d725b94ed2f777e2704031be"),
    "grow": (
        grow_showcase,
        dict(n_pods=1, horizon_s=3000.0, spec=PolicySpec(actions=("grow",))),
        "302fb76d7e1d2e7b9532f1e7a4a622c00fbc9a1441a3b86ee314766b76a1e519"),
    "migration": (
        migration_showcase,
        dict(n_pods=2, horizon_s=3000.0,
             spec=PolicySpec(actions=("shrink", "preempt", "migrate"))),
        "de8c9377f8eb1f954f646b92a6277ad7e105581b3b6ade00087434d435aead3c"),
    "lookahead": (
        lookahead_showcase,
        dict(n_pods=1, horizon_s=3000.0,
             spec=PolicySpec(selector="lookahead",
                             actions=("shrink", "preempt"))),
        "14f2bdc4a3ee504cd6255cc5933d2463bc29c1d191075ee8cecb65cb5cbb0f39"),
    # PR 8: the three-eviction chain only the best-first search commits
    "search": (
        search_showcase,
        dict(n_pods=1,
             spec=PolicySpec(selector="search",
                             actions=("shrink", "preempt"))),
        "3395a68d136691137546a5cfbdb92246181a5a3c52a9a0308b7b3e346af32770"),
    # PR 9: the twin-offload trace replayed with twin pricing left OFF —
    # the deadline job queues to a miss; the twin-on flip is asserted in
    # test_twin.py. This pin holds the default-off path bit-identical.
    "twin-off": (
        twin_showcase,
        dict(n_pods=1, spec=PolicySpec(actions=("shrink", "preempt"))),
        "3b829c2d72cd936198d09980e7af53b3ba809aa9e94774ee60bd42c8b148003c"),
    # PR 10: the MI300 mode-switch trace replayed with reconfigure OFF —
    # every pod stays pinned in the boot mode (spx-nps1) and the deadline
    # job waits out the tenants to a miss; the reconfigure-on flip is
    # asserted in test_reconfigure.py. This pin holds the mode-less
    # default path bit-identical.
    "reconfigure-off": (
        reconfigure_showcase,
        dict(n_pods=2, pod=MI300_POD,
             spec=PolicySpec(actions=("migrate",))),
        "391e6faec2fe799cb5a2a93a9b558535857f1fb3daea0acbc6552895147b3ad7"),
}


@pytest.mark.parametrize("name", sorted(SHOWCASE_PINS))
def test_showcase_timeline_pinned(name):
    trace_fn, kwargs, expected = SHOWCASE_PINS[name]
    sched = ClusterScheduler(policy="frag_repack", **kwargs)
    records, _ = sched.run(trace_fn())
    assert sha(records) == expected, (
        f"{name} showcase timeline drifted — a perf change altered a "
        f"scheduling decision")
    assert not sched._txns   # every recorded trial was closed


# the PR 2/3 goldens: seeded 48-job trace, frozen and progress engines
TRACE0_PINS = {
    True: ("429696d0b32a6c03aec769b791fd0683498c4ec9749b15f463820d6b919fb9c8",
           5841.312618401943),
    False: ("546680c49ee821980492c3bfbe2af8d65a862bc70edaa9f8e710870db60ce772",
            5890.25934641167),
}


@pytest.mark.parametrize("frozen", sorted(TRACE0_PINS))
def test_trace0_timeline_pinned(frozen):
    expected_sha, expected_makespan = TRACE0_PINS[frozen]
    jobs = generate_trace(TraceConfig(seed=0, n_jobs=48,
                                      mean_interarrival_s=5.0))
    sched = ClusterScheduler(n_pods=1, frozen_durations=frozen)
    records, metrics = sched.run(jobs)
    assert sha(records) == expected_sha
    assert metrics.makespan_s == expected_makespan
