"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 device by
design; multi-device sharding tests run in subprocesses (test_sharding.py)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end test")
