"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 device by
design; multi-device sharding tests run in subprocesses (test_sharding.py)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end test")


def pytest_addoption(parser):
    parser.addoption(
        "--durations-budget", type=float, default=None, metavar="SECONDS",
        help="fail the session when any single test phase exceeds this "
             "many seconds (the tier-1 CI budget: no test may hide an "
             "accidental complexity cliff inside the suite wall time)")


def pytest_runtest_logreport(report):
    budget = _BUDGET.get("limit")
    if budget is not None and report.duration > budget:
        _BUDGET.setdefault("over", []).append(
            (report.duration, report.when, report.nodeid))


_BUDGET = {}


def pytest_collection(session):
    _BUDGET["limit"] = session.config.getoption("--durations-budget")


def pytest_sessionfinish(session, exitstatus):
    over = _BUDGET.get("over")
    if over:
        lines = "\n".join(f"  {d:7.2f}s  {when:8s} {nodeid}"
                          for d, when, nodeid in sorted(over, reverse=True))
        print(f"\nduration budget of {_BUDGET['limit']}s exceeded by "
              f"{len(over)} test phase(s):\n{lines}")
        session.exitstatus = 1
