"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config, one forward + one train step on CPU — shapes + finiteness asserted.
The FULL configs are exercised only via the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config
from repro.configs.shapes import SHAPES, ShapeSuite, TRAIN, applicable
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model
from repro.optim import adamw

ENV = host_axis_env()
SMOKE_TRAIN = ShapeSuite("smoke_train", TRAIN, 64, 2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, ENV)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = model.synthetic_batch(SMOKE_TRAIN)
    logits, aux, _ = model.forward(params, batch)
    B, S = 2, 64
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits)), f"{arch}: non-finite logits"
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, ENV)
    params, _ = model.init(jax.random.PRNGKey(1))
    opt = adamw.init(params)
    batch = model.synthetic_batch(SMOKE_TRAIN)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    new_params, new_opt, metrics = adamw.update(adamw.AdamWConfig(), grads,
                                                opt, params)
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert jnp.all(jnp.isfinite(leaf)), f"{arch}: non-finite params"
    assert jnp.isfinite(metrics["grad_norm"])


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, ENV)
    params, _ = model.init(jax.random.PRNGKey(2))
    cache = model.init_cache(2, 32)
    batch = {"pos": jnp.asarray(0, jnp.int32)}
    if cfg.family == "vlm":
        batch["embeds"] = jnp.ones((2, 1, cfg.d_model), jnp.bfloat16)
        batch["positions"] = jnp.zeros((3, 2, 1), jnp.int32)
    else:
        batch["tokens"] = jnp.ones((2, 1), jnp.int32)
    logits, new_cache = model.decode(params, cache, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits)), f"{arch}: non-finite decode logits"
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(new_cache)


def test_param_counts_match_analytic():
    """init() parameter totals track the analytic param_count within 2%
    (analytic drives the offload planner and reward model)."""
    for arch in ("llama3-8b", "qwen3-32b", "granite-moe-1b-a400m",
                 "mamba2-130m", "gpt2-124m"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg, ENV)
        params, _ = model.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        expected = cfg.param_count()
        assert abs(actual - expected) / expected < 0.02, \
            (arch, actual, expected)


def test_cell_grid_covers_assignment():
    """10 archs × 4 shapes with documented skips = the assigned 40 cells."""
    total, runnable, skipped = 0, 0, []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            total += 1
            ok, reason = applicable(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped.append((arch, shape.name, reason))
    assert total == 40
    # long_500k runs only for the two sub-quadratic archs
    assert runnable == 32
    assert all(s[1] == "long_500k" for s in skipped)
