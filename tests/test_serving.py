"""Serving engine: continuous batching correctness vs naive per-request
decode; offloaded-KV (pinned_host) produces identical tokens."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model
from repro.serving.engine import Request, ServingEngine

ENV = host_axis_env()


def _model(arch="llama3-8b"):
    cfg = get_config(arch).reduced().with_(remat="none")
    model = build_model(cfg, ENV)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_decode(model, params, prompt, n_new, max_seq=64):
    """Single-request greedy decode, step by step."""
    cache = model.init_cache(1, max_seq)
    _, _, pc = model.forward(params, {"tokens": jnp.asarray(prompt)[None, :]},
                             return_cache=True)
    L = len(prompt)
    cache = jax.tree_util.tree_map(
        lambda d, s: (d.at[:, :, :L].set(s.astype(d.dtype))
                      if d.ndim >= 3 and d.shape[2] == max_seq else
                      s.astype(d.dtype)),
        cache, pc)
    out = []
    tok = int(prompt[-1])
    pos = L
    for _ in range(n_new):
        logits, cache = model.decode(params, cache, {
            "tokens": jnp.asarray([[tok]], jnp.int32),
            "pos": jnp.asarray(pos, jnp.int32)})
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
        pos += 1
    return out


def test_engine_matches_reference_single():
    cfg, model, params = _model()
    prompt = np.arange(1, 9, dtype=np.int32) % cfg.vocab_size
    want = _reference_decode(model, params, prompt, 6)
    eng = ServingEngine(model, params, slots=1, max_seq=64)
    out = eng.run([Request(0, prompt, 6)])
    assert out[0] == want


def test_engine_concurrent_requests_match_reference():
    cfg, model, params = _model()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7)]
    want = [_reference_decode(model, params, p, 5) for p in prompts]
    eng = ServingEngine(model, params, slots=2, max_seq=64)
    out = eng.run([Request(i, p, 5) for i, p in enumerate(prompts)])
    for i in range(3):
        assert out[i] == want[i], f"request {i}"


def test_offloaded_kv_same_tokens():
    """KV pool in host memory (the paper's offload scheme applied to
    serving) must not change results."""
    from repro.core.offload import host_memory_kind
    from repro.launch.mesh import make_host_mesh
    cfg, model, params = _model()
    mesh = make_host_mesh(1, 1)
    prompt = np.arange(2, 10, dtype=np.int32)
    base = ServingEngine(model, params, slots=1, max_seq=64)
    off = ServingEngine(model, params, slots=1, max_seq=64, mesh=mesh,
                        offload_kv=True)
    # verify placement actually happened ("pinned_host" on TPU/GPU; the CPU
    # backend has a single host space, so the kind degenerates there)
    kinds = {x.sharding.memory_kind
             for x in jax.tree_util.tree_leaves(off.cache)}
    assert kinds == {host_memory_kind(mesh)}
    out_a = base.run([Request(0, prompt, 5)])
    out_b = off.run([Request(0, prompt, 5)])
    assert out_a[0] == out_b[0]


def test_slots_are_recycled():
    cfg, model, params = _model("gpt2-124m")
    rng = np.random.default_rng(2)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=4).astype(np.int32), 3)
            for i in range(5)]
    eng = ServingEngine(model, params, slots=2, max_seq=32)
    out = eng.run(reqs)
    assert len(out) == 5
    assert all(len(v) == 3 for v in out.values())


def test_latency_stamps_under_queue_backlog():
    """Crafted backlog: one slot, three 2-token requests submitted at
    tick 0. Each request waits for its predecessor's two decode ticks,
    so the queue waits step 0/2/4 and end-to-end 2/4/6 — the stamps the
    autoscaler's SLO signal is built from."""
    cfg, model, params = _model("gpt2-124m")
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=3)
                    .astype(np.int32), 2) for i in range(3)]
    eng = ServingEngine(model, params, slots=1, max_seq=32)
    for r in reqs:
        assert eng.submit(r)
    while not eng.idle:
        eng.tick()
    assert [r.submit_tick for r in reqs] == [0, 0, 0]
    assert [r.admit_tick for r in reqs] == [0, 2, 4]
    assert [r.finish_tick for r in reqs] == [2, 4, 6]
    assert eng.stats.queue_wait_ticks == [0, 2, 4]
    assert eng.stats.e2e_ticks == [2, 4, 6]
    pct = eng.stats.latency_percentiles()
    assert pct["queue_wait_p50"] == 2.0
    assert pct["e2e_p50"] == 4.0
    assert pct["e2e_p99"] == pytest.approx(5.96)
    # empty stats stay well-defined (fresh engine, nothing served)
    empty = ServingEngine(model, params, slots=1, max_seq=32)
    assert all(v == 0.0
               for v in empty.stats.latency_percentiles().values())
