"""Benchmark harness plumbing: section resolution for --only/--list must
fail readably (nonzero SystemExit, no KeyError) on unknown section names."""
import sys

import pytest

from benchmarks.run import SECTIONS, main, resolve_sections


def test_resolve_sections_default_is_everything():
    assert resolve_sections(None) == list(SECTIONS)
    assert resolve_sections("") == list(SECTIONS)


def test_resolve_sections_subset_and_whitespace():
    assert resolve_sections("cluster") == ["cluster"]
    assert resolve_sections(" cluster , partition ") == ["cluster",
                                                         "partition"]


def test_resolve_sections_unknown_is_readable_systemexit():
    with pytest.raises(SystemExit) as exc:
        resolve_sections("clusterr")
    msg = str(exc.value)
    assert "clusterr" in msg and "valid" in msg
    # a string code is a message printed to stderr with exit status 1 —
    # nonzero, and never a bare KeyError traceback
    assert not isinstance(exc.value.code, int) or exc.value.code != 0


def test_list_flag_validates_only(monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv",
                        ["run.py", "--list", "--only", "nope"])
    with pytest.raises(SystemExit) as exc:
        main()
    assert "nope" in str(exc.value)


def test_list_flag_prints_requested_sections(monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv",
                        ["run.py", "--list", "--only", "cluster"])
    main()
    out = capsys.readouterr().out
    assert "benchmarks.bench_cluster" in out
    assert "benchmarks.bench_partition" not in out
