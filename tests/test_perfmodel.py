"""PerfModel + PodSimulator: memoized scoring, measured-anchor calibration,
progress-based execution (retro-active re-solve, resize, delays), and the
piecewise co-run energy integration in core.power."""
import json

import pytest

from repro.configs import get_config, get_shape
from repro.core.hw import V5E, V5E_POD
from repro.core.perfmodel import (InstanceLoad, PerfModel, PodSimulator,
                                  get_model, load_anchors)
from repro.core.power import co_run, pod_draw, throttle_factor
from repro.core.slices import PROFILES, get_profile
from repro.cluster.trace import TRAINING, Job


# ---------------------------------------------------------------------------
# PerfModel scoring + memoization
# ---------------------------------------------------------------------------
def test_score_matches_direct_roofline():
    perf = PerfModel()
    cfg, shape = get_config("llama3-8b"), get_shape("decode_32k")
    sc = perf.score(cfg, shape, get_profile("4s.64c"))
    assert sc is not None
    from repro.core.workload import WorkloadEstimate
    wl = WorkloadEstimate(cfg, shape)
    plan = wl.plan_for(get_profile("4s.64c"), V5E)
    spilled = plan.offloaded or plan.partial
    terms = wl.roofline_on(get_profile("4s.64c"), V5E,
                           plan if spilled else None)
    assert sc.step_time == terms.step_time
    assert sc.u_compute == pytest.approx(terms.t_compute / terms.step_time)
    assert sc.perf_per_chip > 0 and not sc.calibrated


def test_score_memoized_and_none_for_oversized():
    perf = PerfModel()
    cfg, shape = get_config("llama3-8b"), get_shape("train_4k")
    a = perf.score(cfg, shape, PROFILES[-1])
    assert a is perf.score(cfg, shape, PROFILES[-1])  # same object: memo hit
    # 8B training state (params+grads+adam fp32 ≈ 128 GiB + activations)
    # cannot fit 16 chips even with every offloadable tensor spilled? it can
    # via host DRAM — but some profile/arch combo must be infeasible:
    huge = get_config("qwen2-vl-72b")
    assert perf.score(huge, get_shape("train_4k"), get_profile("1s.16c")) is None


def test_options_smallest_first_and_pin():
    perf = PerfModel()
    free = Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, 10)
    opts = perf.options(free)
    assert len(opts) > 1
    chips = [sc.profile.n_chips for sc in opts]
    assert chips == sorted(chips)
    pinned = Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, 10,
                 profile="4s.64c")
    assert [sc.profile.name for sc in perf.options(pinned)] == ["4s.64c"]
    unpinned = perf.options(pinned, ignore_pin=True)
    assert len(unpinned) == len(opts)
    assert perf.options(pinned) is perf.options(pinned)  # memoized


def test_get_model_shared_instance():
    assert get_model(V5E) is get_model(V5E)


# ---------------------------------------------------------------------------
# measured-anchor calibration
# ---------------------------------------------------------------------------
def _write_anchor(tmp_path, arch, shape, flops_pc, bytes_pc, n_chips):
    d = tmp_path / "single"
    d.mkdir(exist_ok=True)
    rec = {"arch": arch, "shape": shape,
           "roofline": {"n_chips": n_chips,
                        "hlo_flops_per_chip": flops_pc,
                        "hlo_bytes_per_chip": bytes_pc,
                        "step_time_s": 0.5}}
    (d / f"{arch}__{shape}.json").write_text(json.dumps(rec))


def test_anchor_calibration_scales_terms(tmp_path):
    cfg, shape = get_config("gpt2-124m"), get_shape("train_4k")
    base = PerfModel().score(cfg, shape, get_profile("1s.16c"))
    from repro.core.workload import WorkloadEstimate
    wl = WorkloadEstimate(cfg, shape)
    # measured = 2× the analytic FLOPs, 3× the analytic bytes
    _write_anchor(tmp_path, "gpt2-124m", "train_4k",
                  2.0 * wl.flops() / 64, 3.0 * wl.hbm_bytes() / 64, 64)
    perf = PerfModel.from_artifacts(str(tmp_path))
    assert ("gpt2-124m", "train_4k") in perf.anchors
    sc = perf.score(cfg, shape, get_profile("1s.16c"))
    assert sc.calibrated
    assert sc.terms.t_compute == pytest.approx(2.0 * base.terms.t_compute)
    assert sc.terms.t_memory == pytest.approx(3.0 * base.terms.t_memory)
    # collective and host terms are untouched by the anchor
    assert sc.terms.t_collective == base.terms.t_collective
    # other (arch, shape) cells stay analytic
    other = perf.score(get_config("llama3-8b"), shape, get_profile("4s.64c"))
    assert not other.calibrated


def test_load_anchors_missing_and_broken(tmp_path):
    assert load_anchors(str(tmp_path / "nope")) == {}
    d = tmp_path / "single"
    d.mkdir()
    (d / "a__b.json").write_text(json.dumps({"arch": "a", "shape": "b",
                                             "error": "boom"}))
    assert load_anchors(str(tmp_path)) == {}


# ---------------------------------------------------------------------------
# PodSimulator — progress-based execution
# ---------------------------------------------------------------------------
def _sim(frozen=False):
    return PodSimulator(V5E_POD, frozen=frozen)


def test_single_job_unthrottled_finish():
    sim = _sim()
    fin = sim.admit(0, 128, 1.0, 2.0, 10, 0.0)
    assert fin == pytest.approx(20.0)   # alone: f=1, no stretch
    assert sim.finish_times(0.0)[0] == pytest.approx(20.0)


def test_admission_stretches_and_completion_unstretches():
    sim = _sim()
    sim.admit(0, 128, 1.0, 2.0, 10, 0.0)
    sim.advance(5.0)
    f0 = sim.finish_times(5.0)[0]
    assert f0 == pytest.approx(20.0)
    # second full-power 128-chip instance pushes the pod over the cap
    sim.admit(1, 128, 1.0, 2.0, 10, 5.0)
    f = sim.throttle()
    assert f < 1.0
    stretched = sim.finish_times(5.0)[0]
    assert stretched > f0   # retro-active: in-flight job re-projected later
    # progress during the contended window accrues slower than wall time
    sim.advance(10.0)
    assert sim.jobs[0].work_done == pytest.approx(5.0 + 5.0 * f, rel=1e-9)
    # removing the rival restores full speed for the remainder
    sim.remove(1)
    recovered = sim.finish_times(10.0)[0]
    assert f0 < recovered < stretched


def test_pinned_duration_ignores_throttle():
    sim = _sim()
    fin = sim.admit(0, 128, 1.0, 2.0, 10, 0.0, duration_s=50.0)
    assert fin == pytest.approx(50.0)
    sim.admit(1, 128, 1.0, 2.0, 10, 0.0)
    assert 0 not in sim.finish_times(0.0)  # fixed jobs are never re-projected
    sim.delay(0, 7.0)
    assert sim.jobs[0].delay_s == pytest.approx(7.0)


def test_frozen_mode_matches_legacy_duration_expression():
    sim = _sim(frozen=True)
    sim.admit(0, 128, 1.0, 2.0, 10, 0.0)
    fin = sim.admit(1, 128, 1.0, 2.0, 10, 0.0)
    loads = [InstanceLoad(128, 1.0, 2.0, 1)] * 2
    f = throttle_factor(loads, V5E_POD)
    t_comp = 2.0 * 1.0
    assert fin == 10 * (t_comp / f + (2.0 - t_comp))  # exact float match
    assert sim.finish_times(0.0) == {}  # frozen: nothing to re-project


def test_resize_preserves_progress_fraction():
    sim = _sim()
    sim.admit(0, 128, 0.5, 2.0, 10, 0.0)   # work_total = 20 nominal seconds
    sim.advance(10.0)
    assert sim.jobs[0].progress == pytest.approx(0.5)
    sim.resize(0, 16, 0.5, 8.0)            # smaller slice: slower steps
    j = sim.jobs[0]
    assert j.progress == pytest.approx(0.5)
    assert j.work_total == pytest.approx(80.0)
    assert sim.finish_times(10.0)[0] == pytest.approx(10.0 + 40.0)


def test_resize_rebases_frozen_duration_but_not_pinned():
    frozen = _sim(frozen=True)
    frozen.admit(0, 128, 0.0, 2.0, 10, 0.0)       # u=0: fixed_s = 20
    frozen.resize(0, 16, 0.0, 8.0)                # 4× slower steps
    assert frozen.jobs[0].fixed_s == pytest.approx(80.0)
    assert frozen.projected_finish(0, 0.0) == pytest.approx(80.0)
    pinned = _sim()
    pinned.admit(0, 128, 0.5, 2.0, 10, 0.0, duration_s=50.0)
    pinned.resize(0, 16, 0.5, 8.0)
    assert pinned.jobs[0].fixed_s == pytest.approx(50.0)  # contract holds


def test_delay_burns_before_work():
    sim = _sim()
    sim.admit(0, 16, 1.0, 1.0, 10, 0.0, start_delay=4.0)
    sim.advance(4.0)
    assert sim.jobs[0].work_done == pytest.approx(0.0)
    assert sim.jobs[0].delay_s == pytest.approx(0.0)
    sim.advance(6.0)
    assert sim.jobs[0].work_done == pytest.approx(2.0)


def test_checkpoint_cost_prices_save_and_restore_volumes():
    perf = PerfModel()
    gib = 2 ** 30
    cost = perf.checkpoint_cost(4 * gib, host_link_bw=2 * gib)
    assert cost.bytes == 4 * gib
    assert cost.save_s == pytest.approx(2.0)      # bytes / bw, once out ...
    assert cost.restore_s == pytest.approx(2.0)   # ... and once back in
    assert cost.total_s == pytest.approx(4.0)
    assert perf.checkpoint_cost(0, 2 * gib).total_s == 0.0


def test_admit_with_work_done_resumes_progress():
    # an instance resumed with work_done behaves exactly like one that
    # was admitted at t=0 and ran unthrottled to the same point
    fresh = _sim()
    fin_fresh = fresh.admit(0, 64, 1.0, 1.0, 100, 0.0)
    fresh.advance(40.0)
    resumed = _sim()
    fin_resumed = resumed.admit(0, 64, 1.0, 1.0, 100, 40.0, work_done=40.0)
    assert fin_resumed == pytest.approx(fin_fresh)
    assert resumed.jobs[0].work_done == pytest.approx(
        fresh.jobs[0].work_done)
    assert (resumed.finish_times(40.0)[0]
            == pytest.approx(fresh.finish_times(40.0)[0]))
    # work_done is clamped to the total (an already-finished resume)
    clamped = _sim()
    fin = clamped.admit(1, 64, 1.0, 1.0, 10, 0.0, work_done=99.0)
    assert fin == pytest.approx(0.0)


def test_admit_fixed_remaining_overrides_frozen_expression():
    sim = PodSimulator(V5E_POD, frozen=True)
    fin = sim.admit(0, 64, 0.5, 2.0, 10, 5.0, fixed_remaining=7.0,
                    start_delay=1.0)
    assert fin == pytest.approx(13.0)   # t + delay + remaining
    assert sim.jobs[0].fixed_s == pytest.approx(7.0)
    assert not sim.jobs[0].pinned       # frozen remainder, not a contract


def test_sim_draw_matches_power_model():
    sim = _sim()
    sim.admit(0, 64, 0.9, 1.0, 5, 0.0)
    sim.admit(1, 128, 0.8, 1.0, 5, 0.0)
    loads = [InstanceLoad(64, 0.9, 1.0, 1), InstanceLoad(128, 0.8, 1.0, 1)]
    assert sim.draw(capped=False) == pod_draw(loads, V5E_POD)
    assert sim.throttle() == throttle_factor(loads, V5E_POD)


# ---------------------------------------------------------------------------
# piecewise co-run energy (core.power)
# ---------------------------------------------------------------------------
def test_corun_energy_integrates_piecewise_over_completions():
    short = InstanceLoad(64, 0.9, 1.0, steps=10)
    long = InstanceLoad(64, 0.9, 1.0, steps=100)
    makespan, energy, eff = co_run([short, long], V5E_POD)
    assert makespan == pytest.approx(max(eff))
    cap = V5E_POD.power_cap_watts
    both = min(pod_draw([short, long], V5E_POD), cap)
    alone = min(pod_draw([long], V5E_POD), cap)
    expect = both * min(eff) + alone * (max(eff) - min(eff))
    assert energy == pytest.approx(expect)
    # strictly below the old constant-at-initial-draw account
    assert energy < both * makespan


def test_corun_energy_single_instance_unchanged():
    inst = InstanceLoad(128, 0.5, 2.0, steps=10)
    makespan, energy, eff = co_run([inst], V5E_POD)
    draw = min(pod_draw([inst], V5E_POD), V5E_POD.power_cap_watts)
    assert energy == pytest.approx(draw * makespan)


def test_perfmodel_corun_summary():
    perf = PerfModel()
    loads = [InstanceLoad(128, 1.0, 1.0, 10)] * 2
    run = perf.corun(loads, V5E_POD)
    assert run.throttled and run.throttle == throttle_factor(loads, V5E_POD)
    assert run.makespan_s == max(run.effective_times)
    assert run.energy_J > 0


def test_score_many_fills_memo_and_matches_score():
    perf = PerfModel()
    cfgs = [get_config("gpt2-124m"), get_config("llama3-8b")]
    shapes = [get_shape("decode_32k")]
    table = perf.score_many(cfgs, shapes)
    assert len(table) == len(cfgs) * len(shapes) * len(PROFILES)
    for cfg in cfgs:
        for shape in shapes:
            for p in PROFILES:
                assert table[(cfg.name, shape.name, p.name)] is \
                    perf.score(cfg, shape, p)   # shared memo, same objects


def test_slo_table_rows_match_options_and_lru_hits():
    perf = PerfModel()
    job = Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, steps=40)
    rows = perf.slo_table(job)
    assert rows == tuple((sc, job.steps * sc.step_time)
                         for sc in perf.options(job))
    assert perf.slo_table(job) is rows          # LRU hit, no rebuild
    pinned = Job(1, TRAINING, "llama3-8b", "train_4k", 0.0, steps=1,
                 profile="4s.64c", duration_s=123.0)
    assert [d for _, d in perf.slo_table(pinned)] == [123.0]   # pinned wall


def test_slo_table_lru_bounded():
    perf = PerfModel()
    perf._MAX_SLO_MEMO = 4
    for i in range(8):
        perf.slo_table(Job(i, TRAINING, "llama3-8b", "train_4k", 0.0,
                           steps=i + 1))
    assert len(perf._slo) == 4
