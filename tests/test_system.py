"""End-to-end system behaviour: the paper's full story on one pod.

Scenario (mirrors §VI): a multi-tenant pod hosts three workloads; the
reward selector picks slices (one of them via fine-grained offloading); the
static partitioner packs them; the co-run simulator prices throughput,
energy, and throttling; a failure triggers elastic repartition + replan.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_shape
from repro.core.cosched import mixed_tenancy
from repro.core.hw import GiB, V5E_POD
from repro.core.offload import inventory_from_tree, plan_offload, place_tree
from repro.core.partitioner import StaticPartitioner
from repro.core.reward import select, sweep
from repro.core.slices import get_profile
from repro.core.workload import WorkloadEstimate


def test_full_multi_tenant_flow():
    workloads = {
        "llm-serve": WorkloadEstimate(get_config("llama3-8b"),
                                      get_shape("decode_32k")),
        "ssm-serve": WorkloadEstimate(get_config("mamba2-130m"),
                                      get_shape("decode_32k")),
        "moe-train": WorkloadEstimate(get_config("granite-moe-1b-a400m"),
                                      get_shape("train_4k")),
    }
    # 1. reward-driven selection (α = 0.1, per-tenant quota of half a pod —
    #    a real multi-tenant scheduler constrains individual tenants)
    placement = {}
    for tag, wl in workloads.items():
        pts = [p for p in sweep(wl, alpha=0.1) if p.profile.n_chips <= 128]
        assert pts, tag
        placement[tag] = pts[0].profile.name

    # 2. pack onto one pod — must fit together
    result = mixed_tenancy(workloads, placement)
    assert result["pod_utilization"] <= 1.0
    assert result["makespan_s"] > 0
    assert 0 < result["throttle_factor"] <= 1.0

    # 3. the llama3 decode (527 GiB) placement uses offloading on a small
    #    slice rather than a 1024 GiB slice (the paper's core claim)
    rows = {tag: prof for tag, prof, *_ in result["placements"]}
    wl = workloads["llm-serve"]
    prof = get_profile(rows["llm-serve"])
    if wl.footprint_bytes() > prof.hbm_bytes(V5E_POD.chip):
        plan = wl.plan_for(prof)
        assert plan.fits and plan.host_bytes > 0

    # 4. failure: kill a chip, elastic re-admit of the displaced tenant
    part = StaticPartitioner()
    allocs = {tag: part.allocate(get_profile(p), tag=tag)
              for tag, p in placement.items()}
    victim_tag = min(allocs, key=lambda t: allocs[t].slice_id)
    origin = allocs[victim_tag].origin
    affected = part.fail_chips([origin])
    assert allocs[victim_tag].slice_id in affected
    new_prof = part.largest_free_profile()
    assert new_prof is not None
    realloc = part.allocate(new_prof, tag=victim_tag + "-elastic")
    part.validate()
    # replanned offload still fits on the (possibly smaller) new slice
    wl_victim = workloads[victim_tag]
    plan2 = wl_victim.plan_for(realloc.profile)
    # either fits directly or via offloading; if not even offload fits,
    # the runner would queue — assert the planner reports it coherently
    assert plan2.resident_bytes + plan2.host_bytes == \
        sum(t.bytes for t in wl_victim.inventory())


def test_offload_plan_applies_real_memory_kinds():
    """plan → place_tree puts exactly the planned leaves in the host tier
    ("pinned_host" on TPU/GPU; degenerate single-space CPU backend here)."""
    from repro.core.offload import device_memory_kind, host_memory_kind
    from repro.launch.mesh import make_host_mesh
    from jax.sharding import PartitionSpec as P
    mesh = make_host_mesh(1, 1)
    host_kind, dev_kind = host_memory_kind(mesh), device_memory_kind(mesh)
    tree = {
        "opt": {"mu": jnp.zeros((128, 128)), "nu": jnp.zeros((128, 128))},
        "params": {"w": jnp.zeros((64, 64))},
    }
    specs = {"opt": {"mu": P(), "nu": P()}, "params": {"w": P()}}
    inv = inventory_from_tree(tree)
    # budget fits only the params -> moments must spill
    budget = 64 * 64 * 4 + 1024
    plan = plan_offload(inv, budget)
    assert plan.fits
    placed = place_tree(tree, specs, plan, mesh)
    kinds = {path: leaf.sharding.memory_kind
             for path, leaf in zip(
                 ["opt/mu", "opt/nu", "params/w"],
                 jax.tree_util.tree_leaves(placed))}
    assert kinds["opt/mu"] == host_kind
    assert kinds["opt/nu"] == host_kind
    assert kinds["params/w"] == dev_kind
    # data is intact wherever it lives
    assert float(jnp.sum(placed["opt"]["mu"])) == 0.0


def test_reward_sweep_is_exhaustive_and_sorted():
    wl = WorkloadEstimate(get_config("phi3-mini-3.8b"), get_shape("prefill_32k"))
    pts = sweep(wl, alpha=0.3)
    assert pts, "no feasible configuration found"
    rewards = [p.reward for p in pts]
    assert rewards == sorted(rewards, reverse=True)
    # every point is genuinely feasible
    for p in pts:
        cap = p.profile.hbm_bytes(V5E_POD.chip)
        resident = (p.plan.resident_bytes if p.plan
                    else wl.footprint_bytes())
        assert resident <= cap
