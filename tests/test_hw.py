"""Hardware model: chip/mode registry, effective-chip derivation, ladders.

Covers the partition-mode subsystem of ``repro.core.hw``: the CLI-facing
chip registry (``get_chip``), the per-chip mode tables
(``partition_modes`` / ``default_mode`` / ``get_mode``), the mode-scaled
roofline constants (``effective_chip``) and the granularity-gated slice
ladder (``ladder_for``). The bit-identity contract — identity modes hand
back the *same* ChipSpec object — is what keeps every PR 2-9 timeline pin
byte-stable, so it gets its own tests.
"""
import pytest

from repro.core.hw import (CHIPS, FIXED_MODE, MI300_MODES, MI300_POD, MI300X,
                           V5E, V5E_POD, PartitionMode, default_mode,
                           effective_chip, get_chip, get_mode, ladder_for,
                           partition_modes)
from repro.core.slices import PROFILES


# ---------------------------------------------------------------------------
# registry lookups
# ---------------------------------------------------------------------------
def test_chip_registry_resolves_both_families():
    assert get_chip("v5e") is V5E
    assert get_chip("mi300") is MI300X
    assert set(CHIPS) == {"v5e", "mi300"}


def test_unknown_chip_fails_readably():
    with pytest.raises(ValueError, match=r"unknown chip 'h100'.*v5e"):
        get_chip("h100")


def test_v5e_is_single_fixed_mode():
    modes = partition_modes(V5E)
    assert set(modes) == {"fixed"}
    assert modes["fixed"] == FIXED_MODE
    assert default_mode(V5E) == "fixed"
    assert FIXED_MODE.is_identity


def test_mi300_mode_table():
    modes = partition_modes(MI300X)
    assert set(modes) == {"spx-nps1", "spx-nps4", "cpx-nps1", "cpx-nps4"}
    assert default_mode(MI300X) == "spx-nps1"
    # the default mode is the identity — boot state matches the raw spec
    assert modes["spx-nps1"].is_identity
    for name, mode in modes.items():
        assert mode.name == name
        assert mode.compute in ("spx", "cpx")
        assert mode.memory in ("nps1", "nps4")
        assert mode.switch_downtime_s > 0.0


def test_partition_modes_returns_a_copy():
    modes = partition_modes(MI300X)
    modes["bogus"] = FIXED_MODE
    assert "bogus" not in partition_modes(MI300X)


def test_get_mode_resolves_and_fails_readably():
    assert get_mode(MI300X, "cpx-nps4") is MI300_MODES["cpx-nps4"]
    assert get_mode(V5E, "fixed") == FIXED_MODE
    with pytest.raises(ValueError,
                       match=r"unknown partition mode 'spx'.*mi300x.*cpx-nps1"):
        get_mode(MI300X, "spx")


def test_derived_chip_has_fixed_mode_only():
    eff = effective_chip(MI300X, MI300_MODES["cpx-nps4"])
    assert set(partition_modes(eff)) == {"fixed"}
    assert default_mode(eff) == "fixed"


# ---------------------------------------------------------------------------
# effective_chip: identity object-return + scaled derivation
# ---------------------------------------------------------------------------
def test_identity_mode_returns_base_object():
    # bit-identity contract: everything memo-keyed on the ChipSpec (PerfModel
    # caches, profile_key, ProbeCache signatures) is unchanged by default
    assert effective_chip(V5E, FIXED_MODE) is V5E
    assert effective_chip(MI300X, MI300_MODES["spx-nps1"]) is MI300X


def test_scaled_mode_derives_and_memoizes():
    mode = MI300_MODES["cpx-nps4"]
    eff = effective_chip(MI300X, mode)
    assert eff is not MI300X
    assert eff is effective_chip(MI300X, mode)     # memoized
    assert eff.name == "mi300x:cpx-nps4"
    assert eff.peak_flops_bf16 == pytest.approx(
        MI300X.peak_flops_bf16 * 1.05)
    assert eff.hbm_bw == pytest.approx(MI300X.hbm_bw * 1.30)
    assert eff.hbm_bytes == int(MI300X.hbm_bytes * 0.75)
    # untouched axes carry through
    assert eff.ici_bw_per_link == MI300X.ici_bw_per_link
    assert eff.host_link_bw == MI300X.host_link_bw


def test_nps4_trades_capacity_for_bandwidth():
    eff = effective_chip(MI300X, MI300_MODES["spx-nps4"])
    assert eff.hbm_bw > MI300X.hbm_bw
    assert eff.hbm_bytes < MI300X.hbm_bytes
    assert eff.peak_flops_bf16 == MI300X.peak_flops_bf16  # spx: no flops delta


# ---------------------------------------------------------------------------
# ladder gating
# ---------------------------------------------------------------------------
def test_cpx_ladder_is_full_table():
    assert ladder_for(MI300_MODES["cpx-nps1"]) == tuple(PROFILES)
    assert ladder_for(FIXED_MODE) == tuple(PROFILES)


def test_spx_ladder_respects_granularity_floor():
    floor = MI300_MODES["spx-nps1"].min_slice_chips
    ladder = ladder_for(MI300_MODES["spx-nps1"])
    assert ladder
    assert all(p.n_chips >= floor for p in ladder)
    assert {p.name for p in PROFILES} - {p.name for p in ladder} \
        == {p.name for p in PROFILES if p.n_chips < floor}


def test_custom_floor_gates_ladder():
    mode = PartitionMode(name="coarse", min_slice_chips=256)
    assert [p.name for p in ladder_for(mode)] == ["16s.256c"]


# ---------------------------------------------------------------------------
# pod-level derived figures
# ---------------------------------------------------------------------------
def test_mi300_pod_shape_matches_v5e_grid():
    assert MI300_POD.rows == V5E_POD.rows == 16
    assert MI300_POD.n_chips == 256
    assert MI300_POD.n_hosts == 32
    assert MI300_POD.dcn_bw == pytest.approx(32 * 12.5e9)
    assert MI300_POD.power_cap_watts == pytest.approx(
        0.85 * 256 * MI300X.active_watts)
