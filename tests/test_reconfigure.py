"""ReconfigurePartition end-to-end: the mode-switch showcase miss→hit.

``trace.reconfigure_showcase`` pins two long TRAINING tenants (priority 1)
on a 2-pod MI300 cluster and fires one HBM-bound BATCH decode job with an
SLO factor < 1: no spx-nps1 placement can meet its deadline, and the
tenants outrank it so every eviction rescue is priority-blocked. With
``reconfigure`` enabled the planner drains pod 0's tenant over the DCN,
pays the fixed switch downtime into cpx-nps4 (+30% effective HBM
bandwidth — the decode step is purely bandwidth-bound), and places the
job in time. These tests assert the flip, the pricing identity, the
first-feasible mode ordering, and that the probe-cache generation is
mode-keyed.
"""
import pytest

from repro.cluster import (ClusterScheduler, PolicySpec,
                           ReconfigurePartition, reconfigure_showcase)
from repro.core.hw import MI300_POD, MI300X, get_mode
from repro.core.perfmodel import model_for_mode


def _run(actions):
    sched = ClusterScheduler(n_pods=2, pod=MI300_POD, policy="frag_repack",
                             spec=PolicySpec(actions=actions))
    records, metrics = sched.run(reconfigure_showcase())
    deadline_job = next(r for r in records if r.job.job_id == 2)
    return sched, metrics, deadline_job


def test_without_reconfigure_deadline_job_misses_slo():
    # spx-nps1 physics: the decode step can't beat an 0.9x-ideal deadline,
    # and the priority-1 tenants block every eviction rescue — the job
    # waits out the tenants and misses
    sched, metrics, deadline_job = _run(("migrate",))
    assert metrics.reconfigs == 0 and metrics.migrations == 0
    assert deadline_job.place_s == pytest.approx(50_000.0)
    assert deadline_job.finish_s > deadline_job.deadline_s
    assert metrics.slo_attainment == pytest.approx(2 / 3)
    assert [p.mode for p in sched.pods] == ["spx-nps1", "spx-nps1"]


def test_reconfigure_turns_slo_miss_into_hit():
    sched, metrics, deadline_job = _run(("migrate", "reconfigure"))
    assert metrics.reconfigs == 1
    assert metrics.migrations == 1          # the drain leg
    assert metrics.slo_attainment == pytest.approx(1.0)
    assert deadline_job.place_s == pytest.approx(10.0)
    assert deadline_job.finished
    assert deadline_job.finish_s <= deadline_job.deadline_s
    # pod 0 switched; pod 1 (now holding both tenants) stayed in the base
    assert [p.mode for p in sched.pods] == ["cpx-nps4", "spx-nps1"]
    for pod in sched.pods:
        pod.partitioner.validate()


def test_reconfigure_priced_as_drain_plus_downtime():
    sched, metrics, deadline_job = _run(("migrate", "reconfigure"))
    victim = next(r for r in sched.records if r.job.job_id == 0)
    assert victim.pod_idx == 1 and victim.migrations == 1
    save_s = victim.dcn_bytes / sched._dcn_bw
    assert victim.dcn_delay_s == pytest.approx(2 * save_s)
    downtime = get_mode(MI300X, "cpx-nps4").switch_downtime_s
    # beneficiary start = arrival + drain save + fixed switch outage; its
    # step time is the nps4 (1.30x bandwidth) decode step
    perf4 = model_for_mode(MI300X, get_mode(MI300X, "cpx-nps4"))
    step4 = perf4.options(deadline_job.job)[0].step_time
    assert deadline_job.step_time_s == pytest.approx(step4)
    assert deadline_job.finish_s == pytest.approx(
        10.0 + save_s + downtime + deadline_job.job.steps * step4)


def test_first_feasible_mode_is_cpx_nps4():
    # sorted probe order is cpx-nps1 < cpx-nps4 < spx-nps4; cpx-nps1 keeps
    # nps1 bandwidth, so the HBM-bound decode gains nothing and the probe
    # must reject it — the committed mode is the *second* candidate
    sched = ClusterScheduler(n_pods=2, pod=MI300_POD, policy="frag_repack",
                             horizon_s=15.0,
                             spec=PolicySpec(actions=("migrate",)))
    sched.run(reconfigure_showcase())
    rec = next(r for r in sched.records if r.job.job_id == 2)
    assert rec.place_s is None              # still queued at the pause
    act = ReconfigurePartition.find(sched, rec, sched._now)
    assert act is not None and act.outcome.feasible
    assert act.mode_name == "cpx-nps4"
    bad = ReconfigurePartition(rec, act.pod, "cpx-nps1")
    assert not bad.probe(sched, sched._now).feasible


def test_probe_cache_generation_is_mode_keyed():
    # PodState.generation — the ProbeCache signature — must move when only
    # the mode moves, else stale fixed-mode prices leak across a switch
    sched = ClusterScheduler(n_pods=1, pod=MI300_POD, policy="frag_repack")
    pod = sched.pods[0]
    g0 = pod.generation
    assert pod.mode in g0
    pod.mode = "cpx-nps4"
    assert pod.generation != g0
    pod.mode = "spx-nps1"
    assert pod.generation == g0
