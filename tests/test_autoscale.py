"""The autoscale subsystem: seeded load curves (cluster/loadgen.py) and
the SLO-driven hysteresis controller (cluster/autoscale.py).

The headline property is the day-in-the-life claim itself — autoscaling
must beat fixed peak provisioning on chip-hours at an equal-or-better
SLO hit rate — plus determinism (bit-identical same-seed replay) and the
anti-flapping guarantee: the controller never issues two actions for the
same tenant within one cooldown window, across randomized diurnal and
bursty seeds (hypothesis where installed, a seeded sweep everywhere).
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.cluster import (AutoscaleController, AutoscaleSpec, BurstyCurve,
                           ClusterScheduler, ConstantCurve, DiurnalCurve,
                           TraceConfig, arrival_counts, arrival_times,
                           format_metrics, generate_trace, get_curve,
                           service_rate, serving_workload)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:   # the property still runs via the seeded sweep below
    HAVE_HYPOTHESIS = False

DAY = 14400.0   # compressed 4h "day" — one full diurnal period, ~3 ms/run
SPEC = AutoscaleSpec(interval_s=300.0, cooldown_s=900.0)


def _run(mode="autoscale", *, seed=0, curve="diurnal", tenants=1, pods=1,
         day=DAY, spec=None):
    """One modeled serving day; "fixed" provisions at peak and observes."""
    spec = spec if spec is not None else SPEC
    if mode == "fixed":
        spec = AutoscaleSpec(**{**spec.__dict__, "mode": "observe"})
    jobs, curves = serving_workload(
        n_tenants=tenants, curve=curve, horizon_s=day, seed=seed,
        start_profile="1s.16c" if mode == "autoscale" else "8s.128c")
    ctrl = AutoscaleController(curves, spec, seed=seed)
    sched = ClusterScheduler(n_pods=pods, horizon_s=day, autoscaler=ctrl)
    records, metrics = sched.run(jobs)
    return records, metrics, ctrl


# ---------------------------------------------------------------------------
# loadgen: curve shapes, composition, seeded determinism
# ---------------------------------------------------------------------------
def test_diurnal_curve_shape():
    c = DiurnalCurve(base_rps=2.0, peak_rps=10.0, period_s=1000.0,
                     phase_s=125.0)
    assert c.rate(125.0) == pytest.approx(2.0)            # trough at phase
    assert c.rate(625.0) == pytest.approx(10.0)           # peak half a period on
    assert c.rate(125.0 + 1000.0) == pytest.approx(2.0)   # periodic
    mid = c.rate(375.0)
    assert 2.0 < mid < 10.0
    # composition: sum and scale stay curves
    combo = 2.0 * c + ConstantCurve(1.0)
    assert combo.rate(625.0) == pytest.approx(21.0)


def test_bursty_curve_is_seeded_and_bounded_below():
    a = BurstyCurve(1.0, 5.0, mean_gap_s=200.0, decay_s=50.0, seed=3,
                    horizon_s=2000.0)
    b = BurstyCurve(1.0, 5.0, mean_gap_s=200.0, decay_s=50.0, seed=3,
                    horizon_s=2000.0)
    ts = np.linspace(0.0, 2000.0, 101)
    assert [a.rate(t) for t in ts] == [b.rate(t) for t in ts]
    assert all(a.rate(t) >= 1.0 for t in ts)              # base is a floor
    c = BurstyCurve(1.0, 5.0, mean_gap_s=200.0, decay_s=50.0, seed=4,
                    horizon_s=2000.0)
    assert [a.rate(t) for t in ts] != [c.rate(t) for t in ts]
    with pytest.raises(ValueError, match="unknown load curve"):
        get_curve("nope")


def test_arrival_counts_seeded_and_calibrated():
    c = DiurnalCurve(base_rps=1.0, peak_rps=3.0, period_s=3600.0)
    a = arrival_counts(c, 300.0, 12, seed=7)
    b = arrival_counts(c, 300.0, 12, seed=7)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, arrival_counts(c, 300.0, 12, seed=8))
    # a full period of a sinusoid integrates to its mean rate
    expect = 0.5 * (1.0 + 3.0) * 3600.0
    assert abs(a.sum() - expect) / expect < 0.15
    # exact thinned timestamps: sorted, in range, seeded
    t1 = arrival_times(c, 600.0, seed=5)
    t2 = arrival_times(c, 600.0, seed=5)
    assert np.array_equal(t1, t2)
    assert np.all(np.diff(t1) >= 0) and t1.min() >= 0 and t1.max() < 600.0


def test_service_rate_scales_with_chips():
    mu16 = service_rate("gpt2-124m", "1s.16c")
    mu32 = service_rate("gpt2-124m", "2s.32c")
    assert mu32 == pytest.approx(2.0 * mu16, rel=1e-6)


# ---------------------------------------------------------------------------
# the headline: autoscale beats fixed provisioning (asserted, both regimes)
# ---------------------------------------------------------------------------
def test_autoscale_beats_fixed_on_chip_hours_at_equal_slo():
    _, fixed_m, _ = _run("fixed", tenants=2, pods=2, day=28800.0)
    _, auto_m, ctrl = _run("autoscale", tenants=2, pods=2, day=28800.0)
    assert auto_m.serving_chip_hours < fixed_m.serving_chip_hours
    assert auto_m.serving_slo_hit_rate >= fixed_m.serving_slo_hit_rate
    assert auto_m.autoscale_resizes > 0 and ctrl._grows > 0 \
        and ctrl._shrinks > 0
    # both tenants start on pod 0; tenant 0's grow is locally blocked, so
    # the migrate-toward-headroom fallback must fire organically
    assert ctrl._migrations > 0
    assert any(kind == "migrate" for _, _, kind in ctrl.action_log)
    # cheaper per SLO hit, not just cheaper
    assert auto_m.chip_hours_per_slo_hit < fixed_m.chip_hours_per_slo_hit


def test_same_seed_replay_is_bit_identical():
    _, m1, c1 = _run("autoscale", tenants=2, pods=2, seed=3)
    _, m2, c2 = _run("autoscale", tenants=2, pods=2, seed=3)
    assert dataclasses.asdict(m1) == dataclasses.asdict(m2)
    assert c1.action_log == c2.action_log
    assert [(t, j, dataclasses.astuple(s)) for t, j, s in c1.signal_log] \
        == [(t, j, dataclasses.astuple(s)) for t, j, s in c2.signal_log]


# ---------------------------------------------------------------------------
# anti-flapping: no two actions for one tenant within a cooldown window
# (hypothesis on CI, the seeded sweep everywhere)
# ---------------------------------------------------------------------------
def _flapping_body(seed, curve):
    _, _, ctrl = _run("autoscale", seed=seed, curve=curve,
                      tenants=2, pods=2)
    per_tenant = {}
    for t, jid, kind in ctrl.action_log:
        per_tenant.setdefault(jid, []).append((t, kind))
    for jid, acts in per_tenant.items():
        times = [t for t, _ in acts]
        assert times == sorted(times)
        for (t0, k0), (t1, k1) in zip(acts, acts[1:]):
            gap = t1 - t0
            assert gap >= SPEC.cooldown_s, (
                f"tenant {jid} flapped: {k0}@{t0} then {k1}@{t1} "
                f"({gap}s < cooldown {SPEC.cooldown_s}s)")
    return len(ctrl.action_log)


if HAVE_HYPOTHESIS:
    @settings(max_examples=16, deadline=None)
    @given(seed=st.integers(0, 15),
           curve=st.sampled_from(["diurnal", "bursty"]))
    def test_no_flapping_within_cooldown(seed, curve):
        _flapping_body(seed, curve)


def test_no_flapping_within_cooldown_seeded_sweep():
    total = 0
    for curve in ("diurnal", "bursty"):
        for seed in range(6):
            total += _flapping_body(seed, curve)
    assert total >= 10, "sweep is vacuous: almost no actions were issued"


# ---------------------------------------------------------------------------
# budget, observe mode, plumbing
# ---------------------------------------------------------------------------
def test_chip_hours_budget_denies_and_rolls_back():
    # 1s.16c for a 4h day is exactly 64 chip-hours — the floor the budget
    # cannot undercut (it only gates *increases*). A cap below the floor
    # means every projected grow exceeds it: all denied, all rolled back,
    # and the spend stays exactly at the floor
    spec = AutoscaleSpec(**{**SPEC.__dict__, "chip_hours_budget": 60.0})
    _, m, ctrl = _run("autoscale", spec=spec)
    assert ctrl._grows == 0 and ctrl._budget_denials > 0
    assert m.serving_chip_hours == pytest.approx(64.0)
    # the denied transactions left no trace: the run still replays
    _, m2, ctrl2 = _run("autoscale", spec=spec)
    assert dataclasses.asdict(m) == dataclasses.asdict(m2)
    assert ctrl2._budget_denials == ctrl._budget_denials


def test_observe_mode_watches_without_acting():
    _, m, ctrl = _run("fixed")
    assert ctrl.action_log == [] and m.autoscale_resizes == 0
    assert ctrl._intervals > 0 and ctrl.signal_log, \
        "observe mode must still produce the latency accounting"
    assert m.serving_slo_hit_rate == 1.0


def test_max_queue_rejections_trigger_scale_up():
    # an admission bound converts backlog into rejections; rejections are
    # a scale-up trigger even when rho alone would not trip the watermark
    spec = AutoscaleSpec(**{**SPEC.__dict__, "max_queue": 5.0,
                            "hi_watermark": 10.0})   # rho can never trip
    _, _, ctrl = _run("autoscale", spec=spec)
    assert any(s.rejected > 0 for _, _, s in ctrl.signal_log)
    assert ctrl._grows > 0


def test_autoscaler_requires_horizon():
    jobs, curves = serving_workload(n_tenants=1, horizon_s=DAY, seed=0)
    ctrl = AutoscaleController(curves, SPEC, seed=0)
    with pytest.raises(ValueError, match="horizon"):
        ClusterScheduler(n_pods=1, autoscaler=ctrl)


def test_metrics_default_zero_without_autoscaler():
    jobs = generate_trace(TraceConfig(seed=0, n_jobs=6))
    sched = ClusterScheduler(n_pods=1, execute_serving=False)
    _, m = sched.run(jobs)
    assert m.serving_chip_hours == 0.0 and m.autoscale_resizes == 0
    assert m.serving_p99_s == 0.0 and m.chip_hours_per_slo_hit == 0.0
    table = format_metrics([m])
    assert "serving SLO hit rate" in table and "autoscale resizes" in table
