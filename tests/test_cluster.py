"""ClusterScheduler stack: trace determinism, MISO-style placement,
fragmentation stranding + repack recovery (the bench_cluster scenario),
modeled migration cost, power-cap admission, the progress-based engine
(retro-active stretching, frozen-mode bit-identity with the PR 2
scheduler, elastic SLO rescue), live SliceRuntime execution, and metrics
sanity."""
import hashlib
from collections import Counter

import numpy as np
import pytest

from repro.cluster import (ClusterScheduler, TraceConfig, elastic_showcase,
                           fragmentation_showcase, generate_trace)
from repro.cluster.placement import (FirstFitPolicy, FragAwarePolicy,
                                     feasible_options, get_policy)
from repro.cluster.trace import BATCH, KINDS, SERVING, TRAINING, Job
from repro.core.hw import V5E_POD


# ---------------------------------------------------------------------------
# trace generator
# ---------------------------------------------------------------------------
def test_trace_deterministic_and_mixed():
    a = generate_trace(TraceConfig(seed=3))
    b = generate_trace(TraceConfig(seed=3))
    assert a == b
    assert a != generate_trace(TraceConfig(seed=4))
    kinds = Counter(j.kind for j in a)
    assert set(kinds) <= set(KINDS) and len(kinds) == 3
    arrivals = [j.arrival_s for j in a]
    assert arrivals == sorted(arrivals)
    assert all(j.requests > 0 for j in a if j.kind == SERVING)
    assert all(j.u_compute is not None and j.u_compute < 0.2
               for j in a if j.kind == BATCH)


def test_feasible_options_pinned_profile():
    job = Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, 10,
              profile="4s.64c")
    opts = feasible_options(job)
    assert [p.name for p, _, _ in opts] == ["4s.64c"]
    free = Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, 10)
    assert len(feasible_options(free)) > 1


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------
def test_first_fit_takes_smallest_feasible():
    sched = ClusterScheduler(n_pods=1, policy="first_fit")
    job = Job(0, SERVING, "llama3-8b", "decode_32k", 0.0, 100)
    cands = sched.policy.candidates(job, sched.pods, sched.chip, 0.0, None)
    smallest = feasible_options(job)[0][0]
    assert cands[0].profile.name == smallest.name
    assert cands[0].origin == (0, 0)


def test_frag_aware_candidates_sorted_and_scored():
    sched = ClusterScheduler(n_pods=2, policy="frag")
    job = Job(0, TRAINING, "qwen3-32b", "train_4k", 0.0, 20)
    cands = sched.policy.candidates(job, sched.pods, sched.chip, 0.0, None)
    assert cands, "empty cluster must offer candidates"
    flags = [c.meets_deadline for c in cands]
    assert flags == sorted(flags, reverse=True)
    for c in cands:
        assert c.perf_per_chip > 0
        assert c.largest_after >= 0


def test_get_policy_unknown():
    with pytest.raises(KeyError):
        get_policy("optimal")


# ---------------------------------------------------------------------------
# the stranding scenario (acceptance criterion: repack places a job
# first-fit leaves queued, on the same deterministic trace)
# ---------------------------------------------------------------------------
STRANDED = 10


def _run_showcase(policy):
    sched = ClusterScheduler(n_pods=1, policy=policy, horizon_s=3000.0)
    records, metrics = sched.run(fragmentation_showcase())
    big = next(r for r in records if r.job.job_id == STRANDED)
    return sched, records, metrics, big


def test_first_fit_strands_big_job():
    _, _, metrics, big = _run_showcase("first_fit")
    assert not big.placed, "first-fit should leave the 8x16 job queued"
    assert metrics.left_queued == 1
    assert metrics.repacks == 0
    assert metrics.frag_time_avg > 0.3  # scattered holes persist


def test_repack_places_stranded_job_with_migration_cost():
    sched, records, metrics, big = _run_showcase("frag_repack")
    assert big.placed and big.finished
    assert big.profile_name == "8s.128c"
    assert metrics.left_queued == 0
    assert metrics.repacks == 1 and metrics.repack_failures == 0
    assert metrics.migrated_bytes > 0
    assert metrics.migration_s == pytest.approx(
        metrics.migrated_bytes / sched._pod_host_bw)
    # the stranded job starts only after the migration delay
    assert big.finish_s > big.place_s + big.job.duration_s
    # defrag is visible in the time-averaged fragmentation ratio
    assert metrics.frag_time_avg < 0.05
    sched.pods[0].partitioner.validate()


def test_repack_stretches_moved_running_jobs():
    _, records, _, _ = _run_showcase("frag_repack")
    moved_long = [r for r in records
                  if r.job.duration_s == 10_000.0 and r.placed]
    assert moved_long, "long jobs should be running when repack fires"
    stretched = [r for r in moved_long
                 if r.finish_s > r.place_s + r.job.duration_s]
    assert stretched, "migration must delay at least one moved running job"


# ---------------------------------------------------------------------------
# power-cap admission (paper §V-B)
# ---------------------------------------------------------------------------
def _hot_job(jid, arrival, duration):
    return Job(jid, TRAINING, "llama3-8b", "train_4k", arrival, 1,
               profile="8s.128c", duration_s=duration, u_compute=1.0)


def test_power_cap_defers_second_hot_job():
    # two full-power 128-chip jobs together draw 51.2 kW > the 43.5 kW cap
    # (throttle 0.79 < 0.8) -> the second waits for the first to finish
    jobs = [_hot_job(0, 0.0, 100.0), _hot_job(1, 1.0, 100.0)]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             min_throttle=0.8)
    records, metrics = sched.run(jobs)
    second = next(r for r in records if r.job.job_id == 1)
    assert metrics.power_deferrals >= 1
    assert second.place_s == pytest.approx(100.0)  # admitted at completion
    # with the gate off, both co-run and the pod throttles instead
    sched2 = ClusterScheduler(n_pods=1, policy="frag_repack",
                              min_throttle=0.0)
    records2, metrics2 = sched2.run(jobs)
    second2 = next(r for r in records2 if r.job.job_id == 1)
    assert metrics2.power_deferrals == 0
    assert second2.place_s == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# end-to-end on a generated trace
# ---------------------------------------------------------------------------
def test_scheduler_deterministic_and_metrics_sane():
    trace = generate_trace(TraceConfig(seed=0, n_jobs=16))
    m1 = ClusterScheduler(n_pods=2, policy="frag_repack").run(trace)[1]
    m2 = ClusterScheduler(n_pods=2, policy="frag_repack").run(trace)[1]
    assert m1 == m2
    assert m1.placed == m1.n_jobs == 16
    assert m1.completed == 16 and m1.still_running == 0
    assert 0.0 < m1.chip_hour_utilization <= 1.0
    assert 0.0 <= m1.slo_attainment <= 1.0
    assert 0.0 <= m1.frag_time_avg <= 1.0
    assert m1.energy_J > 0 and m1.makespan_s > 0


def test_pods_empty_after_drain():
    trace = generate_trace(TraceConfig(seed=1, n_jobs=10))
    sched = ClusterScheduler(n_pods=2, policy="frag")
    sched.run(trace)
    for pod in sched.pods:
        assert pod.partitioner.free_chips() == V5E_POD.n_chips
        assert not pod.jobs and not pod.slice_jobs
        pod.partitioner.validate()


def test_scheduler_single_use():
    sched = ClusterScheduler(n_pods=1)
    sched.run([])
    with pytest.raises(AssertionError):
        sched.run([])


# ---------------------------------------------------------------------------
# progress-based engine (PerfModel / PodSimulator rewrite)
# ---------------------------------------------------------------------------
# Golden numbers recorded from the PR 2 scheduler (fixed-at-admission
# durations) on this exact seeded trace, before the PodSimulator rewrite.
# ``frozen_durations=True`` must reproduce them bit-for-bit.
_PR2_TRACE = dict(seed=0, n_jobs=48, mean_interarrival_s=5.0)
_PR2_GOLDEN = {
    "makespan_s": 5841.312618401943,
    "energy_J": 164866198.0380577,
    "mean_queue_delay_s": 149.83535556820502,
    "p95_queue_delay_s": 352.84254173889997,
    "slo_attainment": 0.16666666666666666,
    "chip_hour_utilization": 0.38907819980013525,
    "frag_time_avg": 0.29202000328138994,
    "repacks": 1,
    "power_deferrals": 0,
    "migrated_bytes": 3573412790272,
    "migration_s": 3.489660928,
}
_PR2_TIMELINE_SHA = \
    "429696d0b32a6c03aec769b791fd0683498c4ec9749b15f463820d6b919fb9c8"


def test_frozen_durations_bit_identical_to_pr2_scheduler():
    trace = generate_trace(TraceConfig(**_PR2_TRACE))
    records, m = ClusterScheduler(n_pods=1, policy="frag_repack",
                                  frozen_durations=True).run(trace)
    for key, want in _PR2_GOLDEN.items():
        assert getattr(m, key) == want, key   # exact, not approx
    timeline = repr([(r.job.job_id, r.place_s, r.finish_s) for r in records])
    assert (hashlib.sha256(timeline.encode()).hexdigest()
            == _PR2_TIMELINE_SHA)


def _stretch_jobs():
    # two full-power 128-chip training jobs; together they exceed the cap
    return [Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, 50,
                profile="8s.128c", u_compute=1.0),
            Job(1, TRAINING, "llama3-8b", "train_4k", 10.0, 50,
                profile="8s.128c", u_compute=1.0)]


def test_later_arrival_retroactively_stretches_in_flight_job():
    frozen_rec, _ = ClusterScheduler(
        n_pods=1, policy="frag", min_throttle=0.0,
        frozen_durations=True).run(_stretch_jobs())
    progress_rec, _ = ClusterScheduler(
        n_pods=1, policy="frag", min_throttle=0.0).run(_stretch_jobs())
    f_a = next(r for r in frozen_rec if r.job.job_id == 0)
    p_a = next(r for r in progress_rec if r.job.job_id == 0)
    # frozen: job 0's duration was fixed when it ran alone (throttle 1.0);
    # progress: job 1's arrival re-solves the mix and stretches job 0
    assert p_a.finish_s > f_a.finish_s
    # the stretch is retro-active within the run: the projection at
    # placement time (duration_s) is exceeded by the actual finish
    assert p_a.finish_s > p_a.place_s + p_a.duration_s
    # and job 1 finishes *earlier* than frozen mode predicts: once job 0
    # completes, the survivor speeds back up (frozen can't model that)
    f_b = next(r for r in frozen_rec if r.job.job_id == 1)
    p_b = next(r for r in progress_rec if r.job.job_id == 1)
    assert p_b.finish_s < f_b.finish_s


def test_pinned_duration_traces_identical_in_both_modes():
    # the fragmentation showcase pins every duration, so the progress
    # engine must reproduce the frozen timeline exactly
    a = ClusterScheduler(n_pods=1, policy="frag_repack",
                         horizon_s=3000.0).run(fragmentation_showcase())[1]
    b = ClusterScheduler(n_pods=1, policy="frag_repack", horizon_s=3000.0,
                         frozen_durations=True).run(
                             fragmentation_showcase())[1]
    assert a == b


# ---------------------------------------------------------------------------
# elastic shrink (online profile re-selection: SLO miss -> SLO hit)
# ---------------------------------------------------------------------------
def _run_elastic(elastic):
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             horizon_s=3000.0, elastic=elastic)
    records, metrics = sched.run(elastic_showcase())
    deadline_job = next(r for r in records if r.job.job_id == 2)
    victim = next(r for r in records if r.job.job_id == 0)
    return sched, metrics, deadline_job, victim


def test_without_elastic_deadline_job_misses_slo():
    _, metrics, deadline_job, victim = _run_elastic(False)
    assert not deadline_job.placed          # queued behind two long holders
    assert metrics.shrinks == 0
    assert metrics.slo_attainment == 0.0
    assert victim.profile_name == "8s.128c" and not victim.shrunk


def test_elastic_shrink_turns_slo_miss_into_hit():
    sched, metrics, deadline_job, victim = _run_elastic(True)
    # the low-priority batch job was shrunk to the smallest feasible profile
    assert metrics.shrinks == 1
    assert victim.shrunk and victim.profile_name == "1s.16c"
    # the deadline job placed immediately (plus migration delay) and hit
    assert deadline_job.placed and deadline_job.finished
    assert deadline_job.place_s == pytest.approx(10.0)
    assert deadline_job.finish_s <= deadline_job.deadline_s
    # the shrink is priced as a migration over the pod's host links
    assert metrics.migrated_bytes > 0
    assert metrics.migration_s == pytest.approx(
        metrics.migrated_bytes / sched._pod_host_bw)
    # the victim paid: its finish moved past its pinned duration
    assert victim.finish_s > victim.place_s + victim.job.duration_s
    assert metrics.slo_attainment > 0.0
    sched.pods[0].partitioner.validate()


def test_elastic_shrink_lifts_power_gate():
    # the pod HAS an aligned origin for the deadline job, but admitting it
    # next to the full-power batch holder trips the power gate; shrinking
    # the batch job cuts its dynamic draw and lifts the cap
    jobs = [Job(0, BATCH, "gpt2-124m", "decode_32k", 0.0, 1,
                profile="8s.128c", duration_s=10_000.0, u_compute=1.0),
            Job(1, TRAINING, "llama3-8b", "train_4k", 5.0, 1,
                profile="8s.128c", duration_s=200.0, u_compute=1.0,
                slo_factor=2.0)]
    base_rec, base_m = ClusterScheduler(
        n_pods=1, policy="frag_repack", min_throttle=0.8).run(jobs)
    blocked = next(r for r in base_rec if r.job.job_id == 1)
    assert base_m.power_deferrals == 1
    assert blocked.place_s == pytest.approx(10_000.0)  # waited out the holder
    el_rec, el_m = ClusterScheduler(
        n_pods=1, policy="frag_repack", min_throttle=0.8,
        elastic=True).run(jobs)
    rescued = next(r for r in el_rec if r.job.job_id == 1)
    assert el_m.shrinks == 1 and el_m.power_deferrals == 0
    assert rescued.place_s == pytest.approx(5.0)
    assert rescued.finish_s <= rescued.deadline_s


def test_elastic_never_hurts_generated_trace_slo():
    trace = generate_trace(TraceConfig(seed=0, n_jobs=48,
                                       mean_interarrival_s=5.0))
    base = ClusterScheduler(n_pods=1, policy="frag_repack").run(trace)[1]
    el = ClusterScheduler(n_pods=1, policy="frag_repack",
                          elastic=True).run(trace)[1]
    assert el.slo_attainment >= base.slo_attainment


# ---------------------------------------------------------------------------
# live SliceRuntime execution of serving jobs
# ---------------------------------------------------------------------------
def test_serving_jobs_execute_on_live_runtime():
    jobs = [
        Job(0, SERVING, "gpt2-124m", "decode_32k", 0.0, 50, requests=2),
        Job(1, BATCH, "mamba2-130m", "decode_32k", 5.0, 50, u_compute=0.1),
    ]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             execute_serving=True)
    records, metrics = sched.run(jobs)
    serving = next(r for r in records if r.job.kind == SERVING)
    assert serving.executed and serving.tokens_out > 0
    batch = next(r for r in records if r.job.kind == BATCH)
    assert not batch.executed
    assert metrics.completed == 2
    # tenant removed and rectangle released at completion
    pod = sched.pods[0]
    assert not pod.runtime.tenants
    assert pod.partitioner.free_chips() == V5E_POD.n_chips
